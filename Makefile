# Build / verify entry points. `make tier1` is the CI gate (ROADMAP.md):
# release build, tests, bench compilation, clippy, and rustfmt check.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: tier1 build test test-threaded smoke-net smoke-bitslice smoke-fabric smoke-c10k smoke-obs-fleet bench-build doc clippy fmt-check ci artifacts clean bench-lstep bench-pool bench-serve bench-net bench-obs bench-bitslice bench-fabric bench-c10k

tier1: build test test-threaded smoke-net smoke-bitslice smoke-fabric smoke-c10k smoke-obs-fleet bench-build doc clippy fmt-check

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# One extra pass with a pinned multi-thread policy so the persistent
# worker-pool dispatch path (gemm bands, k-means, serve engine) is
# exercised even on single-core CI runners.
test-threaded:
	LCQUANT_THREADS=2 $(CARGO) test -q

# Loopback network smoke: the LCQ-RPC end-to-end suite (real TCP sockets
# on 127.0.0.1, responses bit-identical to the in-process engine, overload
# shed paths), under the default thread policy and the pinned 2-thread
# pool. Redundant with `test`/`test-threaded` by construction — kept as an
# explicit gate so the serving path cannot be skipped.
smoke-net:
	$(CARGO) test -q --test net
	LCQUANT_THREADS=2 $(CARGO) test -q --test net

# Bit-sliced serving tier + zero-copy .lcq load smoke: tier parity across
# every scheme (in-process and over loopback TCP), mmap-vs-eager
# bit-identity, lazy checksum rejection, the zero-alloc warm path, under
# both thread policies.
smoke-bitslice:
	$(CARGO) test -q --test bitslice
	LCQUANT_THREADS=2 $(CARGO) test -q --test bitslice

# Serve-fabric smoke: loopback cluster e2e (RouterServer over two backend
# replicas, kill-one-mid-run failover with bit-identical answers, exact
# injected-fault accounting under a pinned seed, slow-loris shedding),
# under both thread policies.
smoke-fabric:
	$(CARGO) test -q --test fabric
	LCQUANT_THREADS=2 $(CARGO) test -q --test fabric

# C10K event-plane smoke: pipelined round trips matched by id, the
# bounded write queue shedding typed per request, exact fault-tally
# reconciliation through the router, open-loop Poisson / idle-army /
# slow-loris scenarios, and the RLIMIT_NOFILE-gated 1000-connection
# army, under both thread policies.
smoke-c10k:
	$(CARGO) test -q --test c10k
	LCQUANT_THREADS=2 $(CARGO) test -q --test c10k

# Fleet observability smoke (LCQ-RPC v3): cross-tier trace stitching
# through a live two-replica fabric (every trace id resolves to a router
# span AND a backend span), FleetStats merge reconciling EXACTLY with the
# per-backend sums, bucket-exact Histogram::merge, windowed-rate
# arithmetic, and loadgen trace coverage — under both thread policies.
# Redundant with `test` by construction; explicit so the fleet path
# cannot be skipped.
smoke-obs-fleet:
	$(CARGO) test -q --test obs -- stitch fleet_stats histogram_merge rate_window trace_coverage
	LCQUANT_THREADS=2 $(CARGO) test -q --test obs -- stitch fleet_stats histogram_merge rate_window trace_coverage

# Benches are plain binaries (harness = false); --no-run keeps them
# compiling in tier-1 without paying their runtime.
bench-build:
	$(CARGO) bench --no-run

# Documentation gate: rustdoc warnings (missing docs on the gated modules,
# broken intra-doc links anywhere in the crate) are errors. The standalone
# docs live in docs/ (ARCHITECTURE.md, lcq-format.md).
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --quiet

# Lint gate: warnings are errors. Skips (with a note) when the clippy
# component is not installed; when it runs, failures fail the target.
clippy:
	@if $(CARGO) clippy --version >/dev/null 2>&1; then \
		$(CARGO) clippy -- -D warnings; \
	else \
		echo "cargo-clippy not installed; skipping lint"; \
	fi

fmt-check:
	@if $(CARGO) fmt --version >/dev/null 2>&1; then \
		$(CARGO) fmt -- --check; \
	else \
		echo "rustfmt not installed; skipping fmt-check"; \
	fi

# L-step throughput before/after the flat parameter plane; writes
# BENCH_lstep.json next to the repo root.
bench-lstep:
	$(CARGO) bench --bench bench_lstep

# Dispatch-substrate (thread::scope vs persistent pool) and SIMD-vs-scalar
# vecops numbers; the bench_lstep binary also writes BENCH_pool.json.
bench-pool: bench-lstep

# Serve-plane benches: LUT-vs-dense, micro-batch server at pipeline depth
# 1 vs 4, the multi-client saturation sweep → BENCH_serve_pipeline.json,
# and the loopback LCQ-RPC sweep → BENCH_net.json.
bench-serve:
	$(CARGO) bench --bench bench_serve

# Loopback TCP sweep (connections × pipeline depth → BENCH_net.json); the
# same binary also refreshes BENCH_serve_pipeline.json.
bench-net: bench-serve

# Observability overhead A/B: serve-engine throughput with the metrics
# registry + tracing enabled vs disabled, raw hot-path costs (histogram
# record, trace-ring record), routed trace-stamping on-vs-off through a
# two-replica router, and the FleetStats fan-out cost sweep
# → BENCH_obs.json.
bench-obs:
	$(CARGO) bench --bench bench_obs

# Bit-sliced tier vs LUT gather tier per scheme (batch 1/32/256) plus
# eager-vs-mmap cold model load → BENCH_bitslice.json.
bench-bitslice:
	$(CARGO) bench --bench bench_bitslice

# Router overhead (direct vs routed loadgen) and the failover-blip tail
# (kill 1 of 2 replicas mid-run) → BENCH_fabric.json.
bench-fabric:
	$(CARGO) bench --bench bench_fabric

# Connection-count scaling curve of the epoll plane (64/512/2048
# connections × pipeline 1/8, camped idle herd + active drivers)
# → BENCH_net.json.
bench-c10k:
	$(CARGO) bench --bench bench_c10k

ci: tier1

# AOT-lower the JAX graph to HLO artifacts for the PJRT runtime
# (requires jax; the rust side then needs `--features pjrt` with real
# xla-rs bindings, see vendor/xla/README.md).
artifacts:
	$(PYTHON) python/compile/aot.py

clean:
	$(CARGO) clean
