# Build / verify entry points. `make tier1` is the CI gate (ROADMAP.md):
# release build, tests, bench compilation, and rustfmt check.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: tier1 build test bench-build fmt-check ci artifacts clean

tier1: build test bench-build fmt-check

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Benches are plain binaries (harness = false); --no-run keeps them
# compiling in tier-1 without paying their runtime.
bench-build:
	$(CARGO) bench --no-run

fmt-check:
	@if $(CARGO) fmt --version >/dev/null 2>&1; then \
		$(CARGO) fmt -- --check; \
	else \
		echo "rustfmt not installed; skipping fmt-check"; \
	fi

ci: tier1

# AOT-lower the JAX graph to HLO artifacts for the PJRT runtime
# (requires jax; the rust side then needs `--features pjrt` with real
# xla-rs bindings, see vendor/xla/README.md).
artifacts:
	$(PYTHON) python/compile/aot.py

clean:
	$(CARGO) clean
