//! Serve a **quantized** net end-to-end through the `serve` subsystem:
//! LC-quantize LeNet300 into a *family* of packed models (binary-scale and
//! adaptive K=4), save the `.lcq` artifacts (paper §5's ⌈log₂K⌉ bits per
//! weight + codebook — the compression ratio is measured on disk), load
//! them back through the model [`Registry`], and push concurrent traffic
//! through the micro-batching server — then serve the same registry to
//! **network** clients over loopback TCP (the LCQ-RPC plane), checking
//! that a wire round-trip returns bit-identical logits and driving a
//! multi-connection load-generation pass. Reports latency percentiles,
//! throughput, on-disk compression ratios, and agreement of the LUT engine
//! with the native dense forward.
//!
//! With `--features pjrt` and `make artifacts`, the same assignments also
//! run through the AOT PJRT artifact (the L1 Pallas codebook-matmul
//! kernel) as an optional backend cross-check.
//!
//! ```sh
//! cargo run --release --example quantized_serving
//! ```

use anyhow::{anyhow, Result};
use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
use lcquant::coordinator::{lc_quantize, Backend, LcConfig, LcResult, MuSchedule, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::linalg::Mat;
use lcquant::nn::sgd::ClippedLrSchedule;
use lcquant::nn::{Mlp, MlpSpec};
use lcquant::quant::Scheme;
use lcquant::serve::{MicroBatchServer, PackedModel, Registry, ServerConfig};
use lcquant::util::rng::Rng;
use lcquant::util::timer::Timer;
use std::sync::Arc;
use std::time::Duration;

fn quantize(backend: &mut NativeBackend, scheme: Scheme) -> LcResult {
    let cfg = LcConfig {
        scheme,
        mu: MuSchedule::new(1e-3, 1.5),
        iterations: 10,
        l_steps: 50,
        lr: ClippedLrSchedule { eta0: 0.05, decay: 0.99 },
        eval_every: 0,
        ..LcConfig::default()
    };
    lc_quantize(backend, &cfg)
}

fn main() -> Result<()> {
    lcquant::util::log::set_level(lcquant::util::log::Level::Info);

    // 1. Train the reference LeNet300 once.
    let mut data = SynthMnist::generate(1_500, 42);
    data.subtract_mean(None);
    let mut rng = Rng::new(7);
    let (train, test) = data.split(0.1, &mut rng);
    let spec = MlpSpec::lenet300();
    let net = Mlp::new(&spec, 1);
    let mut backend = NativeBackend::new(net, train, Some(test), 128, 1);
    let mut opt = FlatNesterov::new(backend.layout(), 0.95);
    run_sgd(&mut backend, &mut opt, 400, 0.1, None);
    let w_ref = backend.weights();
    let b_ref = backend.biases();

    // 2. LC-quantize into a compression family and pack each variant.
    //    The packer consumes the final C step's assignment indices
    //    directly (LcResult::assignments) — no re-quantization.
    let model_dir = std::env::temp_dir().join("lcquant_serving_models");
    let _ = std::fs::remove_dir_all(&model_dir);
    let mut lc_results = Vec::new();
    for (name, scheme) in [
        ("lenet300-binary", Scheme::BinaryScale),
        ("lenet300-k4", Scheme::AdaptiveCodebook { k: 4 }),
    ] {
        // full reset: LC's L steps train biases too, so each family member
        // must start from the same reference net
        backend.set_weights(&w_ref);
        backend.set_biases(&b_ref);
        let lc = quantize(&mut backend, scheme);
        let biases = backend.biases();
        let model = PackedModel::from_lc(name, &spec, &lc, backend.params())?;
        println!(
            "{name}: train err {:.2}%, ρ = ×{:.1} on disk ({} KiB vs {} KiB dense)",
            lc.train_err,
            model.compression_ratio(),
            model.payload_bits() / 8 / 1024,
            model.reference_bits() / 8 / 1024,
        );
        model.save(&model_dir.join(format!("{name}.lcq")))?;
        lc_results.push((name, lc, biases));
    }

    // 3. Load the family back and validate the LUT engine against the
    //    dense forward on a test batch.
    let registry = Arc::new(Registry::load_dir(&model_dir)?);
    println!("registry serves: {:?}", registry.names());
    let test_set = backend.test.as_ref().unwrap();
    let batch = 128usize;
    let mut x = Mat::zeros(batch, 784);
    for r in 0..batch {
        x.row_mut(r).copy_from_slice(test_set.images.row(r % test_set.len()));
    }
    for name in registry.names() {
        let loaded = registry.get(&name).unwrap();
        let lut = loaded.engine.forward(&x)?;
        let dense_net = loaded.packed.to_mlp();
        let (dense, _) = dense_net.forward(&x, false, None);
        let mut max_dev = 0.0f32;
        for (a, b) in lut.data.iter().zip(&dense.data) {
            max_dev = max_dev.max((a - b).abs());
        }
        println!("{name}: max |lut - dense| logit deviation: {max_dev:.2e}");
        if max_dev > 1e-3 {
            return Err(anyhow!("LUT/native mismatch too large for {name}"));
        }
    }

    // 4. Serve concurrent single-image traffic through the micro-batcher,
    //    routing across both family members.
    let server = MicroBatchServer::start(
        Arc::clone(&registry),
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            // two executors: a batch of one model can execute while a batch
            // of the other is still in flight (multi-task pool underneath)
            pipeline_depth: 2,
        },
    );
    let names = registry.names();
    let n_threads = 8usize;
    let per_thread = 64usize;
    let clients: Vec<_> = (0..n_threads).map(|_| server.client()).collect();
    let t = Timer::start();
    // blocking request drivers: scoped threads, not pool parts, so the
    // LUT engine under test keeps the worker pool to itself
    lcquant::linalg::pool::run_scoped(n_threads, |th| {
        let client = &clients[th];
        for i in 0..per_thread {
            let name = &names[(th + i) % names.len()];
            let row = x.row((th * per_thread + i) % x.rows).to_vec();
            client.infer(name, row).expect("inference failed");
        }
    });
    let elapsed = t.elapsed_s();
    let mut server = server;
    server.stop();
    let stats = server.stats();
    println!(
        "served {} requests in {elapsed:.2}s ({:.0} img/s): p50 {:.2}ms p90 {:.2}ms \
         p99 {:.2}ms, mean batch {:.1} over {} batches",
        stats.requests,
        stats.requests as f64 / elapsed,
        stats.p50_ms,
        stats.p90_ms,
        stats.p99_ms,
        stats.mean_batch,
        stats.batches,
    );

    // 5. The same registry over loopback TCP: the LCQ-RPC network plane.
    //    A wire round-trip must return logits bit-identical to the
    //    in-process engine (the protocol ships f32 bit patterns verbatim
    //    and the server feeds decoded rows to the engine in place).
    use lcquant::net::{loadgen, LoadGenConfig, NetClient, NetConfig, NetServer};
    let net_server = NetServer::start(
        Arc::clone(&registry),
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            pipeline_depth: 2,
        },
        NetConfig { bind_addr: "127.0.0.1:0".into(), ..NetConfig::default() },
    )?;
    let addr = net_server.local_addr().to_string();
    let mut tcp_client = NetClient::connect(&addr).map_err(|e| anyhow!("{e}"))?;
    let row = x.row(0).to_vec();
    let via_tcp = tcp_client.infer(&names[0], &row).map_err(|e| anyhow!("{e}"))?;
    let mut one = Mat::zeros(1, 784);
    one.row_mut(0).copy_from_slice(&row);
    let direct = registry.get(&names[0]).unwrap().engine.forward(&one)?;
    if via_tcp != direct.row(0).to_vec() {
        return Err(anyhow!("TCP logits differ from the in-process engine"));
    }
    println!("TCP round-trip on {addr}: logits bit-identical to the in-process engine");
    let mut lg = LoadGenConfig::new(&addr);
    lg.connections = 4;
    lg.requests_per_conn = 32;
    let report = loadgen::run(&lg)?;
    println!("loadgen: {}", report.summary());
    let mut net_server = net_server;
    net_server.stop();

    // 6. Optional PJRT backend: the same assignments through the AOT
    //    Pallas codebook-matmul artifact.
    #[cfg(feature = "pjrt")]
    pjrt_cross_check(&backend, &lc_results, &spec)?;
    #[cfg(not(feature = "pjrt"))]
    let _ = &lc_results;

    println!("quantized_serving OK");
    Ok(())
}

/// Run one packed variant through the `lenet300_quantized_fwd` PJRT
/// artifact and compare against the native quantized forward (kept as the
/// optional high-performance backend; requires `make artifacts` and real
/// xla-rs bindings).
#[cfg(feature = "pjrt")]
fn pjrt_cross_check(
    backend: &NativeBackend,
    lc_results: &[(&str, LcResult, Vec<Vec<f32>>)],
    spec: &MlpSpec,
) -> Result<()> {
    use lcquant::runtime::{literal_f32, literal_i32, Engine};
    let dir = Engine::default_dir();
    if !Engine::available(&dir) {
        println!("(artifacts not found at {dir:?}; skipping PJRT cross-check)");
        return Ok(());
    }
    let mut engine = Engine::open(&dir)?;
    let spec_art = engine
        .manifest
        .artifacts
        .get("lenet300_quantized_fwd")
        .ok_or_else(|| anyhow!("artifact lenet300_quantized_fwd missing"))?
        .clone();
    let batch = spec_art.meta.get("batch").copied().unwrap_or(128.0) as usize;
    let k = spec_art.meta.get("k").copied().unwrap_or(2.0) as usize;
    // the artifact is lowered for a fixed K; use the matching family
    // member *with the biases it was packed with*
    let (name, lc, biases) = lc_results
        .iter()
        .find(|(_, lc, _)| lc.codebooks[0].len() == k)
        .ok_or_else(|| anyhow!("no packed variant with K={k}"))?;

    let test_set = backend.test.as_ref().unwrap();
    let mut x = vec![0.0f32; batch * 784];
    for r in 0..batch {
        let i = r % test_set.len();
        x[r * 784..(r + 1) * 784].copy_from_slice(test_set.images.row(i));
    }
    let mut inputs: Vec<xla::Literal> = vec![literal_f32(&x, &[batch, 784])?];
    for (l, (assigns, cb)) in lc.assignments.iter().zip(&lc.codebooks).enumerate() {
        // assignments come straight from the LC result — no repacking
        let ids: Vec<i32> = assigns.iter().map(|&a| a as i32).collect();
        inputs.push(literal_i32(&ids, &[spec.sizes[l], spec.sizes[l + 1]])?);
        let mut cb_padded = cb.clone();
        cb_padded.resize(k, *cb.last().unwrap_or(&0.0));
        inputs.push(literal_f32(&cb_padded, &[k])?);
        inputs.push(literal_f32(&biases[l], &[biases[l].len()])?);
    }
    engine.compile("lenet300_quantized_fwd")?;
    let t = Timer::start();
    let out = engine.execute("lenet300_quantized_fwd", &inputs)?;
    let ms = t.elapsed_ms();
    let logits = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
    let mut xm = Mat::zeros(batch, 784);
    xm.data.copy_from_slice(&x);
    let dense = Mlp::from_parts(spec, &lc.wc, biases);
    let (native_logits, _) = dense.forward(&xm, false, None);
    let mut max_dev = 0.0f32;
    for (a, b) in logits.iter().zip(&native_logits.data) {
        max_dev = max_dev.max((a - b).abs());
    }
    println!("pjrt[{name}]: {batch}-image batch in {ms:.2} ms, max |pjrt - native| {max_dev:.2e}");
    if max_dev > 1e-3 {
        return Err(anyhow!("pjrt/native mismatch too large"));
    }
    Ok(())
}
