//! Serve a **quantized** net through the AOT path: LC-binarize LeNet300,
//! then run batched inference through the PJRT-compiled
//! `lenet300_quantized_fwd` artifact — the forward pass whose layers are
//! the L1 Pallas codebook-matmul kernel (assignments u8→i32 + a K-entry
//! codebook per layer), exactly the hardware argument of paper §2.1.
//! Reports batch latency and agreement with the native forward.
//!
//! Requires `make artifacts`. Falls back with a clear message otherwise.
//!
//! ```sh
//! cargo run --release --example quantized_serving
//! ```

use anyhow::{anyhow, Result};
use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
use lcquant::coordinator::{lc_quantize, Backend, LcConfig, MuSchedule, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::nn::sgd::ClippedLrSchedule;
use lcquant::nn::{Mlp, MlpSpec};
use lcquant::quant::kmeans::nearest_sorted;
use lcquant::quant::Scheme;
use lcquant::runtime::{literal_f32, literal_i32, Engine};
use lcquant::util::rng::Rng;
use lcquant::util::timer::Timer;

fn main() -> Result<()> {
    lcquant::util::log::set_level(lcquant::util::log::Level::Info);
    let dir = Engine::default_dir();
    if !Engine::available(&dir) {
        return Err(anyhow!(
            "artifacts not found at {dir:?} — run `make artifacts` first"
        ));
    }
    let mut engine = Engine::open(&dir)?;
    let spec_art = engine
        .manifest
        .artifacts
        .get("lenet300_quantized_fwd")
        .ok_or_else(|| anyhow!("artifact lenet300_quantized_fwd missing"))?
        .clone();
    let batch = spec_art.meta.get("batch").copied().unwrap_or(128.0) as usize;
    let k = spec_art.meta.get("k").copied().unwrap_or(2.0) as usize;

    // 1. Train + LC-quantize LeNet300 at K=2 natively.
    let mut data = SynthMnist::generate(1_500, 42);
    data.subtract_mean(None);
    let mut rng = Rng::new(7);
    let (train, test) = data.split(0.1, &mut rng);
    let spec = MlpSpec::lenet300();
    let net = Mlp::new(&spec, 1);
    let mut backend = NativeBackend::new(net, train, Some(test), 128, 1);
    let mut opt = FlatNesterov::new(&backend.weights(), &backend.biases(), 0.95);
    run_sgd(&mut backend, &mut opt, 400, 0.1, None);
    let cfg = LcConfig {
        scheme: Scheme::AdaptiveCodebook { k },
        mu: MuSchedule::new(1e-3, 1.5),
        iterations: 12,
        l_steps: 50,
        lr: ClippedLrSchedule { eta0: 0.05, decay: 0.99 },
        eval_every: 0,
        ..LcConfig::default()
    };
    let lc = lc_quantize(&mut backend, &cfg);
    println!(
        "quantized net ready: train err {:.2}%, codebooks {:?}",
        lc.train_err, lc.codebooks
    );

    // 2. Pack weights as (assignments, codebook) pairs for the kernel.
    let mut inputs: Vec<xla::Literal> = Vec::new();
    let test_set = backend.test.as_ref().unwrap();
    let mut x = vec![0.0f32; batch * 784];
    let mut labels = Vec::with_capacity(batch);
    for r in 0..batch {
        let i = r % test_set.len();
        x[r * 784..(r + 1) * 784].copy_from_slice(test_set.images.row(i));
        labels.push(test_set.labels[i]);
    }
    inputs.push(literal_f32(&x, &[batch, 784])?);
    let biases = backend.biases();
    for (l, (wl, cb)) in lc.wc.iter().zip(&lc.codebooks).enumerate() {
        let assigns: Vec<i32> = wl
            .iter()
            .map(|&v| nearest_sorted(cb, v) as i32)
            .collect();
        let shape = [spec.sizes[l], spec.sizes[l + 1]];
        inputs.push(literal_i32(&assigns, &shape)?);
        let mut cb_padded = cb.clone();
        cb_padded.resize(k, *cb.last().unwrap_or(&0.0));
        inputs.push(literal_f32(&cb_padded, &[k])?);
        inputs.push(literal_f32(&biases[l], &[biases[l].len()])?);
    }

    // 3. Serve: compile once, then measure steady-state batch latency.
    engine.compile("lenet300_quantized_fwd")?;
    let mut latencies = Vec::new();
    let mut logits = Vec::new();
    for _ in 0..20 {
        let t = Timer::start();
        let out = engine.execute("lenet300_quantized_fwd", &inputs)?;
        latencies.push(t.elapsed_ms());
        logits = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = latencies[latencies.len() / 2];
    println!(
        "served {batch}-image batches: median latency {med:.2} ms ({:.0} img/s)",
        batch as f64 / (med / 1e3)
    );

    // 4. Agreement with the native quantized forward.
    let mut xm = lcquant::linalg::Mat::zeros(batch, 784);
    xm.data.copy_from_slice(&x);
    backend.set_weights(&lc.wc);
    let (native_logits, _) = backend.net.forward(&xm, false, None);
    let mut max_dev = 0.0f32;
    for (a, b) in logits.iter().zip(&native_logits.data) {
        max_dev = max_dev.max((a - b).abs());
    }
    println!("max |pjrt - native| logit deviation: {max_dev:.2e}");
    let errs = native_logits
        .data
        .chunks(10)
        .zip(&labels)
        .filter(|(row, &l)| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
                != l as usize
        })
        .count();
    println!("batch error rate: {:.1}%", 100.0 * errs as f64 / batch as f64);
    if max_dev > 1e-3 {
        return Err(anyhow!("kernel/native mismatch too large"));
    }
    println!("quantized_serving OK");
    Ok(())
}
