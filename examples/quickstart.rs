//! Quickstart: train a small classifier on synthetic MNIST, quantize it to
//! 1 bit/weight with the LC algorithm, and compare against direct
//! compression.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
use lcquant::coordinator::{baselines, lc_quantize, Backend, LcConfig, MuSchedule, NativeBackend};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::nn::sgd::ClippedLrSchedule;
use lcquant::nn::{Mlp, MlpSpec};
use lcquant::quant::ratio::compression_ratio;
use lcquant::quant::Scheme;
use lcquant::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    lcquant::util::log::set_level(lcquant::util::log::Level::Info);

    // 1. Data: deterministic synthetic MNIST (90/10 split, zero-mean).
    let mut data = SynthMnist::generate(2_000, 42);
    data.subtract_mean(None);
    let mut rng = Rng::new(7);
    let (train, test) = data.split(0.1, &mut rng);

    // 2. Reference net: 784-64-10 tanh MLP, Nesterov SGD.
    let spec = MlpSpec::single_hidden(784, 64, 10);
    let (p1, p0) = spec.param_counts();
    let net = Mlp::new(&spec, 1);
    let mut backend = NativeBackend::new(net, train, Some(test), 128, 1);
    let mut opt = FlatNesterov::new(backend.layout(), 0.95);
    run_sgd(&mut backend, &mut opt, 600, 0.1, None);
    let (ref_loss, ref_err) = backend.eval_train();
    let ref_test = backend.eval_test().unwrap().1;
    println!("reference net: train loss {ref_loss:.4}, train err {ref_err:.2}%, test err {ref_test:.2}%");

    // 3. Direct compression at K=2 (1 bit/weight): quantize-and-hope.
    let w_ref = backend.weights();
    let dc = baselines::direct_compression(&mut backend, &Scheme::AdaptiveCodebook { k: 2 }, 9);
    println!(
        "direct compression K=2: train loss {:.4}, test err {:.2}%",
        dc.train_loss,
        dc.test_err.unwrap()
    );

    // 4. LC algorithm at K=2.
    backend.set_weights(&w_ref);
    let cfg = LcConfig {
        scheme: Scheme::AdaptiveCodebook { k: 2 },
        mu: MuSchedule::new(1e-3, 1.4),
        iterations: 20,
        l_steps: 60,
        lr: ClippedLrSchedule { eta0: 0.05, decay: 0.99 },
        momentum: 0.95,
        ..LcConfig::default()
    };
    let lc = lc_quantize(&mut backend, &cfg);
    println!(
        "LC K=2: train loss {:.4}, test err {:.2}% — codebooks {:?}",
        lc.train_loss,
        lc.test_err.unwrap(),
        lc.codebooks
    );
    println!(
        "compression ratio rho = x{:.1} ({} weights at 1 bit + {} float biases)",
        compression_ratio(p1, p0, 2, spec.n_layers()),
        p1,
        p0
    );
    println!(
        "LC improves training loss over DC by {:.1}x",
        dc.train_loss / lc.train_loss.max(1e-9)
    );
    Ok(())
}
