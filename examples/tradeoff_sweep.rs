//! Fig. 6 workload as an example: sweep hidden-layer width H and codebook
//! size K, and print the loss/size surface plus the smallest net meeting a
//! target loss.
//!
//! ```sh
//! cargo run --release --example tradeoff_sweep -- [--hs 2,5,10] [--log2ks 1,2,4]
//! ```

use lcquant::experiments::{fig6_tradeoff, Scale};
use lcquant::util::cli::Args;

fn main() -> anyhow::Result<()> {
    lcquant::util::log::set_level(lcquant::util::log::Level::Info);
    let args = Args::from_env();
    let out = args.get_or("out", "results");
    std::fs::create_dir_all(out)?;
    let scale = Scale::from_str(args.get_or("scale", "quick"));
    fig6_tradeoff::run(out, scale, args.get_u64("seed", 42))?;
    println!("surface written to {out}/fig6_surface.csv");
    Ok(())
}
