//! End-to-end driver (EXPERIMENTS.md §E2E): train the paper's LeNet300
//! (266,610 parameters) on synthetic MNIST, log the reference loss curve,
//! then LC-quantize to K ∈ {2, 4} comparing LC / DC / iDC — the core
//! protocol of paper §5.3 at a CPU-sized budget.
//!
//! ```sh
//! cargo run --release --example lenet300_mnist -- [--steps 1200] [--n 4000]
//! ```

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
use lcquant::coordinator::Backend;
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::experiments::common::{run_all_algorithms, train_reference_on, Protocol};
use lcquant::nn::MlpSpec;
use lcquant::quant::ratio::compression_ratio;
use lcquant::quant::Scheme;
use lcquant::util::cli::Args;
use lcquant::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    lcquant::util::log::set_level(lcquant::util::log::Level::Info);
    let args = Args::from_env();
    let n = args.get_usize("n", 4_000);
    let ref_steps = args.get_usize("steps", 1_200);
    let seed = args.get_u64("seed", 42);

    let mut p = Protocol::quick();
    p.n_data = n;
    p.ref_steps = ref_steps;
    p.lc_iterations = 25;
    p.l_steps = 80;

    let spec = MlpSpec::lenet300();
    let (p1, p0) = spec.param_counts();
    println!("LeNet300: P1={p1} weights, P0={p0} biases");

    // --- train reference, logging the loss curve ---
    let mut data = SynthMnist::generate(n, seed);
    data.subtract_mean(None);
    let mut rng = Rng::new(seed ^ 0x5EED);
    let (train, test) = data.split(0.1, &mut rng);
    // manual training loop to print the loss curve
    let net = lcquant::nn::Mlp::new(&spec, seed);
    let mut backend = lcquant::coordinator::NativeBackend::new(net, train, Some(test), p.batch, seed);
    let mut opt = FlatNesterov::new(backend.layout(), p.momentum);
    let chunk = (ref_steps / 10).max(1);
    let mut done = 0;
    println!("step,loss,train_err");
    while done < ref_steps {
        let k = chunk.min(ref_steps - done);
        let lr = p.lr0 * p.lr_decay.powi((done / chunk) as i32);
        run_sgd(&mut backend, &mut opt, k, lr, None);
        done += k;
        let (l, e) = backend.eval_train();
        println!("{done},{l:.5},{e:.2}");
    }
    let mut tr = lcquant::experiments::common::TrainedRef {
        ref_weights: backend.weights(),
        ref_biases: backend.biases(),
        ref_train_loss: backend.eval_train().0,
        ref_train_err: backend.eval_train().1,
        ref_test_err: backend.eval_test().map(|(_, e)| e),
        backend,
    };
    println!(
        "reference: loss {:.4}, train err {:.2}%, test err {:.2}%",
        tr.ref_train_loss,
        tr.ref_train_err,
        tr.ref_test_err.unwrap()
    );

    // --- LC vs DC vs iDC at K = 4 and K = 2 ---
    for k in [4usize, 2] {
        let scheme = Scheme::AdaptiveCodebook { k };
        let (lc, dc, idc) = run_all_algorithms(&mut tr, &scheme, &p, seed + k as u64);
        let rho = compression_ratio(p1, p0, k, spec.n_layers());
        println!("\nK={k} (rho ~ x{rho:.1}):");
        println!(
            "  LC : train loss {:.5} | train err {:.2}% | test err {:.2}%",
            lc.train_loss,
            lc.train_err,
            lc.test_err.unwrap()
        );
        println!(
            "  DC : train loss {:.5} | train err {:.2}% | test err {:.2}%",
            dc.train_loss,
            dc.train_err,
            dc.test_err.unwrap()
        );
        println!(
            "  iDC: train loss {:.5} | train err {:.2}% | test err {:.2}%",
            idc.train_loss,
            idc.train_err,
            idc.test_err.unwrap()
        );
        for (l, cb) in lc.codebooks.iter().enumerate() {
            println!("  LC layer-{} codebook: {:?}", l + 1, cb);
        }
    }
    // keep the helper referenced for docs parity
    let _ = train_reference_on;
    Ok(())
}
