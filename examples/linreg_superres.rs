//! Paper §5.2 workload as a standalone example: quantize a linear
//! super-resolution regressor (clustered, non-Gaussian weights) with exact
//! L and C steps, and watch DC/iDC stall while LC improves.
//!
//! ```sh
//! cargo run --release --example linreg_superres -- [--n 500] [--k 2]
//! ```

use lcquant::data::superres::SuperResData;
use lcquant::experiments::fig7_linreg::{run_idc, run_lc, LinRegLc};
use lcquant::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 500);
    let k = args.get_usize("k", 2);
    let seed = args.get_u64("seed", 42);

    let data = SuperResData::generate(n, 0.05, seed);
    println!(
        "super-resolution data: {} pairs, x dim {}, y dim {}",
        data.x.rows, data.x.cols, data.y.cols
    );
    let mut lr = LinRegLc::new(&data);
    lr.solve_reference()?;
    println!("reference loss: {:.6}", lr.loss_of(&lr.w));

    let lc = run_lc(&mut lr, k, 10.0, 1.1, 30, seed)?;
    let idc = run_idc(&mut lr, k, 30, seed)?;
    println!("\niter,lc_loss,idc_loss,kmeans_iters");
    for j in 0..lc.loss_per_iter.len() {
        println!(
            "{j},{:.6},{:.6},{}",
            lc.loss_per_iter[j],
            idc.loss_per_iter.get(j).copied().unwrap_or(f64::NAN),
            lc.kmeans_iters.get(j).copied().unwrap_or(0)
        );
    }
    println!(
        "\nK={k}: DC loss {:.6} (= iDC forever), LC final {:.6}; LC codebook {:?}",
        idc.loss_per_iter[0],
        lc.loss_per_iter.last().unwrap(),
        lc.final_codebook
    );
    Ok(())
}
