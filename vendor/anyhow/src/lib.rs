//! Minimal, dependency-free stand-in for the `anyhow` crate, vendored so the
//! workspace builds fully offline. Implements exactly the surface this repo
//! uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros and the
//! [`Context`] extension trait. Semantics match upstream for that surface:
//! `Error` is *not* `std::error::Error` (that is what permits the blanket
//! `From<E: std::error::Error>` conversion powering `?`), and `context`
//! prepends `"{context}: "` to the message.

use std::fmt;

/// A dynamic error: a message plus an optional source it was built from.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Prepend context to the message (keeps the original source).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause this error was converted from, if any.
    pub fn source(&self) -> Option<&(dyn std::error::Error + Send + Sync + 'static)> {
        self.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values (`Result` with a std error, or
/// `Option`), converting them into [`Result<T, Error>`].
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// No overlap with the impl above: `Error` itself is deliberately not a
// `std::error::Error`.
impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return an error unless a condition holds (upstream-compatible subset).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse().context("not a number")?;
        if n == 0 {
            bail!("zero is not allowed (input {s:?})");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_and_context() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("not a number: "), "{e}");
        assert!(e.source().is_some());
        let e = parse("0").unwrap_err();
        assert!(e.to_string().contains("zero is not allowed"), "{e}");
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 3;
        let b = anyhow!("x = {x}");
        assert_eq!(b.to_string(), "x = 3");
        let c = anyhow!("{} + {}", 1, 2);
        assert_eq!(c.to_string(), "1 + 2");
        let d = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(5u8).context("fine").unwrap(), 5);
    }
}
