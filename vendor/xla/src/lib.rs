//! Stub of the `xla-rs` binding surface used by `lcquant::runtime`.
//!
//! [`Literal`] is functional (a typed host buffer with a shape), so the
//! literal helpers and their tests work. The PJRT entry points
//! ([`HloModuleProto::from_text_file`], [`PjRtClient::compile`]) return
//! errors — executing an artifact needs the real bindings (see README.md).

use std::path::Path;

/// Error type; printed with `{:?}` by callers.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: XLA PJRT is stubbed out in this build; link the real xla-rs \
         bindings (see vendor/xla/README.md)"
    ))
}

/// Element types the repo moves across the PJRT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
    U8,
}

/// A host value that can live in a [`Literal`].
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
    const SIZE: usize;
    fn write_le(&self, out: &mut Vec<u8>);
    fn read_le(buf: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $ty:expr, $n:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            const SIZE: usize = $n;
            fn write_le(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn read_le(buf: &[u8]) -> Self {
                let mut b = [0u8; $n];
                b.copy_from_slice(&buf[..$n]);
                <$t>::from_le_bytes(b)
            }
        }
    };
}

native!(f32, ElementType::F32, 4);
native!(f64, ElementType::F64, 8);
native!(i32, ElementType::S32, 4);
native!(i64, ElementType::S64, 8);
native!(u8, ElementType::U8, 1);

/// A typed host buffer with a shape — fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    elem_size: usize,
    data: Vec<u8>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * T::SIZE);
        for v in data {
            v.write_le(&mut bytes);
        }
        Literal { ty: T::TY, elem_size: T::SIZE, data: bytes, dims: vec![data.len() as i64] }
    }

    pub fn element_count(&self) -> usize {
        self.data.len() / self.elem_size
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(XlaError(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        let mut out = self.clone();
        out.dims = dims.to_vec();
        Ok(out)
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(XlaError(format!("to_vec: literal is {:?}, not {:?}", self.ty, T::TY)));
        }
        Ok(self
            .data
            .chunks_exact(self.elem_size)
            .map(|c| T::read_le(c))
            .collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if T::TY != self.ty {
            return Err(XlaError(format!("first: literal is {:?}, not {:?}", self.ty, T::TY)));
        }
        if self.data.len() < self.elem_size {
            return Err(XlaError("first: empty literal".into()));
        }
        Ok(T::read_le(&self.data))
    }

    /// Destructure a tuple literal. Stub literals are never tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_err("to_tuple"))
    }
}

/// Parsed HLO module. The stub cannot parse HLO text.
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(XlaError(format!("HLO file not found: {p:?}")));
        }
        Err(stub_err("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. `cpu()` succeeds so that artifact-directory probing
/// and error paths behave as with the real bindings.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }
}

/// A compiled executable (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_is_functional() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn pjrt_paths_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
        let comp = XlaComputation(());
        assert!(client.compile(&comp).is_err());
    }
}
