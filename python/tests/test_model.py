"""L2 graph correctness: gradients vs finite differences, eval metrics,
the exact linreg L step, the quantized forward, and the conv net."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", False)


def init_mlp(sizes, key):
    params = []
    for l in range(len(sizes) - 1):
        key, k1 = jax.random.split(key)
        limit = np.sqrt(6.0 / (sizes[l] + sizes[l + 1]))
        params.append(
            jax.random.uniform(
                k1, (sizes[l], sizes[l + 1]), jnp.float32, -limit, limit
            )
        )
        params.append(jnp.zeros(sizes[l + 1], jnp.float32))
    return tuple(params)


def batch(key, b, d, classes):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (b, d), jnp.float32)
    labels = jax.random.randint(k2, (b,), 0, classes)
    y = jax.nn.one_hot(labels, classes, dtype=jnp.float32)
    return x, y, labels


def test_grad_fn_matches_finite_differences():
    sizes = (6, 5, 3)
    params = init_mlp(sizes, jax.random.PRNGKey(0))
    x, y, _ = batch(jax.random.PRNGKey(1), 7, 6, 3)
    out = model.mlp_grad_fn(sizes)(*params, x, y)
    loss, grads = out[0], out[1:]
    assert np.isfinite(loss)
    eps = 1e-3
    p0 = np.asarray(params[0])
    for idx in [(0, 0), (3, 2), (5, 4)]:
        pp = p0.copy()
        pp[idx] += eps
        lp = model.mlp_loss((jnp.asarray(pp),) + params[1:], x, y)
        pm = p0.copy()
        pm[idx] -= eps
        lm = model.mlp_loss((jnp.asarray(pm),) + params[1:], x, y)
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - np.asarray(grads[0])[idx]) < 2e-3


def test_grad_fn_pallas_matches_plain():
    sizes = (8, 6, 4)
    params = init_mlp(sizes, jax.random.PRNGKey(2))
    x, y, _ = batch(jax.random.PRNGKey(3), 4, 8, 4)
    plain = model.mlp_grad_fn(sizes, use_pallas=False)(*params, x, y)
    pallas = model.mlp_grad_fn(sizes, use_pallas=True)(*params, x, y)
    for a, b in zip(plain, pallas):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_eval_fn_counts_errors():
    sizes = (4, 3)
    # identity-ish single layer: logits = x @ w
    w = jnp.eye(4, 3, dtype=jnp.float32) * 10
    b = jnp.zeros(3, jnp.float32)
    x = jnp.eye(3, 4, dtype=jnp.float32)  # 3 samples, sample i peaks class i
    y = jnp.eye(3, dtype=jnp.float32)
    loss, errors = model.mlp_eval_fn(sizes)(w, b, x, y)
    assert errors == 0
    y_wrong = jnp.roll(y, 1, axis=0)
    _, errors2 = model.mlp_eval_fn(sizes)(w, b, x, y_wrong)
    assert errors2 == 3


def test_quantized_fwd_equals_dense_forward():
    sizes = (6, 5, 3)
    key = jax.random.PRNGKey(4)
    x, _, _ = batch(key, 4, 6, 3)
    args = [x]
    dense_params = []
    for l in range(len(sizes) - 1):
        key, k1, k2 = jax.random.split(key, 3)
        k_entries = 4
        codebook = jnp.sort(jax.random.normal(k1, (k_entries,), jnp.float32))
        assign = jax.random.randint(
            k2, (sizes[l], sizes[l + 1]), 0, k_entries, dtype=jnp.int32
        )
        bias = jnp.zeros(sizes[l + 1], jnp.float32)
        args += [assign, codebook, bias]
        dense_params += [codebook[assign], bias]
    (logits,) = model.quantized_fwd_fn(sizes)(*args)
    want = model.mlp_forward(tuple(dense_params), x)
    assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_linreg_lstep_solves_normal_equations():
    d_in, d_out, n = 5, 4, 50
    d = d_in + 1
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    xa = jnp.concatenate(
        [jax.random.normal(k1, (n, d_in)), jnp.ones((n, 1))], axis=1
    )
    w_true = jax.random.normal(k2, (d_out, d))
    y = xa @ w_true.T + 0.01 * jax.random.normal(k3, (n, d_out))
    g = np.asarray(xa.T @ xa / n, np.float64)
    h = np.asarray(y.T @ xa / n, np.float64)
    mask = np.concatenate([np.ones(d_in), np.zeros(1)])
    eye = np.eye(d, dtype=np.float32)

    def assemble(mu):
        a = 2.0 * g + np.diag(mu * mask + 1e-6)
        rhs = 2.0 * h  # target T = 0
        return a.astype(np.float32), rhs.astype(np.float32)

    fn = model.linreg_lstep_fn(d_in, d_out)
    # mu -> 0: recovers least squares
    a, rhs = assemble(1e-8)
    (w,) = fn(jnp.asarray(a), jnp.asarray(rhs), jnp.asarray(eye))
    assert_allclose(np.asarray(w), np.asarray(w_true), atol=0.1)
    # mu huge: weight block pinned to target (= 0), bias free
    a, rhs = assemble(1e7)
    (w_pin,) = fn(jnp.asarray(a), jnp.asarray(rhs), jnp.asarray(eye))
    assert np.abs(np.asarray(w_pin)[:, :d_in]).max() < 1e-2
    # solution actually satisfies W A = rhs
    a, rhs = assemble(0.5)
    (w_mid,) = fn(jnp.asarray(a), jnp.asarray(rhs), jnp.asarray(eye))
    resid = np.abs(np.asarray(w_mid) @ a - rhs).max()
    assert resid < 1e-3, f"residual {resid}"


def test_vgg_small_shapes_and_grads():
    shapes = model.vgg_small_shapes()
    key = jax.random.PRNGKey(6)
    params = []
    for _, s in shapes:
        key, k1 = jax.random.split(key)
        params.append(0.1 * jax.random.normal(k1, s, jnp.float32))
    x = jax.random.normal(key, (2, 3, 32, 32), jnp.float32)
    y = jax.nn.one_hot(jnp.array([1, 7]), 10, dtype=jnp.float32)
    out = model.vgg_small_grad_fn()(*params, x, y)
    assert np.isfinite(out[0])
    assert len(out) == 1 + len(params)
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()
    loss, errors = model.vgg_small_eval_fn()(*params, x, y)
    assert np.isfinite(loss) and 0 <= errors <= 2


def test_lenet300_param_specs():
    specs = model.lenet300_param_specs()
    names = [n for n, _ in specs]
    assert names == ["w1", "b1", "w2", "b2", "w3", "b3"]
    p1 = sum(int(np.prod(s)) for n, s in specs if n.startswith("w"))
    p0 = sum(int(np.prod(s)) for n, s in specs if n.startswith("b"))
    assert p1 == 266_200 and p0 == 410  # paper's counts
