"""AOT path: lowering produces loadable HLO text + a consistent manifest.
Numeric agreement between an artifact and its python source is checked by
re-executing the HLO through jax's own CPU client."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_roundtrip_smoke():
    def fn(a, b):
        return (a @ b + 1.0,)

    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(fn, [s, s])
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_build_artifacts_tiny(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build_artifacts(out, batch=8, quant_k=2, progress=lambda *_: None)
    names = set(manifest["artifacts"])
    assert {
        "lenet300_grad",
        "lenet300_grad_pallas",
        "lenet300_eval",
        "lenet300_quantized_fwd",
        "linreg_lstep",
        "vgg_small_grad",
        "vgg_small_eval",
    } <= names
    # files exist and manifest parses back
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    for name, spec in loaded["artifacts"].items():
        path = os.path.join(out, spec["path"])
        assert os.path.exists(path), name
        with open(path) as fh:
            head = fh.read(200)
        assert "HloModule" in head, name
        # arity sanity
        assert len(spec["inputs"]) > 0 and len(spec["outputs"]) > 0


def test_manifest_shapes_match_model_specs(tmp_path):
    out = str(tmp_path / "a")
    manifest = aot.build_artifacts(out, batch=8, quant_k=2, progress=lambda *_: None)
    grad = manifest["artifacts"]["lenet300_grad"]
    # inputs: 6 params + x + y
    assert len(grad["inputs"]) == 8
    assert grad["inputs"][0]["shape"] == [784, 300]
    assert grad["inputs"][6]["shape"] == [8, 784]
    # outputs: loss + 6 grads
    assert len(grad["outputs"]) == 7
    assert grad["outputs"][1]["shape"] == [784, 300]
    assert grad["meta"]["batch"] == 8
    q = manifest["artifacts"]["lenet300_quantized_fwd"]
    assert q["inputs"][1]["dtype"] == "i32"
    assert q["meta"]["k"] == 2


@pytest.mark.slow
def test_lowered_grad_is_jit_consistent():
    """The lowered (jitted) grad graph must agree with eager evaluation —
    the numeric agreement of the HLO-text path itself is asserted by the
    rust integration test `tests/pjrt_integration.rs` against this same
    function."""
    sizes = (10, 6, 4)
    fn = model.mlp_grad_fn(sizes)
    key = jax.random.PRNGKey(0)
    params = []
    for l in range(len(sizes) - 1):
        key, k1 = jax.random.split(key)
        params.append(
            0.3 * jax.random.normal(k1, (sizes[l], sizes[l + 1]), jnp.float32)
        )
        params.append(jnp.zeros(sizes[l + 1], jnp.float32))
    x = jax.random.normal(key, (4, 10), jnp.float32)
    y = jax.nn.one_hot(jnp.array([0, 1, 2, 3]), 4, dtype=jnp.float32)
    args = [*params, x, y]
    eager = fn(*args)
    jitted = jax.jit(fn)(*args)
    for g, w in zip(jitted, eager):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6)
    # and the HLO text for these shapes lowers cleanly
    text = aot.to_hlo_text(
        fn, [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
    )
    assert "HloModule" in text
