"""L1 kernel correctness: Pallas kernels (interpret mode) vs pure-jnp
oracles, with hypothesis sweeping shapes, codebook sizes and block splits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.assign_nearest import assign_nearest
from compile.kernels.codebook_matmul import (
    codebook_matmul,
    codebook_matmul_centroid,
    vmem_bytes,
)
from compile.kernels.dense_tanh import dense_tanh
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def make_case(seed, b, i, o, k):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = rand(ks[0], b, i)
    assign = jax.random.randint(ks[1], (i, o), 0, k, dtype=jnp.int32)
    codebook = jnp.sort(rand(ks[2], k))
    bias = rand(ks[3], o)
    return x, assign, codebook, bias


# --------------------------------------------------------------- gather --

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**30),
    b=st.sampled_from([1, 2, 4, 8]),
    i=st.sampled_from([3, 8, 17]),
    o=st.sampled_from([2, 6, 12]),
    k=st.sampled_from([2, 3, 4, 16]),
)
def test_codebook_matmul_matches_ref(seed, b, i, o, k):
    x, assign, codebook, bias = make_case(seed, b, i, o, k)
    got = codebook_matmul(x, assign, codebook, bias)
    want = ref.codebook_matmul_ref(x, assign, codebook, bias)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_codebook_matmul_blocked_grid(seed):
    # block sizes that split the grid multiple ways
    x, assign, codebook, bias = make_case(seed, 8, 16, 12, 4)
    want = ref.codebook_matmul_ref(x, assign, codebook, bias)
    for bb, bo in [(4, 12), (8, 6), (2, 4)]:
        got = codebook_matmul(x, assign, codebook, bias, block_b=bb, block_o=bo)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_codebook_matmul_rejects_bad_blocks():
    x, assign, codebook, bias = make_case(0, 8, 16, 12, 4)
    with pytest.raises(AssertionError):
        codebook_matmul(x, assign, codebook, bias, block_b=3)


# ------------------------------------------------------------- centroid --

@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**30),
    b=st.sampled_from([1, 4]),
    i=st.sampled_from([5, 9]),
    o=st.sampled_from([3, 8]),
    k=st.sampled_from([2, 3, 8]),
)
def test_centroid_schedule_matches_gather(seed, b, i, o, k):
    x, assign, codebook, bias = make_case(seed, b, i, o, k)
    gather = codebook_matmul(x, assign, codebook, bias)
    centroid = codebook_matmul_centroid(x, assign, codebook, bias)
    want = ref.codebook_matmul_centroid_ref(x, assign, codebook, bias)
    assert_allclose(np.asarray(centroid), np.asarray(want), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(centroid), np.asarray(gather), rtol=1e-4, atol=1e-4)


def test_k2_binary_codebook_exact():
    # K=2 (binarization) — paper table 2 regime; exact values survive
    x = jnp.ones((2, 3), jnp.float32)
    assign = jnp.array([[0, 1], [1, 1], [0, 0]], jnp.int32)
    codebook = jnp.array([-0.5, 0.25], jnp.float32)
    bias = jnp.zeros(2, jnp.float32)
    got = codebook_matmul(x, assign, codebook, bias)
    # col0: -0.5+0.25-0.5 = -0.75 ; col1: 0.25+0.25+(-0.5)... wait:
    want = ref.codebook_matmul_ref(x, assign, codebook, bias)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


# ------------------------------------------------------------ dense tanh --

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**30),
    b=st.sampled_from([1, 2, 8]),
    i=st.sampled_from([4, 11]),
    o=st.sampled_from([2, 10]),
)
def test_dense_tanh_matches_ref(seed, b, i, o):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w, bias = rand(ks[0], b, i), rand(ks[1], i, o), rand(ks[2], o)
    got = dense_tanh(x, w, bias)
    want = ref.dense_tanh_ref(x, w, bias)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dense_tanh_blocked():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x, w, bias = rand(ks[0], 8, 12), rand(ks[1], 12, 6), rand(ks[2], 6)
    want = ref.dense_tanh_ref(x, w, bias)
    got = dense_tanh(x, w, bias, block_b=2, block_o=3)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dense_tanh_output_bounded():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x, w, bias = rand(ks[0], 4, 5) * 100, rand(ks[1], 5, 3) * 100, rand(ks[2], 3)
    got = np.asarray(dense_tanh(x, w, bias))
    assert np.all(got <= 1.0) and np.all(got >= -1.0)


# -------------------------------------------------------- assign nearest --

@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**30),
    n=st.sampled_from([1, 7, 32]),
    k=st.sampled_from([2, 3, 5, 16]),
)
def test_assign_nearest_matches_ref(seed, n, k):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = rand(ks[0], n)
    codebook = jnp.sort(rand(ks[1], k))
    got = assign_nearest(w, codebook)
    want = ref.assign_nearest_ref(w, codebook)
    assert_allclose(np.asarray(got), np.asarray(want))
    # every assignment is actually a nearest entry
    cb = np.asarray(codebook)
    for wi, ai in zip(np.asarray(w), np.asarray(got)):
        dists = np.abs(cb - wi)
        assert dists[ai] <= dists.min() + 1e-6


def test_assign_nearest_tie_breaks_upward():
    # value exactly at a midpoint goes to the upper cell (eq. 11)
    codebook = jnp.array([0.0, 1.0], jnp.float32)
    w = jnp.array([0.5, 0.4999, 0.5001], jnp.float32)
    got = np.asarray(assign_nearest(w, codebook))
    assert list(got) == [1, 0, 1]


def test_assign_nearest_blocked():
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    w = rand(ks[0], 24)
    codebook = jnp.sort(rand(ks[1], 4))
    want = ref.assign_nearest_ref(w, codebook)
    got = assign_nearest(w, codebook, block_n=8)
    assert_allclose(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------------- misc --

def test_vmem_estimate_monotone():
    base = vmem_bytes(8, 784, 128, 2)
    assert vmem_bytes(16, 784, 128, 2) > base
    assert vmem_bytes(8, 784, 256, 2) > base
    assert vmem_bytes(8, 784, 128, 256) > base
    # LeNet300 layer-1 tile fits in 16 MiB VMEM comfortably
    assert vmem_bytes(128, 784, 128, 2) < 16 * 2**20
