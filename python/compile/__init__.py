"""Build-time compile path (L1 kernels + L2 models + AOT lowering).

Python NEVER runs on the request path: `make artifacts` lowers everything
to HLO text once; the rust coordinator loads the artifacts via PJRT.
"""
