"""Codebook-quantized matmul — the paper's §2.1 hardware argument as an L1
Pallas kernel.

A quantized dense layer stores, instead of a float weight matrix W (I, O),
an assignment matrix `assign` (I, O) of small integers plus a codebook
(K,) of floats: W[i, j] = codebook[assign[i, j]]. Two kernels compute
x @ W + b from that representation:

* `codebook_matmul` — gather-then-matmul: decode the weight tile in VMEM
  (K floats + the int tile are far smaller than the float tile in HBM) and
  feed the MXU a standard tile matmul. This is the schedule a TPU would
  actually run: HBM traffic is ~⌈log2 K⌉/32 of the dense layer, decoding is
  elementwise on the VPU, and the MXU sees a dense (block_b × I)·(I ×
  block_o) contraction.

* `codebook_matmul_centroid` — the paper's §2.1 formulation made literal:
  accumulate activations per centroid (a one-hot contraction) and finish
  with a length-K scalar contraction. Same math; this schedule replaces
  the I-deep float multiply-accumulate with an I-deep *select-accumulate*
  plus K multiplies per output — the digital-filter trick the paper cites
  for K=2 codebooks in hardware.

Both run under `interpret=True` (CPU PJRT cannot execute Mosaic
custom-calls); correctness vs `ref.py` is asserted in pytest, and the
VMEM/MXU analysis lives in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(x_ref, a_ref, c_ref, b_ref, o_ref):
    # decode the weight tile from (assignments, codebook), then tile-matmul
    w = c_ref[...][a_ref[...]]  # (I, block_o) gather on the VPU
    o_ref[...] = x_ref[...] @ w + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_b", "block_o"))
def codebook_matmul(x, assign, codebook, bias, block_b=None, block_o=None):
    """x: (B, I) f32, assign: (I, O) i32, codebook: (K,) f32, bias: (O,).

    Block sizes must divide B and O (default: whole array — one grid cell).
    """
    b, i = x.shape
    i2, o = assign.shape
    assert i == i2, f"inner dims {i} vs {i2}"
    bb = block_b or b
    bo = block_o or o
    assert b % bb == 0 and o % bo == 0, "block sizes must divide shapes"
    return pl.pallas_call(
        _gather_kernel,
        grid=(b // bb, o // bo),
        in_specs=[
            pl.BlockSpec((bb, i), lambda gb, go: (gb, 0)),
            pl.BlockSpec((i, bo), lambda gb, go: (0, go)),
            pl.BlockSpec(codebook.shape, lambda gb, go: (0,)),
            pl.BlockSpec((bo,), lambda gb, go: (go,)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda gb, go: (gb, go)),
        out_shape=jax.ShapeDtypeStruct((b, o), x.dtype),
        interpret=True,
    )(x, assign, codebook, bias)


def _centroid_kernel(k: int, x_ref, a_ref, c_ref, b_ref, o_ref):
    # §2.1 schedule: per-centroid activation sums, then K multiplies.
    x = x_ref[...]                      # (bb, I)
    a = a_ref[...]                      # (I, bo)
    c = c_ref[...]                      # (K,)
    onehot = (a[:, :, None] == jnp.arange(k)[None, None, :]).astype(x.dtype)
    # sums[b, o, k] = Σ_i x[b, i] · 1[assign[i, o] = k]
    sums = jnp.einsum("bi,iok->bok", x, onehot)
    o_ref[...] = jnp.einsum("bok,k->bo", sums, c) + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_b", "block_o"))
def codebook_matmul_centroid(x, assign, codebook, bias, block_b=None, block_o=None):
    """Same contract as `codebook_matmul`, centroid-accumulation schedule."""
    b, i = x.shape
    _, o = assign.shape
    k = codebook.shape[0]
    bb = block_b or b
    bo = block_o or o
    assert b % bb == 0 and o % bo == 0, "block sizes must divide shapes"
    return pl.pallas_call(
        functools.partial(_centroid_kernel, k),
        grid=(b // bb, o // bo),
        in_specs=[
            pl.BlockSpec((bb, i), lambda gb, go: (gb, 0)),
            pl.BlockSpec((i, bo), lambda gb, go: (0, go)),
            pl.BlockSpec((k,), lambda gb, go: (0,)),
            pl.BlockSpec((bo,), lambda gb, go: (go,)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda gb, go: (gb, go)),
        out_shape=jax.ShapeDtypeStruct((b, o), x.dtype),
        interpret=True,
    )(x, assign, codebook, bias)


def vmem_bytes(block_b: int, i: int, block_o: int, k: int) -> int:
    """Estimated VMEM working set of one `codebook_matmul` grid cell:
    x tile + int8 assignment tile + decoded f32 weight tile + codebook +
    bias + output tile. Used by the DESIGN.md §Perf roofline estimate."""
    return (
        4 * block_b * i          # x tile f32
        + 1 * i * block_o        # assignments as i8 (i32 in the demo artifact)
        + 4 * i * block_o        # decoded weight tile f32
        + 4 * k                  # codebook
        + 4 * block_o            # bias
        + 4 * block_b * block_o  # output tile
    )
