"""Nearest-centroid assignment Pallas kernel — the C-step inner operation
(eq. 11): each weight maps to the Voronoi cell of a sorted codebook, whose
boundaries are the centroid midpoints. The kernel is a K−1-way comparison
accumulation per weight (O(K) on the VPU; the rust hot path uses the
O(log K) binary-search form — both are checked against each other)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, mids_ref, o_ref):
    w = w_ref[...]
    mids = mids_ref[...]
    # cell index = #midpoints <= w (upper-cell tie-break, eq. 11)
    o_ref[...] = jnp.sum(
        (w[:, None] >= mids[None, :]).astype(jnp.int32), axis=1
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def assign_nearest(w, codebook, block_n=None):
    """w: (N,) f32, codebook: (K,) f32 sorted ascending → (N,) i32."""
    n = w.shape[0]
    k = codebook.shape[0]
    assert k >= 2, "use K >= 2 (K=1 assigns everything to 0)"
    bn = block_n or n
    assert n % bn == 0, "block_n must divide N"
    mids = 0.5 * (codebook[:-1] + codebook[1:])
    return pl.pallas_call(
        _kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda g: (g,)),
            pl.BlockSpec((k - 1,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(w, mids)
