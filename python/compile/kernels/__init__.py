"""L1 Pallas kernels (build-time only; lowered with interpret=True so the
CPU PJRT client can execute them — real-TPU lowering would emit Mosaic
custom-calls the CPU plugin cannot run)."""
