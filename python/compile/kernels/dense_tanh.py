"""Fused dense+tanh Pallas kernel: tanh(x @ w + b) in one VMEM-resident
tile pass (the L-step forward's hot op). MXU-shaped tiling: the grid walks
(batch, out) tiles; each cell is one (bb × I)·(I × bo) contraction plus a
VPU tanh — no intermediate HBM round-trip between the matmul and the
activation, which is the fusion XLA would have to rediscover."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref):
    z = x_ref[...] @ w_ref[...] + b_ref[...][None, :]
    o_ref[...] = jnp.tanh(z)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o"))
def dense_tanh(x, w, b, block_b=None, block_o=None):
    """x: (B, I), w: (I, O), b: (O,) → tanh(x@w+b): (B, O)."""
    bsz, i = x.shape
    i2, o = w.shape
    assert i == i2
    bb = block_b or bsz
    bo = block_o or o
    assert bsz % bb == 0 and o % bo == 0, "block sizes must divide shapes"
    return pl.pallas_call(
        _kernel,
        grid=(bsz // bb, o // bo),
        in_specs=[
            pl.BlockSpec((bb, i), lambda gb, go: (gb, 0)),
            pl.BlockSpec((i, bo), lambda gb, go: (0, go)),
            pl.BlockSpec((bo,), lambda gb, go: (go,)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda gb, go: (gb, go)),
        out_shape=jax.ShapeDtypeStruct((bsz, o), x.dtype),
        interpret=True,
    )(x, w, b)


# interpret-mode pallas_call has no reverse-mode autodiff rule, so the
# training graph uses this custom_vjp wrapper: forward through the kernel,
# analytic backward (tanh' = 1 − y²) in plain jnp — XLA fuses it anyway.
@jax.custom_vjp
def dense_tanh_ad(x, w, b):
    return dense_tanh(x, w, b)


def _dense_tanh_fwd(x, w, b):
    y = dense_tanh(x, w, b)
    return y, (x, w, y)


def _dense_tanh_bwd(res, dy):
    x, w, y = res
    dz = dy * (1.0 - y * y)
    return (dz @ w.T, x.T @ dz, jnp.sum(dz, axis=0))


dense_tanh_ad.defvjp(_dense_tanh_fwd, _dense_tanh_bwd)
