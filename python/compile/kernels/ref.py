"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts `assert_allclose(kernel(...), ref(...))`.
"""

import jax.numpy as jnp


def codebook_matmul_ref(x, assign, codebook, bias):
    """Quantized dense layer: W[i, j] = codebook[assign[i, j]].

    x: (B, I) f32, assign: (I, O) i32, codebook: (K,) f32, bias: (O,) f32.
    Returns (B, O) f32.
    """
    w = codebook[assign]  # gather: (I, O)
    return x @ w + bias[None, :]


def codebook_matmul_centroid_ref(x, assign, codebook, bias):
    """The paper §2.1 formulation of the same product: for each output
    column, *sum the activations per centroid*, then take K scalar
    multiplications with the codebook.

    Mathematically identical to `codebook_matmul_ref`; written in the
    per-centroid accumulation form to mirror the kernel's compute schedule:
        y[b, o] = sum_k codebook[k] * (sum_{i: assign[i,o]=k} x[b, i])
    """
    k = codebook.shape[0]
    onehot = jnp.equal(assign[:, :, None], jnp.arange(k)[None, None, :])
    sums = jnp.einsum("bi,iok->bok", x, onehot.astype(x.dtype))
    return jnp.einsum("bok,k->bo", sums, codebook) + bias[None, :]


def dense_tanh_ref(x, w, b):
    """Fused dense + tanh: tanh(x @ w + b)."""
    return jnp.tanh(x @ w + b[None, :])


def assign_nearest_ref(w, codebook):
    """Nearest codebook entry per weight (C-step assignment, eq. 11).

    w: (N,) f32, codebook: (K,) f32 sorted ascending. Returns (N,) i32.
    Ties broken toward the *upper* cell, matching eq. (11)'s half-open
    intervals and the rust implementation.
    """
    mids = 0.5 * (codebook[:-1] + codebook[1:])  # (K-1,)
    return jnp.sum(w[:, None] >= mids[None, :], axis=1).astype(jnp.int32)
