"""L2: JAX compute graphs for the LC system, lowered once by `aot.py`.

The rust coordinator owns parameters and optimizer state; these graphs are
pure functions:

* `mlp_grad_fn(sizes)`   — (w1,b1,…,wL,bL, x, y1hot) → (loss, dw1,db1,…)
* `mlp_eval_fn(sizes)`   — (params…, x, y1hot) → (loss, error_count)
* `quantized_fwd_fn(sizes, k)` — codebook-quantized forward through the L1
  Pallas kernel (assignments i32 + per-layer codebooks)
* `linreg_lstep_fn(d, out)` — exact penalized normal-equations L step for
  the §5.2 experiment
* `vgg_small_*` — a small conv net (§5.4 conv substrate) using lax.conv

The penalty term μ/2‖w − w_C − λ/μ‖² is *not* baked into the graph: its
gradient μ(w−w_C)−λ is elementwise and the rust side adds it, which keeps
one artifact valid for every μ, scheme and penalty mode (and lets
BinaryConnect reuse the same artifact).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.codebook_matmul import codebook_matmul
from .kernels.dense_tanh import dense_tanh_ad as dense_tanh


# ---------------------------------------------------------------- MLP ----

def mlp_forward(params, x, activation=jnp.tanh, use_pallas=False):
    """params: flat tuple (w1, b1, ..., wL, bL). Hidden layers activated,
    output layer linear. With use_pallas=True the hidden tanh layers run
    through the fused L1 dense_tanh kernel."""
    n_layers = len(params) // 2
    h = x
    for l in range(n_layers):
        w, b = params[2 * l], params[2 * l + 1]
        if l + 1 == n_layers:
            h = h @ w + b[None, :]
        elif use_pallas and activation is jnp.tanh:
            h = dense_tanh(h, w, b)
        else:
            h = activation(h @ w + b[None, :])
    return h


def mlp_loss(params, x, y, activation=jnp.tanh, use_pallas=False):
    """Mean cross-entropy of logits vs one-hot y."""
    logits = mlp_forward(params, x, activation, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y * logp, axis=-1))


def mlp_grad_fn(sizes, activation=jnp.tanh, use_pallas=False):
    """Returns f(*params, x, y) -> (loss, *grads) with grads interleaved
    (dw1, db1, dw2, db2, ...)."""
    n_layers = len(sizes) - 1

    def f(*args):
        params = args[: 2 * n_layers]
        x, y = args[2 * n_layers], args[2 * n_layers + 1]
        loss, grads = jax.value_and_grad(
            lambda p: mlp_loss(p, x, y, activation, use_pallas)
        )(params)
        return (loss, *grads)

    return f


def mlp_eval_fn(sizes, activation=jnp.tanh):
    """Returns f(*params, x, y) -> (loss, error_count)."""
    n_layers = len(sizes) - 1

    def f(*args):
        params = args[: 2 * n_layers]
        x, y = args[2 * n_layers], args[2 * n_layers + 1]
        logits = mlp_forward(params, x, activation)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.sum(y * logp, axis=-1))
        errors = jnp.sum(
            (jnp.argmax(logits, axis=-1) != jnp.argmax(y, axis=-1)).astype(
                jnp.float32
            )
        )
        return (loss, errors)

    return f


def quantized_fwd_fn(sizes, activation=jnp.tanh):
    """Fully quantized forward pass: every layer is the L1 codebook-matmul
    kernel. f(x, a1, c1, b1, ..., aL, cL, bL) -> (logits,)."""
    n_layers = len(sizes) - 1

    def f(x, *args):
        h = x
        for l in range(n_layers):
            a, c, b = args[3 * l], args[3 * l + 1], args[3 * l + 2]
            h = codebook_matmul(h, a, c, b)
            if l + 1 < n_layers:
                h = activation(h)
        return (h,)

    return f


# ------------------------------------------------------------- linreg ----

def linreg_lstep_fn(d_in, d_out, ns_iters=30):
    """Exact penalized L step for §5.2: f(A, rhs, eye) -> (W,), where the
    caller (rust) assembles the SPD system A = 2·X̃X̃ᵀ/N + μ·diag(mask) +
    ridge and rhs = 2·YX̃ᵀ/N + μ·T (see `fig7_linreg.rs`), and `eye` is the
    (d+1)² identity. The graph solves W·A = rhs.

    Three AOT-interchange constraints shaped this design (each verified by
    a staged numeric probe against the rust oracle):
    * `jnp.linalg.solve` lowers to a LAPACK typed-FFI custom-call that
      xla_extension 0.5.1 (the `xla` crate's pinned XLA) cannot execute;
    * an HLO `while` (from a CG `fori_loop`) mis-executes after the text
      round-trip on that version;
    * large dense constants are **elided** by the HLO text printer
      (`constant({...})`) and parsed back as zeros — so the identity
      matrix must be an *input*, not a baked-in constant.
    Hence: unrolled Newton–Schulz inversion (X ← X(2I − AX)) in f64 — a
    fixed chain of matmuls, the most boring possible HLO — quadratically
    convergent, reaching f64 roundoff in 30 iterations for cond(A) ≲ 1e6."""

    def f(a, rhs, eye):
        # f64 internally; f32 interface.
        a = a.astype(jnp.float64)
        rhs = rhs.astype(jnp.float64)
        eye2 = 2.0 * eye.astype(jnp.float64)
        # Newton–Schulz: X0 = Aᵀ/(‖A‖₁‖A‖∞) guarantees ‖I − AX0‖ < 1.
        norm1 = jnp.max(jnp.sum(jnp.abs(a), axis=0))
        norminf = jnp.max(jnp.sum(jnp.abs(a), axis=1))
        x = a.T / (norm1 * norminf)
        for _ in range(ns_iters):
            x = x @ (eye2 - a @ x)
        # W A = rhs  ⇒  W = rhs · A⁻¹
        w = rhs @ x
        return (w.astype(jnp.float32),)

    return f


# ----------------------------------------------------- small conv net ----

def conv_layer(x, w, b, stride=1):
    """NCHW conv with SAME padding + bias."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def max_pool(x, size=2):
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, size, size),
        window_strides=(1, 1, size, size),
        padding="VALID",
    )


VGG_SMALL_CHANNELS = (16, 32)
VGG_SMALL_DENSE = 64


def vgg_small_forward(params, x):
    """A scaled §5.4 conv net: 2×(conv3×3 + ReLU + maxpool) + dense + out.
    x: (B, 3, 32, 32); params = (cw1, cb1, cw2, cb2, dw1, db1, dw2, db2)."""
    cw1, cb1, cw2, cb2, dw1, db1, dw2, db2 = params
    h = jax.nn.relu(conv_layer(x, cw1, cb1))
    h = max_pool(h)  # (B, c1, 16, 16)
    h = jax.nn.relu(conv_layer(h, cw2, cb2))
    h = max_pool(h)  # (B, c2, 8, 8)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ dw1 + db1[None, :])
    return h @ dw2 + db2[None, :]


def vgg_small_shapes(n_classes=10):
    c1, c2 = VGG_SMALL_CHANNELS
    return [
        ("cw1", (c1, 3, 3, 3)),
        ("cb1", (c1,)),
        ("cw2", (c2, c1, 3, 3)),
        ("cb2", (c2,)),
        ("dw1", (c2 * 8 * 8, VGG_SMALL_DENSE)),
        ("db1", (VGG_SMALL_DENSE,)),
        ("dw2", (VGG_SMALL_DENSE, n_classes)),
        ("db2", (n_classes,)),
    ]


def vgg_small_grad_fn():
    """f(*params, x, y) -> (loss, *grads)."""

    def f(*args):
        params = args[:8]
        x, y = args[8], args[9]

        def loss_fn(p):
            logits = vgg_small_forward(p, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.sum(y * logp, axis=-1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (loss, *grads)

    return f


def vgg_small_eval_fn():
    def f(*args):
        params = args[:8]
        x, y = args[8], args[9]
        logits = vgg_small_forward(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.sum(y * logp, axis=-1))
        errors = jnp.sum(
            (jnp.argmax(logits, -1) != jnp.argmax(y, -1)).astype(jnp.float32)
        )
        return (loss, errors)

    return f


# ------------------------------------------------------------ helpers ----

LENET300_SIZES = (784, 300, 100, 10)


@functools.lru_cache(maxsize=None)
def lenet300_param_specs():
    """[(name, shape), ...] for the LeNet300 artifact signature."""
    specs = []
    sizes = LENET300_SIZES
    for l in range(len(sizes) - 1):
        specs.append((f"w{l+1}", (sizes[l], sizes[l + 1])))
        specs.append((f"b{l+1}", (sizes[l + 1],)))
    return specs
