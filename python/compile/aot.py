"""AOT lowering: JAX graphs → HLO **text** artifacts + manifest.json.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the published `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Usage:  cd python && python -m compile.aot --out ../artifacts [--batch 128]
"""

import argparse
import json
import os

import jax

# x64 must be on before any tracing: the linreg L-step solves its SPD
# system in f64 internally (f32 interface). The other artifacts specify
# f32 shapes explicitly and are unaffected.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(fn, example_args) -> str:
    """Lower a python function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def tensor_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def build_artifacts(out_dir: str, batch: int, quant_k: int, progress=print):
    """Lower every artifact; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": {}}

    def emit(name, fn, in_specs, inputs_meta, outputs_meta, meta=None):
        path = f"{name}.hlo.txt"
        text = to_hlo_text(fn, in_specs)
        # The HLO text printer ELIDES large dense constants ("{...}") and
        # the parser zero-fills them — silently corrupting numerics on the
        # rust side. Any artifact with a large constant is a bug: pass the
        # tensor as an input instead.
        if "constant({...})" in text:
            raise ValueError(
                f"artifact '{name}' contains an elided large constant; "
                "pass it as an input instead (see linreg_lstep_fn docs)"
            )
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "path": path,
            "inputs": inputs_meta,
            "outputs": outputs_meta,
            "meta": meta or {},
        }
        progress(f"  {name}: {len(text)} chars")

    sizes = model.LENET300_SIZES
    n_out = sizes[-1]
    pspecs = model.lenet300_param_specs()

    # ---- lenet300_grad -------------------------------------------------
    grad_in = [spec(s) for _, s in pspecs] + [
        spec((batch, sizes[0])),
        spec((batch, n_out)),
    ]
    grad_inputs = [tensor_entry(n, s) for n, s in pspecs] + [
        tensor_entry("x", (batch, sizes[0])),
        tensor_entry("y", (batch, n_out)),
    ]
    grad_outputs = [tensor_entry("loss", ())] + [
        tensor_entry(f"d{n}", s) for n, s in pspecs
    ]
    emit(
        "lenet300_grad",
        model.mlp_grad_fn(sizes),
        grad_in,
        grad_inputs,
        grad_outputs,
        {"batch": batch},
    )

    # ---- lenet300_grad_pallas (hidden layers through the L1 kernel) ----
    emit(
        "lenet300_grad_pallas",
        model.mlp_grad_fn(sizes, use_pallas=True),
        grad_in,
        grad_inputs,
        grad_outputs,
        {"batch": batch, "pallas": 1},
    )

    # ---- lenet300_eval --------------------------------------------------
    emit(
        "lenet300_eval",
        model.mlp_eval_fn(sizes),
        grad_in,
        grad_inputs,
        [tensor_entry("loss", ()), tensor_entry("errors", ())],
        {"batch": batch},
    )

    # ---- lenet300_quantized_fwd (L1 codebook-matmul kernel, all layers) -
    qk = quant_k
    q_in = [spec((batch, sizes[0]))]
    q_inputs = [tensor_entry("x", (batch, sizes[0]))]
    for l in range(len(sizes) - 1):
        q_in += [
            spec((sizes[l], sizes[l + 1]), jnp.int32),
            spec((qk,)),
            spec((sizes[l + 1],)),
        ]
        q_inputs += [
            tensor_entry(f"assign{l+1}", (sizes[l], sizes[l + 1]), "i32"),
            tensor_entry(f"codebook{l+1}", (qk,)),
            tensor_entry(f"b{l+1}", (sizes[l + 1],)),
        ]
    emit(
        "lenet300_quantized_fwd",
        model.quantized_fwd_fn(sizes),
        q_in,
        q_inputs,
        [tensor_entry("logits", (batch, n_out))],
        {"batch": batch, "k": qk},
    )

    # ---- linreg_lstep ----------------------------------------------------
    d_in, d_out = 196, 784
    d = d_in + 1
    emit(
        "linreg_lstep",
        model.linreg_lstep_fn(d_in, d_out),
        [spec((d, d)), spec((d_out, d)), spec((d, d))],
        [
            tensor_entry("A", (d, d)),
            tensor_entry("rhs", (d_out, d)),
            tensor_entry("eye", (d, d)),
        ],
        [tensor_entry("W", (d_out, d))],
        {"d_in": d_in, "d_out": d_out},
    )

    # ---- vgg_small grad/eval (conv substrate for §5.4) ------------------
    vshapes = model.vgg_small_shapes()
    vbatch = max(batch // 4, 8)
    v_in = [spec(s) for _, s in vshapes] + [
        spec((vbatch, 3, 32, 32)),
        spec((vbatch, 10)),
    ]
    v_inputs = [tensor_entry(n, s) for n, s in vshapes] + [
        tensor_entry("x", (vbatch, 3, 32, 32)),
        tensor_entry("y", (vbatch, 10)),
    ]
    emit(
        "vgg_small_grad",
        model.vgg_small_grad_fn(),
        v_in,
        v_inputs,
        [tensor_entry("loss", ())] + [tensor_entry(f"d{n}", s) for n, s in vshapes],
        {"batch": vbatch},
    )
    emit(
        "vgg_small_eval",
        model.vgg_small_eval_fn(),
        v_in,
        v_inputs,
        [tensor_entry("loss", ()), tensor_entry("errors", ())],
        {"batch": vbatch},
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    progress(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--quant-k", type=int, default=2)
    args = ap.parse_args()
    build_artifacts(args.out, args.batch, args.quant_k)


if __name__ == "__main__":
    main()
