#!/bin/sh
# Tier-1 verification: release build + tests + bench compilation + clippy
# + fmt. Equivalent to `make tier1`; kept as a script for environments
# without make.
set -eu

cargo build --release
cargo test -q
# second pass with a pinned multi-thread policy: exercises the persistent
# worker-pool dispatch path even on single-core runners
LCQUANT_THREADS=2 cargo test -q
# loopback network smoke: the LCQ-RPC end-to-end suite over real TCP
# sockets (responses bit-identical to the in-process engine, overload
# shed paths), again under both thread policies — explicit so the serving
# path cannot be skipped
cargo test -q --test net
LCQUANT_THREADS=2 cargo test -q --test net
# observability smoke: the stats-frame loopback round-trip (registry
# snapshot over real TCP, exact loadgen-count match, hostile stats frames
# rejected) plus the zero-alloc hot-path assertions, under both thread
# policies
cargo test -q --test obs
LCQUANT_THREADS=2 cargo test -q --test obs
# fleet observability smoke (v3): cross-tier trace stitching through a
# live router, exact FleetStats merge reconciliation, windowed rates,
# bucket-exact histogram merge, loadgen trace coverage — the filtered
# subset `make smoke-obs-fleet` runs, again under both thread policies
cargo test -q --test obs -- stitch fleet_stats histogram_merge rate_window trace_coverage
LCQUANT_THREADS=2 cargo test -q --test obs -- stitch fleet_stats histogram_merge rate_window trace_coverage
# bit-sliced serving tier + zero-copy .lcq load smoke: tier parity across
# every scheme (in-process and over loopback TCP), mmap-vs-eager
# bit-identity, lazy checksum rejection, the zero-alloc warm path, again
# under both thread policies
cargo test -q --test bitslice
LCQUANT_THREADS=2 cargo test -q --test bitslice
# serve-fabric smoke: loopback cluster e2e (router over two replicas,
# kill-mid-run failover, exact injected-fault accounting, slow-loris
# shedding), again under both thread policies
cargo test -q --test fabric
LCQUANT_THREADS=2 cargo test -q --test fabric
# C10K event-plane smoke: pipelined ids matched out of order, bounded
# write-queue sheds typed per request, fault tallies reconciled exactly
# with router retry counters, open-loop Poisson / idle-army / slow-loris
# scenarios (1000-connection army gated on RLIMIT_NOFILE), again under
# both thread policies
cargo test -q --test c10k
LCQUANT_THREADS=2 cargo test -q --test c10k
cargo bench --no-run
# Documentation gate: rustdoc must build clean (missing docs on the gated
# modules, broken intra-doc links anywhere) — warnings are errors.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "cargo-clippy not installed; skipping lint"
fi
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt -- --check
else
    echo "rustfmt not installed; skipping fmt check"
fi

echo "tier1 OK"
