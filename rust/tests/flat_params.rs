//! Golden tests for the flat parameter-plane refactor:
//!
//! 1. the fused flat `FlatNesterov` + penalty step reproduces the
//!    pre-refactor per-layer step **bit for bit** on a fixed seed;
//! 2. `lc_quantize` (flat buffers, `compress_into`, fused multiplier
//!    update) reproduces a per-layer reference implementation of the seed's
//!    LC loop exactly — wc, codebooks and assignments unchanged;
//! 3. the per-minibatch step path (`next_loss_grads_into` + `opt.step`)
//!    performs **zero heap allocation** in steady state (verified with a
//!    counting global allocator on sub-threading-threshold shapes);
//! 4. `lc_quantize` is deterministic given a seed;
//! 5. with `LCQUANT_THREADS=2`, the *threaded* step path (gemm row bands
//!    dispatched through the persistent `linalg::pool`) performs **zero
//!    heap allocations and zero thread spawns** after warm-up.
//!
//! Every test pins `LCQUANT_THREADS=2` (via [`pin_threads`], before the
//! first `linalg` call resolves the cached thread count): the golden and
//! allocation fixtures use net shapes below the 64-row threading threshold
//! so they stay single-threaded regardless, while the threaded test uses
//! shapes above it so every gemm core crosses the pool.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov, PenaltyState};
use lcquant::coordinator::{lc_quantize, Backend, LcConfig, MuSchedule, NativeBackend, PenaltyMode};
use lcquant::data::Dataset;
use lcquant::linalg::{vecops, Mat};
use lcquant::nn::sgd::ClippedLrSchedule;
use lcquant::nn::{GradBuffer, Mlp, MlpSpec};
use lcquant::quant::{LayerQuantizer, QuantOut, Scheme};
use lcquant::util::rng::Rng;

// ---- counting allocator: a thread-local counter (so the single-threaded
//      assertions are immune to sibling test threads) plus a process-wide
//      counter (so the threaded assertion also sees what pool *worker*
//      threads allocate — a dispatcher-local counter alone would be blind
//      to allocations inside dispatched band closures) -------------------

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

static PROCESS_ALLOCS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

fn thread_allocs() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

fn process_allocs() -> u64 {
    PROCESS_ALLOCS.load(std::sync::atomic::Ordering::Relaxed)
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        PROCESS_ALLOCS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        PROCESS_ALLOCS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serializes the test bodies in this binary: the process-wide counter is
/// only meaningful while no sibling test is allocating concurrently.
/// (Poison is ignored — a failed sibling must not mask this binary's
/// other assertions.)
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial_guard() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

// ---- fixtures -----------------------------------------------------------

/// Pin the worker-thread policy to 2 for this whole test binary. Must run
/// before anything touches `linalg::num_threads()` (cached in a
/// `OnceLock`), so every test calls this first.
fn pin_threads() {
    static PIN: std::sync::Once = std::sync::Once::new();
    PIN.call_once(|| std::env::set_var("LCQUANT_THREADS", "2"));
}

/// Deterministic classification set with every dimension < 64 so the gemm
/// kernels stay single-threaded.
fn tiny_dataset(n: usize, dim: usize, classes: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut images = Mat::zeros(n, dim);
    rng.fill_normal(&mut images.data, 0.0, 1.0);
    let labels: Vec<u8> = (0..n).map(|_| rng.below(classes) as u8).collect();
    Dataset { images, labels, n_classes: classes }
}

fn tiny_backend(seed: u64) -> NativeBackend {
    let spec = MlpSpec {
        sizes: vec![32, 16, 8],
        hidden_activation: lcquant::nn::Activation::Tanh,
        dropout_keep: vec![],
    };
    let net = Mlp::new(&spec, seed);
    NativeBackend::new(net, tiny_dataset(64, 32, 8, seed ^ 0xDA7A), None, 32, seed)
}

// ---- the pre-refactor reference: per-layer parameter plane --------------

struct LegacyNesterov {
    vw: Vec<Vec<f32>>,
    vb: Vec<Vec<f32>>,
    momentum: f32,
}

impl LegacyNesterov {
    fn new(w: &[Vec<f32>], b: &[Vec<f32>], momentum: f32) -> LegacyNesterov {
        LegacyNesterov {
            vw: w.iter().map(|l| vec![0.0; l.len()]).collect(),
            vb: b.iter().map(|l| vec![0.0; l.len()]).collect(),
            momentum,
        }
    }

    fn reset(&mut self) {
        for v in self.vw.iter_mut() {
            v.fill(0.0);
        }
        for v in self.vb.iter_mut() {
            v.fill(0.0);
        }
    }
}

/// The seed's `run_sgd`, verbatim semantics: clone the parameters into
/// per-layer vectors, per step allocate gradients, run the per-layer
/// Nesterov loop (penalty on weights only), then copy the full parameter
/// set back with `set_weights`/`set_biases`. `benches/bench_lstep.rs`
/// carries the same reference as `legacy_step` for its before/after
/// numbers — keep the two in lockstep.
fn legacy_run_sgd(
    backend: &mut NativeBackend,
    opt: &mut LegacyNesterov,
    steps: usize,
    lr: f32,
    penalty: Option<(&[Vec<f32>], &[Vec<f32>], f32)>,
) -> f32 {
    let mut w = backend.weights();
    let mut b = backend.biases();
    let m = opt.momentum;
    let mut loss_sum = 0.0f64;
    for _ in 0..steps {
        let (loss, grads) = backend.next_loss_grads();
        loss_sum += loss as f64;
        for l in 0..w.len() {
            let (wl, vl) = (&mut w[l], &mut opt.vw[l]);
            let gl = grads.w_layer(l);
            match penalty {
                Some((wc, lam, mu)) if mu > 0.0 => {
                    for i in 0..wl.len() {
                        let g = gl[i] + mu * (wl[i] - wc[l][i]) - lam[l][i];
                        vl[i] = m * vl[i] - lr * g;
                        wl[i] += m * vl[i] - lr * g;
                    }
                }
                _ => {
                    for i in 0..wl.len() {
                        vl[i] = m * vl[i] - lr * gl[i];
                        wl[i] += m * vl[i] - lr * gl[i];
                    }
                }
            }
            let (bl, vbl) = (&mut b[l], &mut opt.vb[l]);
            let gbl = grads.b_layer(l);
            for i in 0..bl.len() {
                vbl[i] = m * vbl[i] - lr * gbl[i];
                bl[i] += m * vbl[i] - lr * gbl[i];
            }
        }
        backend.set_weights(&w);
        backend.set_biases(&b);
    }
    (loss_sum / steps.max(1) as f64) as f32
}

// ---- 1. optimizer parity ------------------------------------------------

#[test]
fn fused_flat_step_matches_legacy_per_layer_step_bitwise() {
    pin_threads();
    let _serial = serial_guard();
    let seed = 2024;
    let mut flat = tiny_backend(seed);
    let mut legacy = tiny_backend(seed);
    assert_eq!(flat.params(), legacy.params(), "fixtures must start identical");

    let layout = flat.layout().clone();
    // a non-trivial penalty target/multiplier pair, shared by both runs
    let mut prng = Rng::new(77);
    let mut wc_flat = vec![0.0f32; layout.w_len()];
    let mut lam_flat = vec![0.0f32; layout.w_len()];
    prng.fill_normal(&mut wc_flat, 0.0, 0.3);
    prng.fill_normal(&mut lam_flat, 0.0, 0.05);
    let wc_per = layout.w_per_layer(&wc_flat);
    let lam_per = layout.w_per_layer(&lam_flat);
    let (steps, lr, mu, momentum) = (40usize, 0.07f32, 0.12f32, 0.9f32);

    let mut opt = FlatNesterov::new(&layout, momentum);
    let penalty = PenaltyState { wc: &wc_flat, lambda: &lam_flat, mu };
    let loss_flat = run_sgd(&mut flat, &mut opt, steps, lr, Some(&penalty));

    let mut lopt = LegacyNesterov::new(&legacy.weights(), &legacy.biases(), momentum);
    let loss_legacy =
        legacy_run_sgd(&mut legacy, &mut lopt, steps, lr, Some((&wc_per, &lam_per, mu)));

    assert_eq!(loss_flat, loss_legacy, "average L-step losses must match bitwise");
    assert_eq!(
        flat.params().w_flat(),
        legacy.params().w_flat(),
        "weights diverged from the per-layer reference"
    );
    assert_eq!(
        flat.params().b_flat(),
        legacy.params().b_flat(),
        "biases diverged from the per-layer reference"
    );

    // and the unpenalized path
    let mut flat2 = tiny_backend(seed + 1);
    let mut legacy2 = tiny_backend(seed + 1);
    let mut opt2 = FlatNesterov::new(&layout, momentum);
    run_sgd(&mut flat2, &mut opt2, steps, lr, None);
    let mut lopt2 = LegacyNesterov::new(&legacy2.weights(), &legacy2.biases(), momentum);
    legacy_run_sgd(&mut legacy2, &mut lopt2, steps, lr, None);
    assert_eq!(flat2.params().w_flat(), legacy2.params().w_flat());
    assert_eq!(flat2.params().b_flat(), legacy2.params().b_flat());
}

// ---- 2. LC loop parity --------------------------------------------------

fn parity_cfg() -> LcConfig {
    LcConfig {
        scheme: Scheme::AdaptiveCodebook { k: 4 },
        mu: MuSchedule::new(0.002, 1.4),
        iterations: 6,
        l_steps: 20,
        lr: ClippedLrSchedule { eta0: 0.05, decay: 0.98 },
        momentum: 0.9,
        mode: PenaltyMode::AugmentedLagrangian,
        tol: 0.0,      // run every iteration in both implementations
        seed: 7,
        eval_every: 0, // metrics only at the end (no extra RNG traffic)
        n_weight_samples: 0,
    }
}

/// The seed's `lc_quantize` loop, reimplemented over per-layer vectors with
/// the allocating `compress` — the pre-refactor semantics.
fn legacy_lc(
    backend: &mut NativeBackend,
    cfg: &LcConfig,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<u32>>, Vec<Vec<f32>>) {
    let n_layers = backend.n_layers();
    let mut quantizers: Vec<LayerQuantizer> = (0..n_layers)
        .map(|l| LayerQuantizer::new(cfg.scheme.clone(), cfg.seed.wrapping_add(l as u64)))
        .collect();
    let mut w = backend.weights();
    let mut wc: Vec<Vec<f32>> = Vec::new();
    let mut codebooks: Vec<Vec<f32>> = Vec::new();
    let mut assignments: Vec<Vec<u32>> = Vec::new();
    for (l, q) in quantizers.iter_mut().enumerate() {
        let out = q.compress(&w[l]);
        wc.push(out.wc);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
    }
    let mut lambda: Vec<Vec<f32>> = w.iter().map(|l| vec![0.0; l.len()]).collect();
    let mut shifted: Vec<Vec<f32>> = w.iter().map(|l| vec![0.0; l.len()]).collect();
    let mut opt = LegacyNesterov::new(&w, &backend.biases(), cfg.momentum);

    for j in 0..cfg.iterations {
        let mu = cfg.mu.mu(j);
        let lr = cfg.lr.lr(j, mu);
        opt.reset();
        legacy_run_sgd(backend, &mut opt, cfg.l_steps, lr, Some((&wc, &lambda, mu)));
        w = backend.weights();
        for l in 0..n_layers {
            vecops::shift_by_multipliers(&w[l], &lambda[l], mu, &mut shifted[l]);
            let out = quantizers[l].compress(&shifted[l]);
            wc[l] = out.wc;
            codebooks[l] = out.codebook;
            assignments[l] = out.assignments;
        }
        for l in 0..n_layers {
            vecops::update_multipliers(&mut lambda[l], &w[l], &wc[l], mu);
        }
    }
    backend.set_weights(&wc);
    (wc, codebooks, assignments, w)
}

#[test]
fn lc_quantize_matches_legacy_reference_implementation() {
    pin_threads();
    let _serial = serial_guard();
    let seed = 515;
    let cfg = parity_cfg();

    // identical pre-trained starting points
    let mut pre_a = tiny_backend(seed);
    let mut pre_b = tiny_backend(seed);
    let mut opt_a = FlatNesterov::new(pre_a.layout(), 0.9);
    run_sgd(&mut pre_a, &mut opt_a, 60, 0.1, None);
    let mut opt_b = FlatNesterov::new(pre_b.layout(), 0.9);
    run_sgd(&mut pre_b, &mut opt_b, 60, 0.1, None);
    assert_eq!(pre_a.params(), pre_b.params());

    let res = lc_quantize(&mut pre_a, &cfg);
    let (wc, codebooks, assignments, w) = legacy_lc(&mut pre_b, &cfg);

    assert_eq!(res.wc, wc, "quantized weights changed under the refactor");
    assert_eq!(res.codebooks, codebooks, "codebooks changed under the refactor");
    assert_eq!(res.assignments, assignments, "assignments changed under the refactor");
    assert_eq!(res.w, w, "continuous weights changed under the refactor");
    // both leave the backend holding the quantized weights
    assert_eq!(pre_a.params().w_flat(), pre_b.params().w_flat());
}

#[test]
fn lc_quantize_is_deterministic_given_seed() {
    pin_threads();
    let _serial = serial_guard();
    let run = || {
        let mut b = tiny_backend(99);
        let mut opt = FlatNesterov::new(b.layout(), 0.9);
        run_sgd(&mut b, &mut opt, 50, 0.1, None);
        lc_quantize(&mut b, &parity_cfg())
    };
    let a = run();
    let b = run();
    assert_eq!(a.wc, b.wc);
    assert_eq!(a.codebooks, b.codebooks);
    assert_eq!(a.assignments, b.assignments);
    assert_eq!(a.train_loss, b.train_loss);
}

// ---- 3. allocation-free step path ---------------------------------------

#[test]
fn steady_state_minibatch_step_is_allocation_free() {
    pin_threads();
    let _serial = serial_guard();
    let mut backend = tiny_backend(31);
    let layout = backend.layout().clone();
    let mut opt = FlatNesterov::new(&layout, 0.9);
    let mut grads = GradBuffer::zeros(layout.clone());
    let wc = vec![0.1f32; layout.w_len()];
    let lambda = vec![0.0f32; layout.w_len()];

    // Warm up: sizes the batch buffer, activation scratch and label
    // capacity, and crosses an epoch-reshuffle boundary (n=64, batch=32).
    for _ in 0..5 {
        backend.next_loss_grads_into(&mut grads);
        let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu: 0.05 };
        opt.step(backend.params_mut(), &grads, 0.05, Some(&penalty));
    }

    let before = thread_allocs();
    for _ in 0..10 {
        backend.next_loss_grads_into(&mut grads);
        let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu: 0.05 };
        opt.step(backend.params_mut(), &grads, 0.05, Some(&penalty));
    }
    let allocs = thread_allocs() - before;
    assert_eq!(
        allocs, 0,
        "per-minibatch step path allocated {allocs} times over 10 steps"
    );

    // the unpenalized path must be allocation-free too
    let before = thread_allocs();
    for _ in 0..10 {
        backend.next_loss_grads_into(&mut grads);
        opt.step(backend.params_mut(), &grads, 0.05, None);
    }
    let allocs = thread_allocs() - before;
    assert_eq!(
        allocs, 0,
        "unpenalized step path allocated {allocs} times over 10 steps"
    );
}

#[test]
fn warm_threaded_cstep_lloyd_passes_are_allocation_free() {
    pin_threads();
    let _serial = serial_guard();
    assert_eq!(lcquant::linalg::num_threads(), 2, "LCQUANT_THREADS pin failed");
    // Above the k-means 2M threading threshold, so every Lloyd assignment
    // pass fans out across the worker pool — the per-part `sums`/`counts`
    // reduction regions and the midpoint buffer must all come from the
    // quantizer's reusable AssignScratch, not per-pass allocations.
    let n = 2_100_000usize;
    let mut rng = Rng::new(0xC57E9);
    let mut data = vec![0.0f32; n];
    rng.fill_normal(&mut data, 0.0, 1.0);
    let mut q = LayerQuantizer::new(Scheme::AdaptiveCodebook { k: 4 }, 11);
    let mut out = QuantOut::default();
    // Warm up: k-means++ init, output/scratch buffer sizing, pool spawn.
    q.compress_into(&data, &mut out);
    q.compress_into(&data, &mut out);
    let spawned_before = lcquant::linalg::pool::total_spawned();
    // Same windowed-minimum discipline as the threaded L-step test below:
    // the libtest harness may allocate on its own threads at arbitrary
    // moments, but a genuinely allocating Lloyd pass allocates in *every*
    // window.
    let mut min_allocs = u64::MAX;
    for _ in 0..5 {
        let before = process_allocs();
        q.compress_into(&data, &mut out);
        min_allocs = min_allocs.min(process_allocs() - before);
    }
    assert_eq!(
        min_allocs, 0,
        "warm threaded C step allocated {min_allocs} times in one compress"
    );
    assert_eq!(
        lcquant::linalg::pool::total_spawned() - spawned_before,
        0,
        "threaded assignment passes must not spawn threads after warm-up"
    );
    // and the result is still a valid 4-entry codebook quantization
    assert_eq!(out.codebook.len(), 4);
    assert_eq!(out.wc.len(), n);
    assert!(out.assignments.iter().all(|&a| a < 4));
}

#[test]
fn threaded_minibatch_step_is_allocation_and_spawn_free() {
    pin_threads();
    let _serial = serial_guard();
    assert_eq!(lcquant::linalg::num_threads(), 2, "LCQUANT_THREADS pin failed");
    // every dimension ≥ the 64-row threading threshold, so all three gemm
    // cores (forward, dW, dX) dispatch row bands through the pool on every
    // minibatch step
    let spec = MlpSpec {
        sizes: vec![96, 80, 10],
        hidden_activation: lcquant::nn::Activation::Tanh,
        dropout_keep: vec![],
    };
    let net = Mlp::new(&spec, 7);
    let mut backend =
        NativeBackend::new(net, tiny_dataset(256, 96, 10, 0xF00D), None, 128, 7);
    let layout = backend.layout().clone();
    let mut opt = FlatNesterov::new(&layout, 0.9);
    let mut grads = GradBuffer::zeros(layout.clone());
    let wc = vec![0.1f32; layout.w_len()];
    let lambda = vec![0.0f32; layout.w_len()];

    // Warm up: initializes the global pool (the one place threads are
    // spawned), sizes the batch/activation scratch, and crosses an
    // epoch-reshuffle boundary (n=256, batch=128).
    for _ in 0..5 {
        backend.next_loss_grads_into(&mut grads);
        let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu: 0.05 };
        opt.step(backend.params_mut(), &grads, 0.05, Some(&penalty));
    }

    let spawned_before = lcquant::linalg::pool::total_spawned();
    // The *process-wide* counter sees dispatcher and pool-worker threads
    // alike (a dispatcher-local counter would be blind to allocations
    // inside dispatched band closures). `SERIAL` excludes sibling test
    // bodies; the libtest harness itself may still allocate on its own
    // threads at arbitrary moments (starting a queued test), so measure
    // several windows and take the minimum: a genuinely allocating step
    // path allocates in *every* window, while one-off harness noise
    // cannot hit all of them.
    let mut min_allocs = u64::MAX;
    let mut min_thread_allocs = u64::MAX;
    for _ in 0..5 {
        let before = process_allocs();
        let t_before = thread_allocs();
        for _ in 0..10 {
            backend.next_loss_grads_into(&mut grads);
            let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu: 0.05 };
            opt.step(backend.params_mut(), &grads, 0.05, Some(&penalty));
        }
        min_allocs = min_allocs.min(process_allocs() - before);
        min_thread_allocs = min_thread_allocs.min(thread_allocs() - t_before);
    }
    let spawned = lcquant::linalg::pool::total_spawned() - spawned_before;
    assert_eq!(
        min_thread_allocs, 0,
        "threaded step path allocated {min_thread_allocs} times on the dispatcher over 10 steps"
    );
    assert_eq!(
        min_allocs, 0,
        "threaded step path allocated {min_allocs} times process-wide over 10 steps \
         (pool dispatch and worker band kernels must be allocation-free)"
    );
    assert_eq!(
        spawned, 0,
        "threaded step path spawned {spawned} pool workers after warm-up"
    );
}
