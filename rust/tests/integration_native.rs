//! Integration tests over the native stack: data → reference training →
//! LC/DC/iDC/BinaryConnect → quantized nets, plus the experiment drivers
//! at smoke scale.

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
use lcquant::coordinator::{baselines, lc_quantize, Backend, LcConfig, MuSchedule, NativeBackend, PenaltyMode};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::nn::sgd::ClippedLrSchedule;
use lcquant::nn::{Mlp, MlpSpec};
use lcquant::quant::{distortion, Scheme};
use lcquant::util::rng::Rng;

fn trained_backend(h: usize, n: usize, steps: usize, seed: u64) -> NativeBackend {
    let mut data = SynthMnist::generate(n, seed);
    data.subtract_mean(None);
    let mut rng = Rng::new(seed);
    let (train, test) = data.split(0.15, &mut rng);
    let net = Mlp::new(&MlpSpec::single_hidden(784, h, 10), seed);
    let mut backend = NativeBackend::new(net, train, Some(test), 64, seed);
    let mut opt = FlatNesterov::new(backend.layout(), 0.9);
    run_sgd(&mut backend, &mut opt, steps, 0.1, None);
    backend
}

fn cfg(scheme: Scheme, iters: usize) -> LcConfig {
    LcConfig {
        scheme,
        mu: MuSchedule::new(1e-3, 1.5),
        iterations: iters,
        l_steps: 80,
        lr: ClippedLrSchedule { eta0: 0.1, decay: 0.98 },
        momentum: 0.9,
        mode: PenaltyMode::AugmentedLagrangian,
        tol: 1e-4,
        seed: 3,
        eval_every: 0,
        n_weight_samples: 0,
    }
}

#[test]
fn full_pipeline_lc_beats_dc_beats_nothing() {
    let mut backend = trained_backend(24, 400, 250, 11);
    let (ref_loss, ref_err) = backend.eval_train();
    assert!(ref_err < 15.0, "reference did not learn: err {ref_err}%");

    let w_ref = backend.weights();
    let dc = baselines::direct_compression(&mut backend, &Scheme::AdaptiveCodebook { k: 2 }, 5);
    backend.set_weights(&w_ref);
    let lc = lc_quantize(&mut backend, &cfg(Scheme::AdaptiveCodebook { k: 2 }, 16));

    assert!(dc.train_loss > ref_loss, "K=2 DC should hurt vs reference");
    assert!(
        lc.train_loss < dc.train_loss,
        "LC {} must beat DC {}",
        lc.train_loss,
        dc.train_loss
    );
}

#[test]
fn paper_ordering_lc_le_idc_le_dc_at_k2() {
    // the central qualitative result of Fig. 9 at 1 bit/weight
    let mut backend = trained_backend(24, 400, 250, 13);
    let w_ref = backend.weights();
    let scheme = Scheme::AdaptiveCodebook { k: 2 };

    let dc = baselines::direct_compression(&mut backend, &scheme, 1);
    backend.set_weights(&w_ref);
    let idc = baselines::iterated_direct_compression(
        &mut backend,
        &scheme,
        16,
        40,
        ClippedLrSchedule { eta0: 0.05, decay: 0.98 },
        0.9,
        1,
        0,
    );
    backend.set_weights(&w_ref);
    let lc = lc_quantize(&mut backend, &cfg(scheme, 16));

    assert!(
        lc.train_loss <= idc.train_loss * 1.05,
        "LC {} should be <= iDC {}",
        lc.train_loss,
        idc.train_loss
    );
    assert!(
        idc.train_loss < dc.train_loss,
        "iDC {} should be < DC {}",
        idc.train_loss,
        dc.train_loss
    );
}

#[test]
fn all_schemes_produce_feasible_nets() {
    let mut backend = trained_backend(12, 250, 150, 17);
    let w_ref = backend.weights();
    let schemes = vec![
        Scheme::AdaptiveCodebook { k: 4 },
        Scheme::Binary,
        Scheme::BinaryScale,
        Scheme::Ternary,
        Scheme::TernaryScale,
        Scheme::PowersOfTwo { c: 3 },
        Scheme::FixedCodebook { codebook: vec![-0.2, 0.0, 0.2] },
    ];
    for scheme in schemes {
        backend.set_weights(&w_ref);
        let lc = lc_quantize(&mut backend, &cfg(scheme.clone(), 10));
        for (wl, cb) in lc.wc.iter().zip(&lc.codebooks) {
            for v in wl {
                assert!(
                    cb.iter().any(|c| (c - v).abs() < 1e-5),
                    "{scheme:?}: weight {v} outside codebook {cb:?}"
                );
            }
        }
        assert!(lc.train_loss.is_finite(), "{scheme:?} diverged");
    }
}

#[test]
fn binary_connect_vs_lc_table2_shape() {
    // Table 2 shape: at 1 bit/weight, LC's error is at least as good as
    // BinaryConnect's, and both produce genuinely quantized nets. (Loss
    // ordering at toy scale is noisy — the paper compares at full scale;
    // error-rate parity + feasibility is the stable invariant.)
    let mut backend = trained_backend(24, 400, 250, 19);
    let w_ref = backend.weights();
    let bc = baselines::binary_connect(&mut backend, &Scheme::Binary, 16 * 80, 0.02, 0.9, 7);
    backend.set_weights(&w_ref);
    let lc = lc_quantize(&mut backend, &cfg(Scheme::AdaptiveCodebook { k: 2 }, 16));
    assert!(
        lc.train_err <= bc.train_err + 1.0,
        "LC err {}% should be <= BC err {}% (+1pt)",
        lc.train_err,
        bc.train_err
    );
    for wl in &bc.wc {
        assert!(wl.iter().all(|v| v.abs() == 1.0));
    }
    for (wl, cb) in lc.wc.iter().zip(&lc.codebooks) {
        assert!(cb.len() <= 2);
        for v in wl {
            assert!(cb.iter().any(|c| (c - v).abs() < 1e-6));
        }
    }
}

#[test]
fn lagrangian_feasibility_tightens_with_mu() {
    let mut backend = trained_backend(12, 250, 150, 23);
    let mut c = cfg(Scheme::AdaptiveCodebook { k: 2 }, 18);
    c.tol = 0.0;
    let lc = lc_quantize(&mut backend, &c);
    let first = lc.history[2].feasibility;
    let last = lc.history.last().unwrap().feasibility;
    assert!(last < first, "feasibility {first} -> {last}");
    // continuous and quantized weights nearly coincide at the end
    let total: f64 = lc
        .w
        .iter()
        .zip(&lc.wc)
        .map(|(a, b)| distortion(a, b))
        .sum();
    let norm: f64 = lc
        .w
        .iter()
        .flat_map(|l| l.iter().map(|v| (*v as f64).powi(2)))
        .sum();
    assert!(total < 0.05 * norm, "final distortion {total} vs norm {norm}");
}

#[test]
fn experiment_drivers_smoke() {
    // fig7 (self-contained linreg) at tiny scale writes its CSVs
    let dir = std::env::temp_dir().join("lcquant_it_fig7");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    lcquant::experiments::fig7_linreg::run(
        dir.to_str().unwrap(),
        lcquant::experiments::Scale::Quick,
        1,
    )
    .unwrap();
    assert!(dir.join("fig7_curves.csv").exists());
    assert!(dir.join("fig7_weight_kde.csv").exists());
    let csv = std::fs::read_to_string(dir.join("fig7_curves.csv")).unwrap();
    assert!(csv.lines().count() > 30);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn config_roundtrip_drives_lc() {
    let dir = std::env::temp_dir().join("lcquant_it_cfg");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_text = r#"{
      "name": "it-tiny",
      "seed": 5,
      "net": {"sizes": [784, 8, 10]},
      "data": {"n": 200, "test_frac": 0.2},
      "train": {"ref_steps": 60, "batch": 32},
      "lc": {"scheme": "binary_scale", "mu0": 0.01, "mu_mult": 1.5, "iterations": 6, "l_steps": 20}
    }"#;
    let cfg = lcquant::config::RunConfig::from_json(cfg_text).unwrap();
    assert_eq!(cfg.lc.scheme, Scheme::BinaryScale);
    let mut data = SynthMnist::generate(cfg.data.n, cfg.seed);
    data.subtract_mean(None);
    let mut rng = Rng::new(1);
    let (train, test) = data.split(cfg.data.test_frac, &mut rng);
    let net = Mlp::new(&cfg.net, cfg.seed);
    let mut backend = NativeBackend::new(net, train, Some(test), cfg.train.batch, cfg.seed);
    let res = lc_quantize(&mut backend, &cfg.lc);
    // binary-with-scale: exactly two values ±a per layer
    for cb in &res.codebooks {
        assert_eq!(cb.len(), 2);
        assert!((cb[0] + cb[1]).abs() < 1e-5);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pack_serve_pipeline_end_to_end() {
    // train → LC → pack → save → load → registry → micro-batch serve:
    // the served logits must match the backend's own quantized forward.
    use lcquant::serve::{MicroBatchServer, PackedModel, Registry, ServerConfig};
    use std::sync::Arc;

    let mut backend = trained_backend(16, 300, 150, 29);
    let lc = lc_quantize(&mut backend, &cfg(Scheme::AdaptiveCodebook { k: 4 }, 10));
    let spec = backend.net.spec.clone();
    let model = PackedModel::from_lc("it-k4", &spec, &lc, backend.params()).unwrap();

    // on-disk accounting matches eq. (14)
    let (p1, p0) = spec.param_counts();
    assert_eq!(
        model.payload_bits(),
        lcquant::quant::ratio::quantized_bits(p1, p0, 4, spec.n_layers())
    );

    let dir = std::env::temp_dir().join("lcquant_it_serve");
    let _ = std::fs::remove_dir_all(&dir);
    model.save(&dir.join("it-k4.lcq")).unwrap();
    let registry = Arc::new(Registry::load_dir(&dir).unwrap());

    // backend already holds wc after lc_quantize; its forward is the oracle
    let test_set = backend.test.as_ref().unwrap();
    let n = 6usize;
    let mut x = lcquant::linalg::Mat::zeros(n, 784);
    for r in 0..n {
        x.row_mut(r).copy_from_slice(test_set.images.row(r % test_set.len()));
    }
    let (oracle, _) = backend.net.forward(&x, false, None);

    let mut server = MicroBatchServer::start(
        Arc::clone(&registry),
        ServerConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(1),
            pipeline_depth: 2,
        },
    );
    let client = server.client();
    for r in 0..n {
        let logits = client.infer("it-k4", x.row(r).to_vec()).unwrap();
        assert_eq!(logits.len(), 10);
        for (a, b) in logits.iter().zip(oracle.row(r)) {
            assert!(
                (a - b).abs() <= 1e-3,
                "row {r}: served {a} vs dense {b}"
            );
        }
    }
    server.stop();
    assert_eq!(server.stats().requests, n);
    let _ = std::fs::remove_dir_all(&dir);
}
