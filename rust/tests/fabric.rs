//! Loopback cluster e2e for the serve fabric: real TCP on 127.0.0.1,
//! backend replicas (`NetServer`) behind a `RouterServer`, driven by a
//! plain `NetClient` — the client needs no fabric awareness.
//!
//! The load-bearing assertions:
//!
//! * routed responses are **bit-identical** to a direct
//!   `LutEngine::forward_into` on the same input (the router relays
//!   backend frames verbatim);
//! * killing a replica mid-run loses nothing: every request is answered
//!   via failover or shed with a typed error — never a hang or a panic;
//! * under a pinned fault seed ([`lcquant::util::fault`]) the router's
//!   failover/health-transition counters match the injected fault counts
//!   **exactly** (the fault registry is count-based, so totals are
//!   deterministic regardless of timing);
//! * a slow-loris client (partial frame, no progress) is shed with a
//!   typed `Timeout` error by both the backend server and the router;
//! * `docs/FABRIC.md` names the stats keys and config knobs the code
//!   ships.
//!
//! `ci.sh` and `make tier1` run this file under the default thread policy
//! and again with `LCQUANT_THREADS=2`.
//!
//! The process-global fault registry is shared by every test in this
//! binary, so tests that start routers serialize on [`lock`].

use lcquant::linalg::Mat;
use lcquant::net::loadgen;
use lcquant::net::proto::{
    self, ErrorCode, ErrorFrame, Frame, FrameReader, HelloFrame, RequestFrame,
};
use lcquant::net::{
    ClientError, ClusterConfig, FabricConfig, HealthState, LoadGenConfig, NetClient, NetConfig,
    NetServer, RetryPolicy, RouterConfig, RouterServer, ShardConfig,
};
use lcquant::nn::{Activation, MlpSpec};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{EngineScratch, LutEngine, PackedModel, Registry, ServerConfig};
use lcquant::util::backoff::BackoffCfg;
use lcquant::util::fault::{self, FaultKind, FaultPlan};
use lcquant::util::rng::Rng;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Serialize router-starting tests: the fault registry is process-global,
/// and the exact-count assertions need the only forward traffic to be
/// their own.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn toy_packed(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec {
        sizes: vec![12, 8, 4],
        hidden_activation: Activation::Tanh,
        dropout_keep: vec![],
    };
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn toy_registry() -> (Arc<Registry>, PackedModel) {
    let packed = toy_packed("toy-k4", &Scheme::AdaptiveCodebook { k: 4 }, 11);
    let mut reg = Registry::new();
    reg.insert(packed.clone()).unwrap();
    reg.insert(toy_packed("toy-binary", &Scheme::BinaryScale, 12)).unwrap();
    (Arc::new(reg), packed)
}

/// One backend replica on an ephemeral loopback port.
fn start_backend(reg: Arc<Registry>) -> NetServer {
    let serve = ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(1),
        pipeline_depth: 2,
    };
    let net = NetConfig {
        bind_addr: "127.0.0.1:0".to_string(),
        max_connections: 8,
        ..NetConfig::default()
    };
    NetServer::start(reg, serve, net).expect("bind backend")
}

/// A deterministic router fronting `replicas`: zero backoff, no active
/// prober (health changes only through request traffic), generous
/// deadline.
fn router_over(replicas: &[String]) -> RouterServer {
    RouterServer::start(RouterConfig {
        net: NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 8,
            ..NetConfig::default()
        },
        fabric: FabricConfig {
            shards: vec![ShardConfig { models: Vec::new(), replicas: replicas.to_vec() }],
            retry_budget: 4,
            deadline: Duration::from_secs(30),
            backoff: BackoffCfg::ZERO,
            probe_every: Duration::ZERO,
            connect_timeout: Duration::from_secs(1),
            seed: 7,
        },
    })
    .expect("bind router")
}

fn infer_bit_identical(client: &mut NetClient, engine: &LutEngine, rng: &mut Rng) {
    let mut input = vec![0.0f32; engine.in_dim()];
    rng.fill_normal(&mut input, 0.0, 1.0);
    let got = client.infer("toy-k4", &input).expect("routed infer");
    let mut x = Mat::zeros(1, engine.in_dim());
    x.row_mut(0).copy_from_slice(&input);
    let mut scratch = EngineScratch::new();
    let want = engine.forward_into(&x, &mut scratch).unwrap();
    assert_eq!(got.len(), want.cols);
    for (g, w) in got.iter().zip(&want.data) {
        assert_eq!(g.to_bits(), w.to_bits(), "routed logits must be bit-identical");
    }
}

// ---- 1. plain serving through the router -------------------------------

#[test]
fn routed_roundtrip_bit_identical_with_merged_catalog() {
    let _g = lock();
    fault::clear();
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let b0 = start_backend(Arc::clone(&reg));
    let b1 = start_backend(Arc::clone(&reg));
    let router =
        router_over(&[b0.local_addr().to_string(), b1.local_addr().to_string()]);

    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();
    // the router's hello is the merged backend catalog: both replicas
    // serve the same registry, so the union is the plain catalog
    let models = client.models().unwrap();
    let names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
    assert_eq!(names, vec!["toy-binary", "toy-k4"]);
    for m in &models {
        assert_eq!(m.in_dim, 12);
        assert_eq!(m.out_dim, 4);
    }

    let mut rng = Rng::new(500);
    for _ in 0..16 {
        infer_bit_identical(&mut client, &engine, &mut rng);
    }

    // model-level errors are relayed typed (identical on every replica —
    // no retry, no failover)
    match client.infer("ghost", &[0.0; 12]) {
        Err(ClientError::Remote { code: ErrorCode::UnknownModel, .. }) => {}
        other => panic!("expected UnknownModel through the router, got {other:?}"),
    }
    match client.infer("toy-k4", &[0.0; 3]) {
        Err(ClientError::Remote { code: ErrorCode::WrongDims, .. }) => {}
        other => panic!("expected WrongDims through the router, got {other:?}"),
    }
    // the connection survives typed errors
    infer_bit_identical(&mut client, &engine, &mut rng);

    let snap = router.stats();
    assert_eq!(snap.requests_ok, 17);
    assert_eq!(snap.requests_failed, 2, "ghost + wrong-dims relay as failed");
    assert_eq!(snap.requests_shed, 0);
    assert_eq!(snap.retries, 0, "healthy fabric needs no retries");
    assert_eq!(snap.failovers, 0);
    assert_eq!(snap.health_transitions, 0);
    // the startup probe pass touched both backends
    assert_eq!(snap.probes, 2);
    for b in router.fabric().backends() {
        assert_eq!(b.state(), HealthState::Healthy);
    }
}

// ---- 2. injected faults match router counters exactly ------------------

#[test]
fn injected_fault_counts_match_router_counters_exactly() {
    let _g = lock();
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let b0 = start_backend(Arc::clone(&reg));
    let b1 = start_backend(Arc::clone(&reg));
    // start (and probe) the fabric *before* arming faults, so the
    // injected counts cover exactly the request traffic below
    let router =
        router_over(&[b0.local_addr().to_string(), b1.local_addr().to_string()]);
    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();

    // forced Overloaded on every 4th forward attempt: count-based
    // injection, so the total is exact however the attempts interleave.
    // The rate stays below 1/2 so a retry (the very next forward call)
    // never lands on another injection.
    fault::install(&FaultPlan::new(42).with(FaultKind::Overload, 0.25));

    let n = 60u64;
    let mut rng = Rng::new(900);
    for _ in 0..n {
        // every request must still be answered, bit-identically: the
        // retry budget (4) absorbs every injected shed
        infer_bit_identical(&mut client, &engine, &mut rng);
    }
    let injected = fault::injected(FaultKind::Overload);
    fault::clear();

    // n requests with one retry per injection ⇒ n + injected forward
    // calls, every 4th injected
    assert!(injected >= n / 4, "rate 0.25 over ≥{n} calls, got {injected}");
    let snap = router.stats();
    assert_eq!(snap.requests_ok, n, "every request answered despite injection");
    assert_eq!(snap.requests_shed, 0);
    assert_eq!(snap.requests_failed, 0);
    // each injection costs exactly one retry, and with two replicas the
    // retry always switches backend
    assert_eq!(snap.retries, injected, "retries must match injected faults exactly");
    assert_eq!(snap.failovers, injected, "failovers must match injected faults exactly");
    // the first injection suspects its victim while the rescuer is still
    // healthy (1 transition); every later injection suspects the current
    // healthy replica *and* heals the suspect one (2 transitions)
    assert_eq!(
        snap.health_transitions,
        2 * injected - 1,
        "health transitions must match injected faults exactly"
    );
    // nothing was ever marked Down: overload is a Suspect-grade signal
    for b in router.fabric().backends() {
        assert_ne!(b.state(), HealthState::Down);
    }
}

// ---- 3. killing replicas mid-run ---------------------------------------

#[test]
fn killed_replica_fails_over_then_exhausted_fabric_sheds_typed() {
    let _g = lock();
    fault::clear();
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let b0 = start_backend(Arc::clone(&reg));
    let b1 = start_backend(Arc::clone(&reg));
    let b0_addr = b0.local_addr().to_string();
    let router = router_over(&[b0_addr.clone(), b1.local_addr().to_string()]);
    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();

    let mut rng = Rng::new(77);
    for _ in 0..10 {
        infer_bit_identical(&mut client, &engine, &mut rng);
    }

    // kill replica 0 mid-run: the next request that lands on it fails
    // over; every request still gets a bit-identical answer
    let mut b0 = b0;
    b0.stop();
    for _ in 0..30 {
        infer_bit_identical(&mut client, &engine, &mut rng);
    }
    let snap = router.stats();
    assert_eq!(snap.requests_ok, 40, "no request may be lost to the kill");
    assert_eq!(snap.requests_shed, 0);
    assert_eq!(snap.requests_failed, 0);
    assert!(snap.retries >= 1, "the kill must surface as at least one retry");
    assert!(snap.failovers >= 1, "…and that retry must switch replica");
    let dead = router
        .fabric()
        .backends()
        .iter()
        .find(|b| b.addr() == b0_addr)
        .expect("killed backend in fabric");
    assert_eq!(dead.state(), HealthState::Down, "dead replica must be marked Down");

    // kill the last replica too: the router sheds typed, never hangs
    let mut b1 = b1;
    b1.stop();
    match client.infer("toy-k4", &[0.0; 12]) {
        Err(e) if e.is_overloaded() => {}
        other => panic!("expected typed Overloaded with the fabric down, got {other:?}"),
    }
    assert_eq!(router.stats().requests_shed, 1);
    for b in router.fabric().backends() {
        assert_eq!(b.state(), HealthState::Down);
    }
}

// ---- 4. the loadgen cluster scenario -----------------------------------

#[test]
fn cluster_scenario_kill_and_restart_reports_failover_counters() {
    let _g = lock();
    fault::clear();
    let (reg, _) = toy_registry();
    let b0 = start_backend(Arc::clone(&reg));
    let b1 = start_backend(Arc::clone(&reg));
    let b0_addr = b0.local_addr().to_string();
    // a live prober this time, so the restarted replica rejoins
    let mut router = RouterServer::start(RouterConfig {
        net: NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 8,
            ..NetConfig::default()
        },
        fabric: FabricConfig {
            shards: vec![ShardConfig {
                models: Vec::new(),
                replicas: vec![b0_addr.clone(), b1.local_addr().to_string()],
            }],
            retry_budget: 4,
            deadline: Duration::from_secs(30),
            backoff: BackoffCfg::ZERO,
            probe_every: Duration::from_millis(50),
            connect_timeout: Duration::from_secs(1),
            seed: 3,
        },
    })
    .expect("bind router");

    let victim = Arc::new(Mutex::new(Some(b0)));
    let restarted_slot = Arc::clone(&victim);
    let kill_slot = Arc::clone(&victim);
    let restart_reg = Arc::clone(&reg);
    let restart_addr = b0_addr.clone();

    let mut load = LoadGenConfig::new(&router.local_addr().to_string());
    load.connections = 4;
    load.requests_per_conn = 25;
    load.seed = 5;
    let report = loadgen::run_cluster(
        &ClusterConfig { load, kill_at: Some(20), restart_at: Some(60) },
        move || {
            if let Some(mut s) = kill_slot.lock().unwrap().take() {
                s.stop();
            }
        },
        move || {
            let serve = ServerConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(1),
                pipeline_depth: 2,
            };
            let net = NetConfig {
                bind_addr: restart_addr.clone(),
                max_connections: 8,
                ..NetConfig::default()
            };
            if let Ok(s) = NetServer::start(restart_reg, serve, net) {
                *restarted_slot.lock().unwrap() = Some(s);
            }
        },
    )
    .expect("cluster run");

    assert!(report.killed, "the kill hook must fire at 20 sent requests");
    assert!(report.restarted, "the restart hook must fire at 60 sent requests");
    assert_eq!(report.load.sent, 100);
    assert_eq!(report.load.failed, 0, "every request must be answered or shed typed");
    assert_eq!(report.load.ok + report.load.shed, 100);
    // the wire-fetched counters are the router's own (retries/failovers
    // only move with request traffic, which has ended; health transitions
    // may still tick — the prober heals the restarted replica)
    let snap = router.stats();
    assert_eq!(report.router_retries, Some(snap.retries));
    assert_eq!(report.router_failovers, Some(snap.failovers));
    assert!(snap.health_transitions >= report.router_health_transitions.unwrap());
    assert!(
        snap.retries >= 1 && snap.failovers >= 1,
        "a mid-run kill must surface as failover: {snap:?}"
    );
    router.stop();
    if let Some(mut s) = victim.lock().unwrap().take() {
        s.stop();
    }
}

// ---- 5. slow-loris shedding (server and router) ------------------------

/// Raw-socket handshake helper (from `tests/net.rs`): preamble exchange +
/// hello consumed.
fn raw_handshake(addr: &str) -> (TcpStream, FrameReader) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(&proto::encode_preamble()).unwrap();
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    stream.read_exact(&mut pre).unwrap();
    assert_eq!(proto::decode_preamble(&pre).unwrap(), proto::VERSION);
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Hello(_))) => return (stream, reader),
            Ok(Some(f)) => panic!("expected hello, got {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("handshake failed: {e}"),
        }
    }
}

/// Read frames until the peer closes; returns the last error frame seen.
fn read_error_then_eof(stream: &mut TcpStream, reader: &mut FrameReader) -> Option<ErrorFrame> {
    let mut last = None;
    loop {
        match reader.poll_frame(stream) {
            Ok(Some(Frame::Error(e))) => last = Some(e),
            Ok(Some(f)) => panic!("unexpected frame {f:?}"),
            Ok(None) => continue,
            Err(_) => return last, // closed (or mid-frame EOF)
        }
    }
}

/// Dribble half a request frame at `addr`, then stall: the peer must shed
/// with a typed `Timeout` error and close — not wait forever.
fn assert_slow_loris_shed(addr: &str) {
    let (mut stream, mut reader) = raw_handshake(addr);
    let bytes = Frame::Request(RequestFrame {
        id: 9,
        model: "toy-k4".to_string(),
        rows: 1,
        cols: 12,
        data: vec![0.0; 12],
        trace: None,
    })
    .to_bytes();
    stream.write_all(&bytes[..bytes.len() / 2]).unwrap();
    // no further bytes: the frame-progress deadline (100ms here) fires
    let err = read_error_then_eof(&mut stream, &mut reader)
        .expect("peer must report before closing");
    assert_eq!(err.code, ErrorCode::Timeout);
}

#[test]
fn slow_loris_is_shed_with_typed_timeout_by_server_and_router() {
    let _g = lock();
    fault::clear();
    let (reg, _) = toy_registry();
    let serve = ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(1),
        pipeline_depth: 2,
    };
    let server = NetServer::start(
        Arc::clone(&reg),
        serve,
        NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 8,
            frame_deadline: Duration::from_millis(100),
            ..NetConfig::default()
        },
    )
    .unwrap();
    assert_slow_loris_shed(&server.local_addr().to_string());
    assert_eq!(server.stats().frame_timeouts, 1);

    // the router's client side applies the same per-frame deadline
    let router = RouterServer::start(RouterConfig {
        net: NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 8,
            frame_deadline: Duration::from_millis(100),
            ..NetConfig::default()
        },
        fabric: FabricConfig {
            shards: vec![ShardConfig {
                models: Vec::new(),
                replicas: vec![server.local_addr().to_string()],
            }],
            probe_every: Duration::ZERO,
            ..FabricConfig::default()
        },
    })
    .unwrap();
    assert_slow_loris_shed(&router.local_addr().to_string());
    assert_eq!(router.stats().frame_timeouts, 1);

    // an interrupted frame does not poison the listener: a fresh client
    // still round-trips
    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
}

// ---- 6. the client retry budget ----------------------------------------

/// A scripted LCQ-RPC server: completes the handshake on every accepted
/// connection, drops the first `flaky` connections right after hello, and
/// answers one request on the next connection with a typed `Internal`
/// error carrying `marker`.
fn scripted_server(flaky: usize, marker: &'static str) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        for i in 0..=flaky {
            let (mut stream, _) = listener.accept().unwrap();
            let mut pre = [0u8; proto::PREAMBLE_LEN];
            stream.read_exact(&mut pre).unwrap();
            stream.write_all(&proto::encode_preamble()).unwrap();
            stream
                .write_all(&Frame::Hello(HelloFrame { models: vec![] }).to_bytes())
                .unwrap();
            if i < flaky {
                continue; // drop right after the handshake
            }
            // answer exactly one request, typed
            let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
            loop {
                match reader.poll_frame(&mut stream) {
                    Ok(Some(Frame::Request(req))) => {
                        proto::write_frame(
                            &mut stream,
                            &Frame::Error(ErrorFrame {
                                id: req.id,
                                code: ErrorCode::Internal,
                                message: marker.to_string(),
                            }),
                        )
                        .unwrap();
                        break;
                    }
                    Ok(Some(f)) => panic!("unexpected frame {f:?}"),
                    Ok(None) => continue,
                    Err(e) => panic!("scripted server read: {e}"),
                }
            }
        }
    });
    (addr, handle)
}

#[test]
fn client_retry_budget_governs_transparent_reconnect() {
    let _g = lock();
    fault::clear();
    // default policy (2 attempts): the dropped connection is retried
    // transparently, and the second connection's typed answer surfaces
    let (addr, handle) = scripted_server(1, "answered on the retry");
    let mut client = NetClient::connect(&addr).unwrap();
    match client.infer("toy-k4", &[0.0; 12]) {
        Err(ClientError::Remote { code: ErrorCode::Internal, message }) => {
            assert_eq!(message, "answered on the retry");
        }
        other => panic!("expected the retried connection's answer, got {other:?}"),
    }
    handle.join().unwrap();

    // attempts = 1 disables the reconnect: the same drop surfaces as Io
    let (addr, handle) = scripted_server(1, "never reached");
    let mut client = NetClient::connect_with(
        &addr,
        RetryPolicy { attempts: 1, backoff: BackoffCfg::ZERO, seed: 0 },
    )
    .unwrap();
    match client.infer("toy-k4", &[0.0; 12]) {
        Err(ClientError::Io(_)) => {}
        other => panic!("expected a surfaced Io error with attempts=1, got {other:?}"),
    }
    // the next call dials fresh and reaches the scripted answer, so the
    // server thread can finish
    match client.infer("toy-k4", &[0.0; 12]) {
        Err(ClientError::Remote { code: ErrorCode::Internal, .. }) => {}
        other => panic!("expected the fresh connection's answer, got {other:?}"),
    }
    handle.join().unwrap();

    // a deeper budget absorbs repeated drops in one call
    let (addr, handle) = scripted_server(3, "answered on the third retry");
    let mut client = NetClient::connect_with(
        &addr,
        RetryPolicy { attempts: 4, backoff: BackoffCfg::ZERO, seed: 0 },
    )
    .unwrap();
    match client.infer("toy-k4", &[0.0; 12]) {
        Err(ClientError::Remote { code: ErrorCode::Internal, message }) => {
            assert_eq!(message, "answered on the third retry");
        }
        other => panic!("expected the third retry's answer, got {other:?}"),
    }
    handle.join().unwrap();
}

// ---- 7. the docs name what the code ships ------------------------------

fn doc(path: &str) -> String {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(path);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

#[test]
fn fabric_doc_names_states_faults_stats_and_config_keys() {
    let text = doc("docs/FABRIC.md");
    for s in [HealthState::Healthy, HealthState::Suspect, HealthState::Down] {
        assert!(text.contains(s.name()), "FABRIC.md missing health state '{}'", s.name());
    }
    for k in FaultKind::ALL {
        assert!(text.contains(k.name()), "FABRIC.md missing fault kind '{}'", k.name());
    }
    // the router snapshot keys wire clients (and run_cluster) depend on
    for key in [
        "router",
        "backends",
        "requests_ok",
        "requests_failed",
        "requests_shed",
        "retries",
        "failovers",
        "health_transitions",
        "probes",
        "frame_timeouts",
    ] {
        assert!(text.contains(key), "FABRIC.md missing snapshot key '{key}'");
    }
    // the `serve.fabric` config knobs
    for key in [
        "shards",
        "models",
        "replicas",
        "retry_budget",
        "deadline_ms",
        "backoff_base_ms",
        "backoff_cap_ms",
        "probe_every_ms",
        "connect_timeout_ms",
    ] {
        assert!(text.contains(key), "FABRIC.md missing config key '{key}'");
    }
}
