//! Adversarial C10K suite for the event-driven connection plane (PR 9):
//! real TCP on 127.0.0.1, a pipelined `NetClient` (and raw sockets where
//! determinism demands a single write), thousands of mostly-idle
//! connections, and seeded fault schedules from [`lcquant::util::fault`].
//!
//! The load-bearing assertions:
//!
//! * a client holding `window` request ids in flight gets **every** slot
//!   answered bit-identically to a direct `LutEngine` forward — replies
//!   are matched by id, so out-of-order completion is safe;
//! * the per-connection pipeline bound sheds excess requests with a
//!   typed `Overloaded` error *per request id* and the connection
//!   survives — never a hang, never a dropped id;
//! * under a pinned fault seed the router's retry/failover counters
//!   reconcile with the injected fault totals **exactly** (the fault
//!   registry is count-based, so totals are deterministic regardless of
//!   interleaving): with suspect-grade faults,
//!   `injected == retries + requests_shed` and `failovers == retries`;
//!   with down-grade faults (conn drop / corrupt) every injection is
//!   accounted for by a retry or a shed — `retries <= injected <=
//!   retries + requests_shed` — and every request is still answered
//!   bit-identically or shed typed;
//! * the open-loop scenarios ([`loadgen::run_poisson`],
//!   [`loadgen::run_idle_army`], [`loadgen::run_slow_loris`]) report
//!   exact shed-vs-answered counts under a fixed seed, including a
//!   1000-connection idle army multiplexed onto two net threads (gated
//!   behind an `RLIMIT_NOFILE` check that skips cleanly — it never
//!   flakes on a small fd budget);
//! * `docs/wire-protocol.md` and `docs/ARCHITECTURE.md` name the
//!   pipelining contract and the event plane this suite pins.
//!
//! `ci.sh` and `make tier1` run this file under the default thread
//! policy and again with `LCQUANT_THREADS=2` (`make smoke-c10k`).
//!
//! The process-global fault registry is shared by every test in this
//! binary, so tests that install plans or forward through a router
//! serialize on [`lock`].

use lcquant::linalg::Mat;
use lcquant::net::loadgen;
use lcquant::net::proto::{self, ErrorCode, Frame, FrameReader, RequestFrame};
use lcquant::net::{
    FabricConfig, IdleArmyConfig, NetClient, NetConfig, NetServer, PoissonConfig, RouterConfig,
    RouterServer, ShardConfig, SlowLorisConfig,
};
use lcquant::nn::{Activation, MlpSpec};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{EngineScratch, LutEngine, PackedModel, Registry, ServerConfig};
use lcquant::util::backoff::BackoffCfg;
use lcquant::util::fault::{self, FaultKind, FaultPlan, FaultStream};
use lcquant::util::rng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Serialize fault-installing and router-forwarding tests: the fault
/// registry is process-global, and the exact-count assertions need the
/// only injected traffic to be their own.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn toy_packed(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec {
        sizes: vec![12, 8, 4],
        hidden_activation: Activation::Tanh,
        dropout_keep: vec![],
    };
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn toy_registry() -> (Arc<Registry>, PackedModel) {
    let packed = toy_packed("toy-k4", &Scheme::AdaptiveCodebook { k: 4 }, 11);
    let mut reg = Registry::new();
    reg.insert(packed.clone()).unwrap();
    (Arc::new(reg), packed)
}

fn serve_cfg() -> ServerConfig {
    ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(1),
        pipeline_depth: 2,
    }
}

/// A server on an ephemeral loopback port with the given net knobs.
fn start_server(reg: Arc<Registry>, net: NetConfig) -> NetServer {
    NetServer::start(reg, serve_cfg(), net).expect("bind server")
}

fn loopback_net() -> NetConfig {
    NetConfig {
        bind_addr: "127.0.0.1:0".to_string(),
        max_connections: 8,
        ..NetConfig::default()
    }
}

/// A deterministic router fronting `replicas`: zero backoff, no active
/// prober (health changes only through request traffic), generous
/// deadline, and a pipeline bound wide enough that the fault tests
/// exercise the fabric, not the write queue.
fn router_over(replicas: &[String], net: NetConfig) -> RouterServer {
    RouterServer::start(RouterConfig {
        net,
        fabric: FabricConfig {
            shards: vec![ShardConfig { models: Vec::new(), replicas: replicas.to_vec() }],
            retry_budget: 4,
            deadline: Duration::from_secs(30),
            backoff: BackoffCfg::ZERO,
            probe_every: Duration::ZERO,
            connect_timeout: Duration::from_secs(1),
            seed: 7,
        },
    })
    .expect("bind router")
}

fn router_net() -> NetConfig {
    NetConfig {
        bind_addr: "127.0.0.1:0".to_string(),
        max_connections: 8,
        max_inflight: 32,
        ..NetConfig::default()
    }
}

fn expected_bits(engine: &LutEngine, input: &[f32]) -> Vec<u32> {
    let mut x = Mat::zeros(1, engine.in_dim());
    x.row_mut(0).copy_from_slice(input);
    let mut scratch = EngineScratch::new();
    let out = engine.forward_into(&x, &mut scratch).unwrap();
    out.data.iter().map(|v| v.to_bits()).collect()
}

/// Drive `total` distinct single-row requests through `infer_pipelined`
/// with `window` ids in flight, asserting every slot is answered
/// bit-identically or shed with a typed `Overloaded` error. Returns
/// `(ok, shed)`.
fn drive_pipelined_matrix(
    client: &mut NetClient,
    engine: &LutEngine,
    rng: &mut Rng,
    total: usize,
    window: usize,
) -> (usize, usize) {
    let in_dim = engine.in_dim();
    let (mut ok, mut shed) = (0usize, 0usize);
    let mut issued = 0usize;
    while issued < total {
        let w = window.min(total - issued);
        let mut inputs = vec![0.0f32; in_dim * w];
        rng.fill_normal(&mut inputs, 0.0, 1.0);
        let rows: Vec<&[f32]> = inputs.chunks(in_dim).collect();
        let results = client.infer_pipelined("toy-k4", &rows, w);
        assert_eq!(results.len(), w, "one result per submitted row");
        for (slot, result) in results.into_iter().enumerate() {
            match result {
                Ok(got) => {
                    let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        got_bits,
                        expected_bits(engine, rows[slot]),
                        "pipelined slot {slot} must be bit-identical",
                    );
                    ok += 1;
                }
                Err(e) => {
                    assert!(e.is_overloaded(), "slot {slot}: non-overload error {e:?}");
                    shed += 1;
                }
            }
        }
        issued += w;
    }
    (ok, shed)
}

/// Raw-socket handshake: client preamble out, server preamble + hello
/// consumed. Generic over the stream so [`FaultStream`] wraps it too.
fn raw_handshake<S: Read + Write>(stream: &mut S) -> FrameReader {
    stream.write_all(&proto::encode_preamble()).unwrap();
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    stream.read_exact(&mut pre).unwrap();
    assert_eq!(proto::decode_preamble(&pre).unwrap(), proto::VERSION);
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    loop {
        match reader.poll_frame(stream) {
            Ok(Some(Frame::Hello(_))) => return reader,
            Ok(Some(f)) => panic!("expected hello, got {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("handshake failed: {e}"),
        }
    }
}

fn request_frame(id: u64, input: &[f32]) -> Vec<u8> {
    Frame::Request(RequestFrame {
        id,
        model: "toy-k4".to_string(),
        rows: 1,
        cols: input.len() as u32,
        data: input.to_vec(),
        trace: None,
    })
    .to_bytes()
}

// ---- 1. pipelined round trips are out-of-order-safe --------------------

#[test]
fn pipelined_window_answers_every_slot_bit_identically() {
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig { max_inflight: 32, ..loopback_net() },
    );
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    let mut rng = Rng::new(901);
    // distinct inputs per slot: a response matched to the wrong id would
    // fail the bit-identity check, so this pins id matching, not just
    // transport health
    let (ok, shed) = drive_pipelined_matrix(&mut client, &engine, &mut rng, 32, 8);
    assert_eq!((ok, shed), (32, 0));
    let snap = server.stats();
    assert_eq!(snap.requests_ok, 32);
    assert_eq!(snap.requests_shed, 0);
    assert_eq!(snap.requests_failed, 0);
    assert_eq!(snap.writeq_sheds, 0);
    assert_eq!(snap.frame_timeouts, 0);
}

// ---- 2. the pipeline bound sheds typed, per id, and survives -----------

#[test]
fn pipeline_bound_sheds_excess_ids_typed_and_connection_survives() {
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig { max_inflight: 2, ..loopback_net() },
    );
    let mut rng = Rng::new(902);
    let total = 16usize;
    let in_dim = engine.in_dim();
    let mut inputs = vec![0.0f32; in_dim * total];
    rng.fill_normal(&mut inputs, 0.0, 1.0);
    let rows: Vec<&[f32]> = inputs.chunks(in_dim).collect();

    // one write_all of all 16 request frames (~1.5 KiB, a single
    // loopback segment) so the server decodes them in one readable
    // batch — the bound must trip, deterministically, before the first
    // micro-batch completion can drain the pipeline
    let mut burst = Vec::new();
    for (i, row) in rows.iter().enumerate() {
        burst.extend_from_slice(&request_frame(i as u64 + 1, row));
    }
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut reader = raw_handshake(&mut stream);
    stream.write_all(&burst).unwrap();

    // collect one reply per id; shed errors for later ids are enqueued
    // before the first responses complete, so replies arrive out of
    // request order — id matching is what keeps the books straight
    let mut outcomes: Vec<Option<Result<Vec<u32>, ErrorCode>>> = vec![None; total];
    let mut seen = 0usize;
    while seen < total {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Response(r))) => {
                let slot = (r.id - 1) as usize;
                assert!(outcomes[slot].is_none(), "duplicate reply for id {}", r.id);
                outcomes[slot] = Some(Ok(r.data.iter().map(|v| v.to_bits()).collect()));
                seen += 1;
            }
            Ok(Some(Frame::Error(e))) => {
                assert_ne!(e.id, 0, "unexpected connection-level error: {e:?}");
                let slot = (e.id - 1) as usize;
                assert!(outcomes[slot].is_none(), "duplicate reply for id {}", e.id);
                outcomes[slot] = Some(Err(e.code));
                seen += 1;
            }
            Ok(Some(f)) => panic!("unexpected frame {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("wire error mid-burst: {e}"),
        }
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    for (slot, outcome) in outcomes.iter().enumerate() {
        match outcome.as_ref().expect("every id answered") {
            Ok(bits) => {
                assert_eq!(bits, &expected_bits(&engine, rows[slot]), "slot {slot}");
                ok += 1;
            }
            Err(code) => {
                assert_eq!(*code, ErrorCode::Overloaded, "slot {slot} shed must be typed");
                shed += 1;
            }
        }
    }
    // the first two ids always fit under max_inflight = 2; the rest of
    // the burst lands while they are still in compute, so at least one
    // later id must hit the bound
    assert!(outcomes[0].as_ref().unwrap().is_ok(), "id 1 fits under the bound");
    assert!(outcomes[1].as_ref().unwrap().is_ok(), "id 2 fits under the bound");
    assert!(shed >= 1, "a 16-id burst against max_inflight=2 must shed");
    assert_eq!(ok + shed, total);

    let snap = server.stats();
    assert_eq!(snap.requests_ok, ok as u64);
    assert_eq!(snap.requests_shed, shed as u64);
    assert_eq!(snap.writeq_sheds, shed as u64, "every shed here is a pipeline-bound shed");
    assert_eq!(snap.requests_failed, 0);

    // the connection survives its sheds: a lockstep request still works
    let follow = request_frame(17, rows[0]);
    stream.write_all(&follow).unwrap();
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Response(r))) => {
                assert_eq!(r.id, 17);
                let bits: Vec<u32> = r.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, expected_bits(&engine, rows[0]));
                break;
            }
            Ok(Some(f)) => panic!("unexpected frame {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("wire error on follow-up: {e}"),
        }
    }
}

// ---- 3. fault matrix: suspect-grade faults reconcile exactly -----------

#[test]
fn pipelined_overload_schedule_reconciles_with_retry_counters_exactly() {
    let _g = lock();
    fault::clear();
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let b0 = start_server(Arc::clone(&reg), loopback_net());
    let b1 = start_server(Arc::clone(&reg), loopback_net());
    let router = router_over(
        &[b0.local_addr().to_string(), b1.local_addr().to_string()],
        router_net(),
    );
    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();
    fault::install(&FaultPlan::new(0xFEED).with(FaultKind::Overload, 0.25));

    let mut rng = Rng::new(903);
    let total = 64usize;
    let (ok, shed) = drive_pipelined_matrix(&mut client, &engine, &mut rng, total, 8);
    let injected = fault::injected(FaultKind::Overload);
    fault::clear();

    assert_eq!(ok + shed, total, "every id answered or shed — never lost");
    let snap = router.stats();
    assert_eq!(snap.requests_ok, ok as u64);
    assert_eq!(snap.requests_shed, shed as u64);
    assert_eq!(snap.requests_failed, 0);
    // count-based injection makes the totals deterministic under ANY
    // worker interleaving: an injected overload either triggers a retry
    // (suspect-grade — the replica is never marked down, so the retry
    // always has somewhere to go) or, on a request's last budgeted
    // attempt, becomes a typed shed. Nothing else retries or sheds.
    assert_eq!(
        injected,
        snap.retries + snap.requests_shed,
        "every injected overload is a retry or a shed",
    );
    // with two live replicas the picker always avoids the one that just
    // failed, so every retry is a failover
    assert_eq!(snap.failovers, snap.retries);
    // 64 requests guarantee >= 64 forward attempts at rate 0.25
    assert!(injected >= 16, "schedule must actually fire (got {injected})");
}

// ---- 4. fault matrix: down-grade faults (conn drop + corrupt) ----------

#[test]
fn pipelined_conn_drop_corrupt_schedule_never_hangs_and_books_balance() {
    let _g = lock();
    fault::clear();
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let b0 = start_server(Arc::clone(&reg), loopback_net());
    let b1 = start_server(Arc::clone(&reg), loopback_net());
    let router = router_over(
        &[b0.local_addr().to_string(), b1.local_addr().to_string()],
        router_net(),
    );
    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();
    fault::install(
        &FaultPlan::new(0xD00F)
            .with(FaultKind::ConnDrop, 0.1)
            .with(FaultKind::Corrupt, 0.1),
    );

    // both kinds are down-grade: a drop fails the dial, a corrupt
    // request makes the backend answer Malformed and the router treats
    // the connection as poisoned. With no prober configured, each
    // firing downs one replica for good — after two firings the fabric
    // is exhausted and everything left sheds typed. The suite's bar:
    // every id still gets an answer or a typed shed, and the books
    // still reconcile with the injected totals.
    let mut rng = Rng::new(904);
    let total = 64usize;
    let (ok, shed) = drive_pipelined_matrix(&mut client, &engine, &mut rng, total, 8);
    let injected = fault::injected(FaultKind::ConnDrop) + fault::injected(FaultKind::Corrupt);
    fault::clear();

    assert_eq!(ok + shed, total, "every id answered or shed — never lost");
    let snap = router.stats();
    assert_eq!(snap.requests_ok, ok as u64);
    assert_eq!(snap.requests_shed, shed as u64);
    assert_eq!(snap.requests_failed, 0);
    // every retry is caused by exactly one injection; an injection on a
    // request's last budgeted attempt sheds instead of retrying, and
    // fabric-exhausted requests shed without a preceding injection — so
    // the tallies sandwich exactly:
    assert!(snap.retries <= injected, "retries {} > injected {injected}", snap.retries);
    assert!(
        injected <= snap.retries + snap.requests_shed,
        "injected {injected} unaccounted for ({} retries, {} sheds)",
        snap.retries,
        snap.requests_shed,
    );
    // 64 requests give the two firings needed to exhaust both replicas
    assert!(injected >= 2, "schedule must down both replicas (got {injected})");
    assert!(snap.requests_shed >= 1, "an exhausted fabric must shed");
    assert!(snap.health_transitions >= 2, "both replicas must transition to down");

    // post-collapse the client still gets typed sheds, never a hang or
    // a transport error
    let mut input = vec![0.0f32; engine.in_dim()];
    rng.fill_normal(&mut input, 0.0, 1.0);
    match client.infer("toy-k4", &input) {
        Ok(_) => panic!("fabric is exhausted; an answer means health leaked"),
        Err(e) => assert!(e.is_overloaded(), "post-collapse error must be typed: {e:?}"),
    }
}

// ---- 5. stalled client streams (read/write stall schedule) -------------

#[test]
fn stalled_client_stream_round_trips_bit_identically() {
    let _g = lock();
    fault::clear();
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig { max_inflight: 32, ..loopback_net() },
    );
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    // every read and write through the wrapper stalls 2 ms: a client
    // this slow dribbles request bytes across many server poll ticks,
    // but keeps making progress — the frame deadline must not fire
    fault::install(
        &FaultPlan::new(0x51A1)
            .with(FaultKind::ReadStall, 1.0)
            .with(FaultKind::WriteStall, 1.0)
            .stall(Duration::from_millis(2)),
    );
    let mut fs = FaultStream::new(stream);
    let mut reader = raw_handshake(&mut fs);
    let mut rng = Rng::new(905);
    let in_dim = engine.in_dim();
    let total = 8usize;
    let mut inputs = vec![0.0f32; in_dim * total];
    rng.fill_normal(&mut inputs, 0.0, 1.0);
    let rows: Vec<&[f32]> = inputs.chunks(in_dim).collect();
    for (i, row) in rows.iter().enumerate() {
        fs.write_all(&request_frame(i as u64 + 1, row)).unwrap();
    }
    let mut seen = 0usize;
    while seen < total {
        match reader.poll_frame(&mut fs) {
            Ok(Some(Frame::Response(r))) => {
                let slot = (r.id - 1) as usize;
                let bits: Vec<u32> = r.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, expected_bits(&engine, rows[slot]), "slot {slot}");
                seen += 1;
            }
            Ok(Some(f)) => panic!("unexpected frame {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("wire error under stall schedule: {e}"),
        }
    }
    let stalls = fault::injected(FaultKind::ReadStall) + fault::injected(FaultKind::WriteStall);
    fault::clear();
    assert!(stalls >= total as u64, "rate-1.0 stalls must fire every call (got {stalls})");
    let snap = server.stats();
    assert_eq!(snap.requests_ok, total as u64);
    assert_eq!(snap.frame_timeouts, 0, "a slow-but-progressing client is not a loris");
}

// ---- 6. open-loop Poisson bursts: exact counts under a fixed seed ------

#[test]
fn poisson_open_loop_counts_are_exact() {
    let (reg, _) = toy_registry();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig { max_connections: 16, ..loopback_net() },
    );
    let cfg = PoissonConfig::new(&server.local_addr().to_string());
    let report = loadgen::run_poisson(&cfg).expect("poisson run");
    // arrival *times* are random; offered *counts* are not
    let want = cfg.load.connections * cfg.bursts * cfg.load.pipeline;
    assert_eq!(report.sent, want);
    assert_eq!(report.ok, want, "an unloaded server answers every burst");
    assert_eq!(report.shed, 0);
    assert_eq!(report.failed, 0);
    let snap = server.stats();
    assert_eq!(snap.requests_ok, want as u64);
    assert_eq!(snap.requests_shed, 0);
    assert_eq!(snap.frame_timeouts, 0);
}

// ---- 7. slow-loris army: typed Timeout on server and router ------------

#[test]
fn slow_loris_army_is_shed_typed_by_server_and_router() {
    let (reg, _) = toy_registry();
    // 6 bytes x 10 ms = 60 ms of trickle, then a stall; the 300 ms
    // frame deadline anchors at the FIRST partial byte and progress
    // never resets it, so the verdict lands deterministically after
    // the trickle has already finished — no write-vs-close race
    let deadline = Duration::from_millis(300);
    let server = start_server(
        Arc::clone(&reg),
        NetConfig { frame_deadline: deadline, ..loopback_net() },
    );
    let report =
        loadgen::run_slow_loris(&SlowLorisConfig::new(&server.local_addr().to_string()))
            .expect("loris run vs server");
    assert_eq!(report.timed_out, report.connections, "every loris gets a typed Timeout");
    assert_eq!(report.closed_unanswered, 0);
    assert_eq!(report.failed, 0, "a hung loris means the deadline scanner is broken");
    let snap = server.stats();
    assert_eq!(snap.frame_timeouts, report.connections as u64);
    assert_eq!(snap.requests_ok, 0, "a loris never completes a request");

    // the router's front plane is the same event plane: same verdict,
    // and the backends behind it never see a single frame
    let backend = start_server(Arc::clone(&reg), loopback_net());
    let router = router_over(
        &[backend.local_addr().to_string()],
        NetConfig { frame_deadline: deadline, ..router_net() },
    );
    let report =
        loadgen::run_slow_loris(&SlowLorisConfig::new(&router.local_addr().to_string()))
            .expect("loris run vs router");
    assert_eq!(report.timed_out, report.connections);
    assert_eq!(report.closed_unanswered, 0);
    assert_eq!(report.failed, 0);
    assert_eq!(router.stats().frame_timeouts, report.connections as u64);
    assert_eq!(backend.stats().frame_timeouts, 0);
    assert_eq!(backend.stats().requests_ok, 0);
}

// ---- 8. idle army: camped herd + live traffic on two net threads -------

#[test]
fn idle_army_camps_while_active_traffic_is_served() {
    let (reg, _) = toy_registry();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig { max_connections: 96, ..loopback_net() },
    );
    let cfg = IdleArmyConfig::new(&server.local_addr().to_string());
    let report = loadgen::run_idle_army(&cfg).expect("idle army run");
    assert_eq!(report.idle_held, cfg.connections, "the whole herd must camp");
    assert_eq!(report.idle_refused, 0);
    assert_eq!(report.idle_failed, 0);
    let want = cfg.active * cfg.requests_per_active;
    assert_eq!(report.sent, want);
    assert_eq!(report.ok, want, "a camped herd must not starve live traffic");
    assert_eq!(report.shed, 0);
    assert_eq!(report.failed, 0);
    assert_eq!(server.stats().requests_ok, want as u64);
}

/// Soft `RLIMIT_NOFILE` from `/proc/self/limits`; `None` when the file
/// is absent or unparseable (non-Linux), which the gated test treats as
/// "skip cleanly".
fn nofile_soft_limit() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/limits").ok()?;
    for line in text.lines() {
        if line.starts_with("Max open files") {
            let soft = line.split_whitespace().nth(3)?;
            if soft == "unlimited" {
                return Some(u64::MAX);
            }
            return soft.parse().ok();
        }
    }
    None
}

// ---- 9. C10K: a 1000-connection army on a fixed net-thread pool --------

#[test]
fn c10k_thousand_idle_connections_on_two_net_threads() {
    // both socket ends live in this process, so the fd bill is roughly
    // 2x the herd plus listener/client/pool overhead
    let herd = 1000usize;
    let need = (2 * herd + 256) as u64;
    match nofile_soft_limit() {
        Some(limit) if limit >= need => {}
        other => {
            eprintln!(
                "skipping c10k idle army: RLIMIT_NOFILE soft limit {:?} < {} needed",
                other, need
            );
            return;
        }
    }
    let (reg, _) = toy_registry();
    let army = |addr: &str| IdleArmyConfig {
        connections: herd,
        handshake_timeout: Duration::from_secs(10),
        ..IdleArmyConfig::new(addr)
    };

    // the epoll server: 1000 camped sockets + live traffic on the
    // default two net threads — the fixed pool is the point
    let server = start_server(
        Arc::clone(&reg),
        NetConfig { max_connections: 1100, net_threads: 2, ..loopback_net() },
    );
    let cfg = army(&server.local_addr().to_string());
    let report = loadgen::run_idle_army(&cfg).expect("c10k army vs server");
    assert_eq!(report.idle_held, herd, "server must hold the full herd");
    assert_eq!(report.idle_refused, 0);
    assert_eq!(report.idle_failed, 0);
    let want = cfg.active * cfg.requests_per_active;
    assert_eq!(report.ok, want, "live traffic must not starve behind the herd");
    assert_eq!(report.shed, 0);
    assert_eq!(report.failed, 0);
    drop(server);

    // the router tier runs the same plane: same herd, same verdict
    let backend = start_server(Arc::clone(&reg), loopback_net());
    let router = router_over(
        &[backend.local_addr().to_string()],
        NetConfig { max_connections: 1100, net_threads: 2, ..router_net() },
    );
    let cfg = army(&router.local_addr().to_string());
    let report = loadgen::run_idle_army(&cfg).expect("c10k army vs router");
    assert_eq!(report.idle_held, herd, "router must hold the full herd");
    assert_eq!(report.idle_refused, 0);
    assert_eq!(report.idle_failed, 0);
    assert_eq!(report.ok, want);
    assert_eq!(report.shed, 0);
    assert_eq!(report.failed, 0);
}

// ---- 10. docs name what this suite pins --------------------------------

fn doc(path: &str) -> String {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(path);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

#[test]
fn docs_name_the_event_plane_and_the_pipelining_contract() {
    let wire = doc("docs/wire-protocol.md");
    for needle in ["Pipelining", "max_inflight", "submission order", "Overloaded"] {
        assert!(wire.contains(needle), "wire-protocol.md must mention {needle:?}");
    }
    let arch = doc("docs/ARCHITECTURE.md");
    for needle in ["epoll", "net thread", "acceptor", "frame_deadline"] {
        assert!(arch.contains(needle), "ARCHITECTURE.md must mention {needle:?}");
    }
    let obs = doc("docs/OBSERVABILITY.md");
    for needle in ["net_epoll_wakeups", "net_writeq_sheds", "net_inflight"] {
        assert!(obs.contains(needle), "OBSERVABILITY.md must mention {needle:?}");
    }
}
