//! Stress and policy tests for the persistent worker pool
//! (`linalg::pool`) — the threading substrate of the whole compute plane.
//!
//! Covers: the multi-task queue (concurrent two-task dispatch from scoped
//! threads, task-slot exhaustion falling back inline without deadlock,
//! panic isolation between concurrent tasks), nested/reentrant dispatch
//! (from the dispatcher thread and from inside worker-run parts), the
//! 1-thread degenerate case, concurrent dispatchers hammering one pool
//! from many threads, `LCQUANT_THREADS` clamping policy, band partitioning
//! edge shapes, and end-to-end parity of the pool-dispatched gemm/serve
//! kernels against their serial paths.
//!
//! This binary pins `LCQUANT_THREADS=3` (before anything resolves the
//! cached thread count) so the *global* pool genuinely fans out; private
//! `Pool::new(n)` instances cover the other widths in-process.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use lcquant::linalg::pool::{self, DisjointMut, Pool, TASK_SLOTS};
use lcquant::linalg::{gemm, resolve_threads, Mat};
use lcquant::util::rng::Rng;

/// Pin the global thread policy for this test binary; every test calls
/// this before touching linalg.
fn pin_threads() {
    static PIN: std::sync::Once = std::sync::Once::new();
    PIN.call_once(|| std::env::set_var("LCQUANT_THREADS", "3"));
}

#[test]
fn resolve_threads_clamps_and_falls_back() {
    pin_threads();
    // parseable values clamp to 1..=16
    assert_eq!(resolve_threads(Some("4")), 4);
    assert_eq!(resolve_threads(Some(" 7 ")), 7);
    assert_eq!(resolve_threads(Some("0")), 1);
    assert_eq!(resolve_threads(Some("1")), 1);
    assert_eq!(resolve_threads(Some("16")), 16);
    assert_eq!(resolve_threads(Some("64")), 16);
    assert_eq!(resolve_threads(Some("9999999")), 16);
    // garbage and absence fall back to available_parallelism (≥ 1, ≤ 16)
    for env in [None, Some(""), Some("abc"), Some("-3"), Some("2.5")] {
        let n = resolve_threads(env);
        assert!((1..=16).contains(&n), "{env:?} -> {n}");
    }
    assert_eq!(resolve_threads(None), resolve_threads(Some("junk")));
}

#[test]
fn global_pool_width_matches_pinned_policy() {
    pin_threads();
    assert_eq!(lcquant::linalg::num_threads(), 3);
    assert_eq!(pool::global().width(), 3);
}

#[test]
fn deeply_nested_dispatch_terminates_and_covers_all_parts() {
    pin_threads();
    // three levels of nesting: outer parts run pooled, inner levels
    // degrade to inline — the count must still be exact
    let count = AtomicUsize::new(0);
    pool::run(4, |_| {
        pool::run(3, |_| {
            pool::run(2, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
    });
    assert_eq!(count.load(Ordering::Relaxed), 4 * 3 * 2);
}

#[test]
fn concurrent_dispatchers_from_scoped_threads() {
    pin_threads();
    // several OS threads race dispatches into one pool: each takes its own
    // task slot (or, if the ring ever fills, runs inline), and every part
    // of every dispatch still runs exactly once
    let pool = Pool::new(4);
    let hits: Vec<AtomicUsize> = (0..8 * 100).map(|_| AtomicUsize::new(0)).collect();
    std::thread::scope(|s| {
        for t in 0..8usize {
            let pool = &pool;
            let hits = &hits;
            s.spawn(move || {
                for round in 0..10usize {
                    pool.run(10, |p| {
                        hits[t * 100 + round * 10 + p].fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }
    });
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(h.load(Ordering::Relaxed), 1, "slot {i}");
    }
}

#[test]
fn one_thread_pool_is_sequential_and_ordered() {
    pin_threads();
    let pool = Pool::new(1);
    assert_eq!(pool.width(), 1);
    let order = Mutex::new(Vec::new());
    pool.run(16, |p| order.lock().unwrap().push(p));
    assert_eq!(*order.lock().unwrap(), (0..16).collect::<Vec<_>>());
    // run_bands with a 1-thread pool covers all rows serially
    let mut out = vec![0.0f32; 9 * 3];
    pool.run_bands(9, 3, &mut out, |rows, band| {
        for (local, r) in rows.enumerate() {
            band[local * 3..(local + 1) * 3].fill(r as f32);
        }
    });
    for r in 0..9 {
        assert!(out[r * 3..(r + 1) * 3].iter().all(|&v| v == r as f32));
    }
}

#[test]
fn wide_pool_with_few_rows_leaves_no_row_unwritten() {
    pin_threads();
    let pool = Pool::new(8);
    for (m, n) in [(1usize, 4usize), (2, 1), (5, 3), (7, 0), (8, 2), (9, 2), (63, 7)] {
        let mut out = vec![f32::NAN; m * n];
        pool.run_bands(m, n, &mut out, |rows, band| {
            assert_eq!(band.len(), rows.len() * n);
            for v in band.iter_mut() {
                *v = 1.0;
            }
        });
        assert!(out.iter().all(|v| *v == 1.0), "m={m} n={n}");
    }
}

#[test]
fn disjoint_mut_parts_land_in_the_right_slots() {
    pin_threads();
    let pool = Pool::new(4);
    let mut slots = vec![0usize; 23];
    {
        let parts = DisjointMut::new(&mut slots);
        pool.run(23, |p| {
            let cell = unsafe { parts.take(p..p + 1) };
            cell[0] = p + 1;
        });
    }
    for (i, v) in slots.iter().enumerate() {
        assert_eq!(*v, i + 1);
    }
}

#[test]
#[should_panic(expected = "part out of range")]
fn disjoint_mut_rejects_out_of_range_parts() {
    let mut buf = vec![0u8; 4];
    let parts = DisjointMut::new(&mut buf);
    let _ = unsafe { parts.take(2..5) };
}

#[test]
fn run_scoped_gives_every_part_its_own_thread() {
    pin_threads();
    // parts may all block simultaneously (here: a barrier none could pass
    // if parts shared threads), and pooled kernels must stay usable from
    // inside a scoped part — the serve smoke-client shape
    let n = 6usize;
    let barrier = std::sync::Barrier::new(n);
    let done = AtomicUsize::new(0);
    pool::run_scoped(n, |_| {
        barrier.wait(); // deadlocks unless all n parts run concurrently
        let mut out = vec![0.0f32; 4];
        pool::run_bands(4, 1, &mut out, |rows, band| {
            for (local, r) in rows.enumerate() {
                band[local] = r as f32;
            }
        });
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0]);
        done.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(done.load(Ordering::Relaxed), n);
}

#[test]
fn pooled_gemm_matches_serial_reference() {
    pin_threads();
    // m ≥ 64 rows ⇒ all three cores cross the pool (global width 3);
    // compare against a naive f64 reference
    let mut rng = Rng::new(99);
    let (m, k, n) = (96usize, 70usize, 33usize);
    let mut a = Mat::zeros(m, k);
    let mut b = Mat::zeros(k, n);
    rng.fill_normal(&mut a.data, 0.0, 1.0);
    rng.fill_normal(&mut b.data, 0.0, 1.0);
    let c = gemm::matmul(&a, &b);
    for i in 0..m {
        for j in 0..n {
            let want: f64 =
                (0..k).map(|p| (a[(i, p)] as f64) * (b[(p, j)] as f64)).sum();
            assert!(
                (c[(i, j)] - want as f32).abs() < 1e-3,
                "({i},{j}): {} vs {want}",
                c[(i, j)]
            );
        }
    }
    // transposed cores through the pool, against the explicit-transpose
    // route: AᵀC is (k, n) threaded over k = 70; CBᵀ is (m, k) over m = 96
    let atc = gemm::matmul_at_b(&a, &c);
    let want_atc = gemm::matmul(&a.transpose(), &c);
    for (x, y) in atc.data.iter().zip(&want_atc.data) {
        assert!((x - y).abs() < 2e-3, "{x} vs {y}");
    }
    let cbt = gemm::matmul_a_bt(&c, &b);
    let want_cbt = gemm::matmul(&c, &b.transpose());
    for (x, y) in cbt.data.iter().zip(&want_cbt.data) {
        assert!((x - y).abs() < 2e-3, "{x} vs {y}");
    }
}

/// Bounded spin-wait (yields): turns a logic error in the concurrency
/// tests into a clean panic instead of a hung test binary.
fn spin_until(f: impl Fn() -> bool) {
    for _ in 0..50_000_000u64 {
        if f() {
            return;
        }
        std::thread::yield_now();
    }
    panic!("spin_until timed out — expected concurrency never materialized");
}

#[test]
fn two_tasks_run_concurrently_with_worker_participation() {
    pin_threads();
    // 1 dispatcher-slot thread (scoped) + 2 workers. Task A blocks one
    // thread and holds its task slot; task B then *requires* two threads
    // to rendezvous. Under the old single-task pool, B would degrade to
    // inline serial execution (its dispatcher owns both parts) and the
    // rendezvous could never complete — the multi-task queue is exactly
    // what lets a worker join B while A is still live.
    let pool = Pool::new(3);
    let release = AtomicBool::new(false);
    let a_blocked = AtomicUsize::new(0);
    let b_entered = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let pool = &pool;
        let release = &release;
        let a_blocked = &a_blocked;
        let b_entered = &b_entered;
        s.spawn(move || {
            pool.run(2, |p| {
                if p == 0 {
                    a_blocked.fetch_add(1, Ordering::SeqCst);
                    spin_until(|| release.load(Ordering::SeqCst));
                }
            });
        });
        // task A is live: one part parked, its slot held
        spin_until(|| a_blocked.load(Ordering::SeqCst) == 1);
        // task B: two parts that only finish if two threads run them
        // concurrently — dispatcher (this thread) plus a pool worker
        pool.run(2, |_| {
            b_entered.fetch_add(1, Ordering::SeqCst);
            spin_until(|| b_entered.load(Ordering::SeqCst) == 2);
        });
        // B completed while A was still parked: tasks overlapped
        assert!(!release.load(Ordering::SeqCst));
        assert_eq!(b_entered.load(Ordering::SeqCst), 2);
        release.store(true, Ordering::SeqCst);
    });
}

#[test]
fn task_slot_exhaustion_falls_back_inline_without_deadlock() {
    pin_threads();
    let pool = Pool::new(2); // 1 worker: most parts of the fillers park
    let release = AtomicBool::new(false);
    let occupied: Vec<AtomicUsize> = (0..TASK_SLOTS).map(|_| AtomicUsize::new(0)).collect();
    std::thread::scope(|s| {
        let pool = &pool;
        let release = &release;
        let occupied = &occupied;
        // TASK_SLOTS dispatchers, each parking a task in one ring slot
        for t in 0..TASK_SLOTS {
            s.spawn(move || {
                pool.run(2, |_| {
                    occupied[t].fetch_add(1, Ordering::SeqCst);
                    spin_until(|| release.load(Ordering::SeqCst));
                });
            });
        }
        // every filler task has at least one part running ⇒ all
        // TASK_SLOTS ring slots are held
        spin_until(|| occupied.iter().all(|o| o.load(Ordering::SeqCst) >= 1));
        // a further dispatch must find no slot, run inline on this very
        // thread, and complete — never block waiting for a slot
        let me = std::thread::current().id();
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), |p| {
            assert_eq!(
                std::thread::current().id(),
                me,
                "ring-full dispatch must run inline on the caller"
            );
            hits[p].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert!(!release.load(Ordering::SeqCst), "inline fallback finished first");
        release.store(true, Ordering::SeqCst);
    });
}

#[test]
fn panic_in_one_task_does_not_poison_a_concurrent_task() {
    pin_threads();
    let pool = Pool::new(4);
    for round in 0..20 {
        let good = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let pool = &pool;
            let good = &good;
            let bad = s.spawn(move || {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    pool.run(8, |p| {
                        if p % 2 == 0 {
                            panic!("bad task part {p}");
                        }
                    });
                }))
            });
            // the concurrent task must complete cleanly: a panic leaking
            // across slots would make this dispatch re-raise and unwind
            // the scope
            s.spawn(move || {
                pool.run(64, |_| {
                    good.fetch_add(1, Ordering::Relaxed);
                });
            });
            let bad_result = bad.join().expect("bad dispatcher thread survived");
            assert!(
                bad_result.is_err(),
                "round {round}: panic must reach the panicking task's own dispatcher"
            );
        });
        assert_eq!(
            good.load(Ordering::Relaxed),
            64,
            "round {round}: concurrent task lost parts to a foreign panic"
        );
    }
}

#[test]
fn panic_in_worker_part_propagates_and_pool_recovers() {
    pin_threads();
    let pool = Pool::new(4);
    for _ in 0..3 {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, |p| {
                if p % 17 == 5 {
                    panic!("boom at {p}");
                }
            });
        }));
        assert!(result.is_err());
        // the same pool must keep dispatching correctly afterwards
        let ok = AtomicUsize::new(0);
        pool.run(64, |_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 64);
    }
}
