//! Integration tests for the observability plane (`lcquant::obs`), the
//! properties `docs/OBSERVABILITY.md` claims:
//!
//! 1. the recording hot path (histogram + trace ring + counters + gauges)
//!    performs **zero heap allocation** — verified with a counting global
//!    allocator, the same discipline as `rust/tests/flat_params.rs`;
//! 2. log₂ bucket boundaries hold for arbitrary values, and every
//!    bucket's representative (inclusive upper edge) lies in its own
//!    bucket;
//! 3. histogram percentiles land **within one bucket** of the exact
//!    nearest-rank sample percentile (`metrics::percentile_sorted`'s rank
//!    rule);
//! 4. a real `lc_quantize` run mirrors its telemetry into the global
//!    registry **bit-identically** to the run's own history records;
//! 5. the v2 `Stats` frame round-trips over loopback TCP and its counters
//!    match a load generator's observed outcome counts **exactly** — the
//!    books balance, not approximately balance;
//! 6. hostile `StatsRequest` frames (trailing bytes, truncated fields)
//!    are rejected with `Malformed` and a closed connection;
//! 7. the snapshot stays valid at **every lifecycle point** of the epoll
//!    plane — fresh, mid-traffic (with a second connection camped on a
//!    partial frame), and after `stop()` — for both the `NetServer` and
//!    the `RouterServer`;
//! 8. the docs that describe all of the above actually name the metrics,
//!    stages and wire tags that exist in the code;
//! 9. (v3) one trace id stitches the tiers: every traced request routed
//!    through a `RouterServer` resolves to a router-side hop span AND a
//!    backend-side 7-stage span by the same id, `Histogram::merge` is
//!    bucket-exact (merging snapshots equals recording into one
//!    histogram), the `FleetStats` merged view reconciles **exactly**
//!    with the per-backend sections it was built from, and
//!    `obs::RateWindow` turns successive fleet snapshots into exact
//!    windowed rates.
//!
//! `ci.sh` and `make tier1` run this file under the default thread policy
//! and again with `LCQUANT_THREADS=2` (`smoke-obs-fleet`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use lcquant::coordinator::{lc_quantize, LcConfig, MuSchedule, NativeBackend, PenaltyMode};
use lcquant::data::Dataset;
use lcquant::linalg::Mat;
use lcquant::net::loadgen::{self, LoadGenConfig};
use lcquant::net::proto::{self, ErrorCode, ErrorFrame, Frame, FrameReader, StatsRequestFrame};
use lcquant::net::{
    FabricConfig, NetClient, NetConfig, NetServer, RouterConfig, RouterServer, ShardConfig,
};
use lcquant::nn::sgd::ClippedLrSchedule;
use lcquant::nn::{Activation, Mlp, MlpSpec};
use lcquant::obs::hist::{bucket_index, bucket_max_ns};
use lcquant::obs::{
    self, CounterId, GaugeId, HistId, Histogram, HistogramSnapshot, RateWindow, RouterStage,
    Stage, Trace, TraceRing,
};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{PackedModel, Registry, ServerConfig};
use lcquant::util::backoff::BackoffCfg;
use lcquant::util::json::Json;
use lcquant::util::rng::Rng;

// ---- counting allocator (flat_params.rs discipline): a thread-local
//      counter so the hot-path assertions are immune to sibling test
//      threads allocating concurrently -----------------------------------

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn thread_allocs() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serializes tests that assert exact deltas on the process-global
/// registry (gauges, the LC counters). Poison is ignored — a failed
/// sibling must not mask this binary's other assertions.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial_guard() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

// ---- 1. zero-alloc hot path --------------------------------------------

#[test]
fn recording_hot_path_performs_zero_allocations() {
    // build everything (histogram, ring, one trace, a full rate window,
    // a merge accumulator) *before* measuring
    let hist = Histogram::new();
    let ring = TraceRing::new(64);
    let mut trace = Trace::from_parts(0, 0, [0; obs::STAGES]);
    // warm one pass so any lazy init is behind us
    hist.record_ns(1);
    ring.record(&trace);
    obs::counter(CounterId::TracesRecorded).get();
    let mut win = RateWindow::new(4);
    for t in 0..4u64 {
        win.push(t as f64, t, 0, hist.snapshot());
    }
    let mut merged = hist.snapshot();

    let before = thread_allocs();
    for i in 0..10_000u64 {
        hist.record_ns(i.wrapping_mul(2_654_435_761) & 0xff_ffff);
        trace.id = i;
        trace.trace_id = i + 1; // the traced (v3) record path
        trace.set(Stage::Compute, i & 0xffff);
        ring.record(&trace);
        obs::gauge(GaugeId::LcMu).set(i as f64);
        obs::counter(CounterId::TracesRecorded).add(0);
        obs::hist(HistId::ServeLatency).record_ns(i & 0xfff);
        // snapshot → merge → window push: the fleet-stats aggregation
        // path is fixed-size arithmetic, no heap
        let snap = hist.snapshot();
        merged.merge(&snap);
        win.push((4 + i) as f64, i, 0, snap);
    }
    let delta = thread_allocs() - before;
    assert_eq!(delta, 0, "metrics hot path allocated {delta} times in 10k records");
    assert!(hist.snapshot().count() >= 10_000);
    assert!(merged.count() > 0);
    assert!(win.rates().is_some());
}

// ---- 2. bucket boundary properties -------------------------------------

#[test]
fn bucket_boundaries_hold_for_arbitrary_values() {
    // bucket 0 is exactly {0}; bucket i ≥ 1 covers [2^(i-1), 2^i), with
    // the top bucket absorbing everything above its floor
    assert_eq!(bucket_index(0), 0);
    let mut rng = Rng::new(0x0B5);
    for _ in 0..20_000 {
        // log-uniform-ish: random bucket magnitude, random offset inside
        let e = rng.below(63) as u32;
        let v = (1u64 << e) | ((rng.below(usize::MAX) as u64) & ((1u64 << e) - 1));
        let i = bucket_index(v);
        assert!(i >= 1, "nonzero value {v} in the zero bucket");
        assert!(v >= 1u64 << (i - 1), "{v} below the floor of bucket {i}");
        if i < 63 {
            assert!(v < 1u64 << i, "{v} above the ceiling of bucket {i}");
        }
    }
    // every bucket's representative (inclusive upper edge) lies in the
    // bucket it represents — so percentile answers index back correctly
    for i in 0..obs::HIST_BUCKETS {
        assert_eq!(
            bucket_index(bucket_max_ns(i)),
            i,
            "representative of bucket {i} escapes its bucket"
        );
    }
    // adjacent buckets never overlap: each floor is the previous edge + 1
    for i in 2..obs::HIST_BUCKETS {
        assert_eq!(bucket_max_ns(i - 1) + 1, 1u64 << (i - 1));
    }
}

// ---- 3. percentile parity with the exact-sample discipline -------------

#[test]
fn histogram_percentile_within_one_bucket_of_exact_sample() {
    let hist = Histogram::new();
    let mut samples: Vec<u64> = Vec::new();
    let mut rng = Rng::new(0xCAFE);
    for _ in 0..5_000 {
        // latencies spanning ~1 µs to ~100 ms, log-distributed like real
        // request latencies
        let e = 10 + rng.below(17) as u32;
        let v = (1u64 << e) | ((rng.below(usize::MAX) as u64) & ((1u64 << e) - 1));
        hist.record_ns(v);
        samples.push(v);
    }
    samples.sort_unstable();
    let snap = hist.snapshot();
    assert_eq!(snap.count(), samples.len() as u64);
    assert_eq!(snap.sum_ns, samples.iter().sum::<u64>());

    for q in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0] {
        // the exact nearest-rank answer, metrics::percentile_sorted's rule
        let rank = ((q / 100.0) * (samples.len() - 1) as f64).round() as usize;
        let exact = samples[rank.min(samples.len() - 1)];
        let approx = snap.percentile_ns(q);
        // same discipline ⇒ the histogram answer is the exact answer's
        // bucket edge: identical bucket, i.e. within one bucket width
        assert_eq!(
            bucket_index(approx),
            bucket_index(exact),
            "p{q}: histogram {approx} vs exact {exact} disagree beyond one bucket"
        );
    }
    // the reported max bounds the true max from above, within its bucket
    let true_max = *samples.last().unwrap();
    assert!(snap.max_ns() >= true_max);
    assert_eq!(bucket_index(snap.max_ns()), bucket_index(true_max));

    // and the f32-ms path agrees with metrics::percentile_sorted run on
    // the same data quantized the same way
    let sorted_ms: Vec<f32> = samples.iter().map(|&v| (v as f64 / 1e6) as f32).collect();
    let exact_p50_ms = lcquant::metrics::percentile_sorted(&sorted_ms, 50.0);
    let hist_p50_ms = snap.percentile_ms(50.0);
    assert!(
        hist_p50_ms >= exact_p50_ms && hist_p50_ms <= exact_p50_ms * 2.0 + 1e-6,
        "p50 {hist_p50_ms}ms not within one log₂ bucket of exact {exact_p50_ms}ms"
    );
}

// ---- 4. LC loop mirrors its history into the registry bit-exactly ------

fn tiny_dataset(n: usize, dim: usize, classes: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut images = Mat::zeros(n, dim);
    rng.fill_normal(&mut images.data, 0.0, 1.0);
    let labels: Vec<u8> = (0..n).map(|_| rng.below(classes) as u8).collect();
    Dataset { images, labels, n_classes: classes }
}

#[test]
fn lc_run_mirrors_history_into_registry_bit_exact() {
    let _guard = serial_guard();
    obs::set_enabled(true);
    let spec = MlpSpec {
        sizes: vec![32, 16, 8],
        hidden_activation: Activation::Tanh,
        dropout_keep: vec![],
    };
    let seed = 42u64;
    let net = Mlp::new(&spec, seed);
    let mut backend = NativeBackend::new(net, tiny_dataset(64, 32, 8, seed ^ 0xDA7A), None, 32, seed);

    let cfg = LcConfig {
        scheme: Scheme::AdaptiveCodebook { k: 4 },
        mu: MuSchedule::new(0.001, 1.4),
        iterations: 5,
        l_steps: 10,
        lr: ClippedLrSchedule { eta0: 0.05, decay: 0.98 },
        momentum: 0.9,
        mode: PenaltyMode::AugmentedLagrangian,
        tol: 0.0, // never stop early: the counter delta below is exact
        seed: 7,
        eval_every: 0,
        n_weight_samples: 0,
    };
    let iters_before = obs::counter(CounterId::LcIterations).get();
    let lstep_count_before = obs::hist(HistId::LcLstep).snapshot().count();
    let res = lc_quantize(&mut backend, &cfg);
    let last = res.history.last().expect("history");

    // gauges hold the *exact bit patterns* of the history's f64 casts —
    // the registry is a live mirror of the run record, not a re-derivation
    assert_eq!(obs::gauge(GaugeId::LcIter).get().to_bits(), (last.iter as f64).to_bits());
    assert_eq!(obs::gauge(GaugeId::LcMu).get().to_bits(), (last.mu as f64).to_bits());
    assert_eq!(obs::gauge(GaugeId::LcLoss).get().to_bits(), (last.lstep_loss as f64).to_bits());
    assert_eq!(
        obs::gauge(GaugeId::LcFeasibility).get().to_bits(),
        (last.feasibility as f64).to_bits()
    );
    // step-time gauges are wall-clock (not comparable to history) but must
    // be finite, non-negative milliseconds
    assert!(obs::gauge(GaugeId::LcLstepMs).get() >= 0.0);
    assert!(obs::gauge(GaugeId::LcCstepMs).get() >= 0.0);
    // one counter bump + one L-step histogram record per outer iteration
    assert_eq!(
        obs::counter(CounterId::LcIterations).get() - iters_before,
        res.history.len() as u64
    );
    assert_eq!(
        obs::hist(HistId::LcLstep).snapshot().count() - lstep_count_before,
        res.history.len() as u64
    );
}

// ---- loopback fixtures (mirrors rust/tests/net.rs) ---------------------

fn toy_packed(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec {
        sizes: vec![12, 8, 4],
        hidden_activation: Activation::Tanh,
        dropout_keep: vec![],
    };
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn start_toy_server() -> NetServer {
    let mut reg = Registry::new();
    reg.insert(toy_packed("toy-k4", &Scheme::AdaptiveCodebook { k: 4 }, 11)).unwrap();
    let serve = ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(1),
        pipeline_depth: 2,
    };
    let net = NetConfig {
        bind_addr: "127.0.0.1:0".to_string(),
        max_connections: 8,
        ..NetConfig::default()
    };
    NetServer::start(Arc::new(reg), serve, net).expect("bind loopback server")
}

/// Fetch `path` under `key` or panic with a schema message.
fn field<'j>(j: &'j Json, key: &str) -> &'j Json {
    j.get(key).unwrap_or_else(|| panic!("snapshot missing key '{key}'"))
}

fn field_u64(j: &Json, key: &str) -> u64 {
    field(j, key).as_f64().unwrap_or_else(|| panic!("key '{key}' not a number")) as u64
}

// ---- 5. the Stats frame balances the books exactly ---------------------

#[test]
fn stats_frame_round_trip_matches_loadgen_counts_exactly() {
    obs::set_enabled(true);
    let server = start_toy_server();
    let addr = server.local_addr().to_string();

    let connections = 3usize;
    let per_conn = 16usize;
    let report = loadgen::run(&LoadGenConfig {
        addr: addr.clone(),
        connections,
        requests_per_conn: per_conn,
        model: Some("toy-k4".to_string()),
        batch: 1,
        seed: 5,
        pipeline: 1,
        trace: false,
    })
    .expect("loadgen run");
    // an unloaded loopback server must answer everything
    assert_eq!(report.sent, connections * per_conn);
    assert_eq!(report.ok, connections * per_conn);
    assert_eq!(report.shed, 0);
    assert_eq!(report.failed, 0);

    // the wire snapshot, via the v2 Stats frame pair
    let mut client = NetClient::connect(&addr).expect("stats connection");
    let body = client.stats().expect("stats round trip");
    let snap = Json::parse(&body).expect("snapshot must be valid JSON");

    // server section: exact match against what the loadgen observed
    let srv = field(&snap, "server");
    assert_eq!(field_u64(srv, "requests_ok"), report.ok as u64, "requests_ok must balance");
    assert_eq!(field_u64(srv, "requests_shed"), report.shed as u64);
    assert_eq!(field_u64(srv, "requests_failed"), report.failed as u64);
    assert_eq!(field_u64(srv, "stats_requests"), 1, "exactly this Stats frame");
    // loadgen probe + drivers + this stats connection
    assert!(field_u64(srv, "connections") >= (connections + 2) as u64);

    // batch section: every ok single-row request crossed the micro-batcher
    let batch = field(&snap, "batch");
    assert_eq!(field_u64(batch, "requests"), report.ok as u64);
    assert_eq!(field_u64(batch, "errors"), 0);
    assert!(field_u64(batch, "batches") >= 1);
    assert!(field_u64(field(batch, "latency"), "count") >= report.ok as u64);

    // process registry: all three metric families present and named
    let process = field(&snap, "process");
    for id in CounterId::ALL {
        assert!(
            field(process, "counters").get(id.name()).is_some(),
            "registry snapshot missing counter '{}'",
            id.name()
        );
    }
    for id in GaugeId::ALL {
        assert!(field(process, "gauges").get(id.name()).is_some());
    }
    for id in HistId::ALL {
        assert!(field(process, "histograms").get(id.name()).is_some());
    }

    // pool profile: one lane per worker slot, plus the dispatch counters
    let pool = field(&snap, "pool");
    let lanes = field(pool, "parts_claimed").as_arr().expect("parts_claimed array");
    assert_eq!(lanes.len(), lcquant::linalg::pool::PROFILE_WORKERS);
    field_u64(pool, "inline_dispatches");
    field_u64(pool, "slot_exhausted");
    field_u64(pool, "total_spawned");

    // traces: recorded requests carry all seven pipeline stages
    let traces = field(&snap, "traces").as_arr().expect("traces array");
    assert!(!traces.is_empty(), "48 answered requests must leave traces");
    for t in traces {
        field_u64(t, "id");
        assert!(field(t, "total_ms").as_f64().unwrap() >= 0.0);
        let stages = field(t, "stages");
        for s in Stage::ALL {
            assert!(
                stages.get(s.name()).is_some(),
                "trace missing stage '{}'",
                s.name()
            );
        }
    }
    field_u64(&snap, "traces_dropped");

    // the per-instance snapshot agrees with the wire document
    let stats = server.stats();
    assert_eq!(stats.requests_ok, report.ok as u64);
    assert_eq!(stats.stats_requests, 1);
}

// ---- 6. hostile stats frames -------------------------------------------

/// FNV-1a 64 (the envelope checksum, per docs/wire-protocol.md).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Hand-rolled envelope around an arbitrary (possibly malformed) payload,
/// with a *correct* length prefix and checksum — so the rejection under
/// test is the payload decoder's, not the envelope's.
fn raw_frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 12);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    out
}

/// Raw-socket handshake: client preamble out, server preamble + hello in.
fn raw_handshake(addr: &str) -> (TcpStream, FrameReader) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(&proto::encode_preamble()).unwrap();
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    stream.read_exact(&mut pre).unwrap();
    assert_eq!(proto::decode_preamble(&pre).unwrap(), proto::VERSION);
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Hello(_))) => return (stream, reader),
            Ok(Some(f)) => panic!("expected hello, got {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("handshake failed: {e}"),
        }
    }
}

/// Read frames until the peer closes; returns the last error frame seen.
fn read_error_then_eof(stream: &mut TcpStream, reader: &mut FrameReader) -> Option<ErrorFrame> {
    let mut last = None;
    loop {
        match reader.poll_frame(stream) {
            Ok(Some(Frame::Error(e))) => last = Some(e),
            Ok(Some(f)) => panic!("unexpected frame {f:?}"),
            Ok(None) => continue,
            Err(_) => return last, // closed (or mid-frame EOF)
        }
    }
}

#[test]
fn stats_request_echoes_id_over_raw_socket() {
    let server = start_toy_server();
    let (mut stream, mut reader) = raw_handshake(&server.local_addr().to_string());
    let id = 0xDEAD_BEEF_u64;
    stream
        .write_all(&Frame::StatsRequest(StatsRequestFrame { id }).to_bytes())
        .unwrap();
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::StatsResponse(r))) => {
                assert_eq!(r.id, id, "response must echo the request id");
                let snap = Json::parse(&r.json).expect("snapshot JSON");
                let keys = [
                    "server", "batch", "process", "pool", "plane", "traces", "traces_dropped",
                    "trace_ids",
                ];
                for key in keys {
                    assert!(snap.get(key).is_some(), "snapshot missing '{key}'");
                }
                return;
            }
            Ok(Some(f)) => panic!("expected StatsResponse, got {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("stats round trip failed: {e}"),
        }
    }
}

#[test]
fn stats_request_with_trailing_bytes_is_malformed() {
    let server = start_toy_server();
    let (mut stream, mut reader) = raw_handshake(&server.local_addr().to_string());
    // tag 5 + id + one illegal trailing byte, valid checksum
    let mut payload = vec![5u8];
    payload.extend_from_slice(&7u64.to_le_bytes());
    payload.push(0xAA);
    stream.write_all(&raw_frame(&payload)).unwrap();
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::Malformed);
}

#[test]
fn stats_request_with_truncated_id_is_malformed() {
    let server = start_toy_server();
    let (mut stream, mut reader) = raw_handshake(&server.local_addr().to_string());
    // tag 5 but only half the id field, valid checksum
    let mut payload = vec![5u8];
    payload.extend_from_slice(&[1, 2, 3, 4]);
    stream.write_all(&raw_frame(&payload)).unwrap();
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::Malformed);
}

// ---- 7. snapshot validity across the plane lifecycle -------------------

#[test]
fn stats_snapshot_is_valid_at_every_lifecycle_point() {
    // the epoll plane serves stats from its first poll tick to after
    // stop: fresh, mid-traffic (with a second connection camped on a
    // partial frame), and post-stop via the in-process snapshot
    let mut server = start_toy_server();
    let addr = server.local_addr().to_string();

    // fresh: no traffic yet, the document is already complete
    let snap = Json::parse(&server.snapshot_json()).expect("fresh snapshot JSON");
    let keys =
        ["server", "batch", "process", "pool", "plane", "traces", "traces_dropped", "trace_ids"];
    for key in keys {
        assert!(snap.get(key).is_some(), "fresh snapshot missing '{key}'");
    }
    assert_eq!(field_u64(field(&snap, "server"), "requests_ok"), 0);

    // mid-traffic: one connection camps mid-frame while pipelined
    // traffic completes on another — the snapshot must stay valid and
    // balanced while partial-frame state is live
    let (mut camper, _camper_reader) = raw_handshake(&addr);
    camper.write_all(&[0xAB, 0xCD]).unwrap(); // partial length prefix, never completed

    let mut client = NetClient::connect(&addr).expect("traffic connection");
    let rows_flat = vec![0.25f32; 12 * 6];
    let rows: Vec<&[f32]> = rows_flat.chunks(12).collect();
    let results = client.infer_pipelined("toy-k4", &rows, 3);
    assert!(results.iter().all(|r| r.is_ok()), "unloaded server answers every slot");
    let body = client.stats().expect("mid-traffic stats round trip");
    let snap = Json::parse(&body).expect("mid-traffic snapshot JSON");
    let keys =
        ["server", "batch", "process", "pool", "plane", "traces", "traces_dropped", "trace_ids"];
    for key in keys {
        assert!(snap.get(key).is_some(), "mid-traffic snapshot missing '{key}'");
    }
    assert_eq!(field_u64(field(&snap, "server"), "requests_ok"), 6);

    // after stop: the wire is gone but the in-process snapshot survives
    // with the final books
    drop(camper);
    server.stop();
    let snap = Json::parse(&server.snapshot_json()).expect("post-stop snapshot JSON");
    let srv = field(&snap, "server");
    assert_eq!(field_u64(srv, "requests_ok"), 6);
    assert_eq!(field_u64(srv, "stats_requests"), 1);

    // the router runs the same event plane with its own schema — same
    // three lifecycle points
    let backend = start_toy_server();
    let mut router = RouterServer::start(RouterConfig {
        net: NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 8,
            ..NetConfig::default()
        },
        fabric: FabricConfig {
            shards: vec![ShardConfig {
                models: Vec::new(),
                replicas: vec![backend.local_addr().to_string()],
            }],
            retry_budget: 4,
            deadline: Duration::from_secs(30),
            backoff: BackoffCfg::ZERO,
            probe_every: Duration::ZERO,
            connect_timeout: Duration::from_secs(1),
            seed: 7,
        },
    })
    .expect("bind router");

    let snap = Json::parse(&router.snapshot_json()).expect("fresh router snapshot JSON");
    for key in ["router", "backends", "process", "plane", "traces", "traces_dropped", "trace_ids"] {
        assert!(snap.get(key).is_some(), "fresh router snapshot missing '{key}'");
    }
    assert_eq!(field_u64(field(&snap, "router"), "requests_ok"), 0);

    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();
    let rows: Vec<&[f32]> = rows_flat.chunks(12).take(4).collect();
    let results = client.infer_pipelined("toy-k4", &rows, 2);
    assert!(results.iter().all(|r| r.is_ok()), "routed slots must all answer");
    let body = client.stats().expect("mid-traffic router stats");
    let snap = Json::parse(&body).expect("mid-traffic router snapshot JSON");
    for key in ["router", "backends", "process", "plane", "traces", "traces_dropped", "trace_ids"] {
        assert!(snap.get(key).is_some(), "mid-traffic router snapshot missing '{key}'");
    }
    assert_eq!(field_u64(field(&snap, "router"), "requests_ok"), 4);

    router.stop();
    let snap = Json::parse(&router.snapshot_json()).expect("post-stop router snapshot JSON");
    let r = field(&snap, "router");
    assert_eq!(field_u64(r, "requests_ok"), 4);
    assert_eq!(field_u64(r, "stats_requests"), 1);
}

// ---- 9. cross-tier trace stitching + fleet stats (v3) -------------------

fn start_router(replicas: &[String]) -> RouterServer {
    RouterServer::start(RouterConfig {
        net: NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 8,
            ..NetConfig::default()
        },
        fabric: FabricConfig {
            shards: vec![ShardConfig { models: Vec::new(), replicas: replicas.to_vec() }],
            retry_budget: 4,
            deadline: Duration::from_secs(30),
            backoff: BackoffCfg::ZERO,
            probe_every: Duration::ZERO,
            connect_timeout: Duration::from_secs(1),
            seed: 7,
        },
    })
    .expect("bind router")
}

#[test]
fn histogram_merge_is_bucket_exact_and_preserves_percentile_discipline() {
    let mut rng = Rng::new(0x4E46);
    let h1 = Histogram::new();
    let h2 = Histogram::new();
    let pooled = Histogram::new();
    let mut samples: Vec<u64> = Vec::new();
    for i in 0..4_000usize {
        let e = 8 + rng.below(20) as u32;
        let v = (1u64 << e) | ((rng.below(usize::MAX) as u64) & ((1u64 << e) - 1));
        if i % 3 == 0 {
            h1.record_ns(v);
        } else {
            h2.record_ns(v);
        }
        pooled.record_ns(v);
        samples.push(v);
    }
    samples.sort_unstable();

    let mut merged = h1.snapshot();
    merged.merge(&h2.snapshot());
    let direct = pooled.snapshot();
    // bucket-exact: merging two snapshots answers identically to having
    // recorded both streams into one histogram (log₂ buckets align, so
    // the merge is lossless — the fleet view is not an approximation)
    assert_eq!(merged.count(), direct.count());
    assert_eq!(merged.sum_ns, direct.sum_ns);
    assert_eq!(merged.counts, direct.counts);
    for q in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
        assert_eq!(merged.percentile_ns(q), direct.percentile_ns(q), "p{q} diverged");
        // and the nearest-rank discipline vs the exact pooled samples
        // still holds after the merge: same bucket as the true answer
        let rank = ((q / 100.0) * (samples.len() - 1) as f64).round() as usize;
        let exact = samples[rank.min(samples.len() - 1)];
        assert_eq!(
            bucket_index(merged.percentile_ns(q)),
            bucket_index(exact),
            "p{q}: merged histogram and exact samples disagree beyond one bucket"
        );
    }
    assert_eq!(merged.max_ns(), direct.max_ns());

    // merging an empty snapshot is the identity
    let before = merged.clone();
    merged.merge(&HistogramSnapshot::empty());
    assert_eq!(merged.counts, before.counts);
    assert_eq!(merged.sum_ns, before.sum_ns);

    // the live-histogram fold agrees with the snapshot-side merge
    let live = Histogram::new();
    live.merge(&h1.snapshot());
    live.merge(&h2.snapshot());
    assert_eq!(live.snapshot().counts, direct.counts);
    assert_eq!(live.snapshot().sum_ns, direct.sum_ns);

    // the canonical serialized form round-trips count- and bucket-exact
    let back = HistogramSnapshot::from_json(&merged.to_json()).expect("canonical form parses");
    assert_eq!(back.counts, merged.counts);
    assert_eq!(back.sum_ns, merged.sum_ns);
    for q in [50.0, 99.0] {
        assert_eq!(back.percentile_ns(q), merged.percentile_ns(q));
    }
}

#[test]
fn trace_ids_stitch_router_and_backend_spans_end_to_end() {
    obs::set_enabled(true);
    let b1 = start_toy_server();
    let b2 = start_toy_server();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let router = start_router(&addrs);

    // one client, a known trace base: request i carries id base + i
    let n = 24u64;
    let base = 0x7E5E_0000_0000u64;
    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();
    client.set_trace_base(base);
    for _ in 0..n {
        client.infer("toy-k4", &[0.1; 12]).expect("routed inference");
    }
    assert_eq!(client.traces_issued(), n);

    // every issued id resolves to a router-side hop span…
    let router_traces = router.traces();
    for i in 1..=n {
        let id = base.wrapping_add(i);
        let span = router_traces
            .iter()
            .find(|t| t.trace_id == id)
            .unwrap_or_else(|| panic!("trace {id:#x} missing from the router ring"));
        let total = span.total_ns();
        assert!(total > 0, "router span for {id:#x} must cover nonzero time");
        for s in 0..obs::ROUTER_STAGES {
            assert!(span.stage_ns[s] <= total, "hop stage {s} exceeds the span total");
        }
        // a real routed request spends real time waiting on its backend
        assert!(span.stage_ns[RouterStage::BackendWait as usize] > 0);
    }

    // …AND a backend-side span: the union of the two rings holds every
    // id, and each recorded trace accounts all seven pipeline stages
    let mut backend_ids: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for addr in &addrs {
        let mut c = NetClient::connect(addr).unwrap();
        let snap = Json::parse(&c.stats().unwrap()).expect("backend snapshot JSON");
        for v in field(&snap, "trace_ids").as_arr().expect("trace_ids array") {
            backend_ids.insert(v.as_f64().expect("trace id number") as u64);
        }
        for t in field(&snap, "traces").as_arr().expect("traces array") {
            let total = field(t, "total_ms").as_f64().unwrap();
            for s in Stage::ALL {
                let ms = field(field(t, "stages"), s.name()).as_f64().unwrap();
                assert!(
                    ms >= 0.0 && ms <= total + 1e-9,
                    "stage '{}' ({ms}ms) outside its trace total ({total}ms)",
                    s.name()
                );
            }
        }
    }
    for i in 1..=n {
        let id = base.wrapping_add(i);
        assert!(backend_ids.contains(&id), "trace {id:#x} not in any backend ring");
    }
}

#[test]
fn fleet_stats_merge_reconciles_exactly_with_per_backend_sums() {
    obs::set_enabled(true);
    let b1 = start_toy_server();
    let b2 = start_toy_server();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let router = start_router(&addrs);

    // traffic through the router so the books have something in them
    let traffic = 30u64;
    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();
    for _ in 0..traffic {
        client.infer("toy-k4", &[0.2; 12]).expect("routed inference");
    }

    let body = client.fleet_stats().expect("fleet stats round trip");
    let doc = Json::parse(&body).expect("fleet stats JSON");
    let fleet = field(&doc, "fleet");
    assert_eq!(field_u64(fleet, "backends_total"), 2);
    assert_eq!(field_u64(fleet, "backends_ok"), 2);
    assert_eq!(field_u64(field(fleet, "health"), "healthy"), 2);
    assert_eq!(field_u64(field(fleet, "health"), "down"), 0);

    // per-backend sections carry each backend's full stats document;
    // sum their counters by hand
    let sections = field(&doc, "backends").as_arr().expect("backends array");
    assert_eq!(sections.len(), 2);
    let (mut sum_ok, mut sum_shed, mut sum_failed, mut sum_lat) = (0u64, 0u64, 0u64, 0u64);
    for s in sections {
        assert!(field(s, "ok").as_bool().unwrap(), "backend section must be ok");
        let stats = field(s, "stats");
        let srv = field(stats, "server");
        sum_ok += field_u64(srv, "requests_ok");
        sum_shed += field_u64(srv, "requests_shed");
        sum_failed += field_u64(srv, "requests_failed");
        sum_lat += field_u64(field(field(stats, "batch"), "latency"), "count");
    }
    // the merged fleet view equals the sum of the sections it was built
    // from — exactly, not approximately
    let counters = field(fleet, "counters");
    assert_eq!(field_u64(counters, "requests_ok"), sum_ok);
    assert_eq!(field_u64(counters, "requests_shed"), sum_shed);
    assert_eq!(field_u64(counters, "requests_failed"), sum_failed);
    assert_eq!(field_u64(field(fleet, "latency"), "count"), sum_lat);
    // and the books balance against the traffic: every routed request
    // landed on exactly one backend
    assert_eq!(sum_ok, traffic);
    assert_eq!(sum_lat, traffic);
    assert_eq!(sum_shed + sum_failed, 0);
    // the router counts the fan-out it served
    assert_eq!(field_u64(field(&doc, "router"), "fleet_stats_requests"), 1);
    assert_eq!(field_u64(field(&doc, "router"), "requests_ok"), traffic);
    assert_eq!(router.stats().fleet_stats_requests, 1);
}

#[test]
fn rate_window_derives_exact_rates_from_fleet_snapshots() {
    obs::set_enabled(true);
    let b1 = start_toy_server();
    let addrs = vec![b1.local_addr().to_string()];
    let router = start_router(&addrs);
    let mut client = NetClient::connect(&router.local_addr().to_string()).unwrap();

    let fleet_sample = |client: &mut NetClient| -> (u64, u64, HistogramSnapshot) {
        let doc = Json::parse(&client.fleet_stats().unwrap()).unwrap();
        let fleet = field(&doc, "fleet");
        let c = field(fleet, "counters");
        let hist = HistogramSnapshot::from_json(field(fleet, "latency"))
            .expect("canonical fleet latency");
        (
            field_u64(c, "requests_ok") + field_u64(c, "requests_failed"),
            field_u64(c, "requests_shed"),
            hist,
        )
    };

    let mut win = RateWindow::new(8);
    let (req0, shed0, h0) = fleet_sample(&mut client);
    win.push(0.0, req0, shed0, h0);
    let burst = 20u64;
    for _ in 0..burst {
        client.infer("toy-k4", &[0.3; 12]).expect("routed inference");
    }
    let (req1, shed1, h1) = fleet_sample(&mut client);
    // timestamps are caller-supplied, so the books are exact: 20 requests
    // over exactly one second of window span
    win.push(1.0, req1, shed1, h1);
    let r = win.rates().expect("two samples give rates");
    assert_eq!(r.qps, burst as f64);
    assert_eq!(r.shed_per_s, 0.0);
    assert_eq!(r.shed_rate, 0.0);
    assert_eq!(r.delta_count, burst);
    assert!(r.p99_ms >= 0.0);
}

#[test]
fn loadgen_reports_full_trace_coverage_against_a_v3_server() {
    obs::set_enabled(true);
    let server = start_toy_server(); // default trace ring: 256 slots ≥ 40 ids
    let mut cfg = LoadGenConfig::new(&server.local_addr().to_string());
    cfg.connections = 2;
    cfg.requests_per_conn = 20;
    cfg.model = Some("toy-k4".to_string());
    cfg.trace = true;
    let report = loadgen::run(&cfg).expect("loadgen run");
    assert_eq!(report.ok, 40);
    assert_eq!(report.trace_issued, 40, "every issued request minted a trace id");
    assert_eq!(
        report.trace_found, 40,
        "every issued trace id must be found in the target's ring"
    );
    assert!((report.trace_coverage() - 1.0).abs() < 1e-9);
    assert!(report.summary().contains("trace coverage"));
}

// ---- 8. the docs name what the code ships ------------------------------

fn doc(path: &str) -> String {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(path);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

#[test]
fn observability_doc_names_every_metric_and_stage() {
    let text = doc("docs/OBSERVABILITY.md");
    for id in CounterId::ALL {
        assert!(text.contains(id.name()), "OBSERVABILITY.md missing counter '{}'", id.name());
    }
    for id in GaugeId::ALL {
        assert!(text.contains(id.name()), "OBSERVABILITY.md missing gauge '{}'", id.name());
    }
    for id in HistId::ALL {
        assert!(text.contains(id.name()), "OBSERVABILITY.md missing histogram '{}'", id.name());
    }
    for s in Stage::ALL {
        assert!(text.contains(s.name()), "OBSERVABILITY.md missing stage '{}'", s.name());
    }
    for s in RouterStage::ALL {
        assert!(
            text.contains(s.name()),
            "OBSERVABILITY.md missing router stage '{}'",
            s.name()
        );
    }
    // the snapshot schema keys the wire clients depend on
    for key in
        ["server", "batch", "process", "pool", "plane", "traces", "traces_dropped", "trace_ids"]
    {
        assert!(text.contains(key), "OBSERVABILITY.md missing snapshot key '{key}'");
    }
    // the v3 fleet machinery is documented by name
    for needle in ["Histogram::merge", "RateWindow", "FleetStats", "lcquant top", "wakeups"] {
        assert!(text.contains(needle), "OBSERVABILITY.md missing '{needle}'");
    }
}

#[test]
fn wire_protocol_doc_matches_the_shipped_version_and_tags() {
    let text = doc("docs/wire-protocol.md");
    assert!(
        text.contains(&format!("version {}", proto::VERSION)),
        "wire-protocol.md title must carry the shipped version"
    );
    assert!(text.contains(&format!("version = {}", proto::VERSION)));
    for needle in ["StatsRequest", "StatsResponse", "tag = 5", "tag = 6", "Version history"] {
        assert!(text.contains(needle), "wire-protocol.md missing '{needle}'");
    }
    // v3: the trace tail, the fleet frame pair, and the v2 compat rule
    for needle in [
        "FleetStatsRequest",
        "FleetStatsResponse",
        "tag = 7",
        "tag = 8",
        "trace context",
        "parent_span",
        "v2 compatibility",
    ] {
        assert!(text.contains(needle), "wire-protocol.md missing '{needle}'");
    }
}

#[test]
fn architecture_doc_covers_the_observability_plane() {
    let text = doc("docs/ARCHITECTURE.md");
    assert!(text.contains("Observability plane"));
    assert!(text.contains("obs"));
}
