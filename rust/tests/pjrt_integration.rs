//! Integration tests over the PJRT path: artifact loading, numeric
//! agreement between the AOT JAX graph and the native rust implementation,
//! and the LC algorithm running end-to-end on the PJRT backend.
//!
//! These tests SKIP (pass trivially with a note) when `artifacts/` has not
//! been built — run `make artifacts` first for full coverage. The whole
//! file is compiled only with the `pjrt` feature (the runtime module is
//! feature-gated).
#![cfg(feature = "pjrt")]

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
use lcquant::coordinator::{lc_quantize, Backend, LcConfig, MuSchedule, NativeBackend, PenaltyMode};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::nn::sgd::ClippedLrSchedule;
use lcquant::nn::{Mlp, MlpSpec};
use lcquant::quant::Scheme;
use lcquant::runtime::{Engine, PjrtBackend};
use lcquant::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    // cargo test runs from the workspace root
    let dir = Engine::default_dir();
    if Engine::available(&dir) {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn paired_backends(seed: u64) -> Option<(NativeBackend, PjrtBackend)> {
    let dir = artifacts_dir()?;
    let mut data = SynthMnist::generate(640, seed);
    data.subtract_mean(None);
    let engine = Engine::open(&dir).expect("engine");
    let pjrt =
        PjrtBackend::new(engine, "lenet300", data.clone(), None, seed).expect("pjrt backend");
    let batch = pjrt.batch_size();
    let net = Mlp::new(&MlpSpec::lenet300(), seed);
    let mut native = NativeBackend::new(net, data, None, batch, seed);
    // force identical parameters
    let mut pjrt = pjrt;
    native.set_weights(&pjrt.weights());
    native.set_biases(&pjrt.biases());
    Some((native, pjrt))
}

#[test]
fn grad_step_matches_native_backend() {
    let Some((mut native, mut pjrt)) = paired_backends(31) else {
        return;
    };
    // identical batcher seeds → identical minibatch order
    let (loss_n, g_n) = native.next_loss_grads();
    let (loss_p, g_p) = pjrt.next_loss_grads();
    assert!(
        (loss_n - loss_p).abs() < 1e-4 * loss_n.abs().max(1.0),
        "losses differ: native {loss_n} pjrt {loss_p}"
    );
    for l in 0..native.n_layers() {
        let max_dev = g_n
            .w_layer(l)
            .iter()
            .zip(g_p.w_layer(l))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_dev < 1e-4, "layer {l} dw max dev {max_dev}");
        let max_dev_b = g_n
            .b_layer(l)
            .iter()
            .zip(g_p.b_layer(l))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_dev_b < 1e-4, "layer {l} db max dev {max_dev_b}");
    }
}

#[test]
fn eval_matches_native_backend() {
    let Some((mut native, mut pjrt)) = paired_backends(37) else {
        return;
    };
    let (ln, en) = native.eval_train();
    let (lp, ep) = pjrt.eval_train();
    // pjrt walks ⌊n/B⌋ full batches = all 640 samples here
    assert!((ln - lp).abs() < 1e-4 * ln.max(1.0), "loss {ln} vs {lp}");
    assert!((en - ep).abs() < 0.5, "err {en}% vs {ep}%");
}

#[test]
fn sgd_training_descends_on_pjrt() {
    let Some((_, mut pjrt)) = paired_backends(41) else {
        return;
    };
    let (l0, _) = pjrt.eval_train();
    let mut opt = FlatNesterov::new(pjrt.layout(), 0.9);
    run_sgd(&mut pjrt, &mut opt, 30, 0.1, None);
    let (l1, _) = pjrt.eval_train();
    assert!(l1 < l0 * 0.9, "pjrt SGD did not descend: {l0} -> {l1}");
}

#[test]
fn lc_runs_end_to_end_on_pjrt_backend() {
    let Some((_, mut pjrt)) = paired_backends(43) else {
        return;
    };
    // brief reference training then a short LC run at K=2
    let mut opt = FlatNesterov::new(pjrt.layout(), 0.9);
    run_sgd(&mut pjrt, &mut opt, 40, 0.1, None);
    let cfg = LcConfig {
        scheme: Scheme::AdaptiveCodebook { k: 2 },
        mu: MuSchedule::new(1e-2, 1.6),
        iterations: 6,
        l_steps: 10,
        lr: ClippedLrSchedule { eta0: 0.05, decay: 0.98 },
        momentum: 0.9,
        mode: PenaltyMode::AugmentedLagrangian,
        tol: 0.0,
        seed: 1,
        eval_every: 0,
        n_weight_samples: 0,
    };
    let res = lc_quantize(&mut pjrt, &cfg);
    assert!(res.train_loss.is_finite());
    for (wl, cb) in res.wc.iter().zip(&res.codebooks) {
        assert_eq!(cb.len(), 2);
        for v in wl {
            assert!(cb.iter().any(|c| (c - v).abs() < 1e-6));
        }
    }
}

#[test]
fn linreg_lstep_artifact_matches_cholesky() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    use lcquant::data::superres::SuperResData;
    use lcquant::experiments::fig7_linreg::LinRegLc;
    use lcquant::linalg::Mat;
    use lcquant::runtime::{literal_f32, to_vec_f32};

    let mut engine = Engine::open(&dir).expect("engine");
    let data = SuperResData::generate(300, 0.05, 7);
    let mut lr = LinRegLc::new(&data);
    let target = Mat::zeros(lr.d_out, lr.d_in);
    let mu = 0.5f32;
    lr.solve_penalized(&target, mu).unwrap();
    let rust_w = lr.w.clone();

    // assemble the same system the rust Cholesky solved (target = 0)
    let d = lr.d_in + 1;
    let (a, rhs) = lr.assemble_system(&target, mu);
    let eye = Mat::eye(d);
    let out = engine
        .execute(
            "linreg_lstep",
            &[
                literal_f32(&a.data, &[d, d]).unwrap(),
                literal_f32(&rhs.data, &[lr.d_out, d]).unwrap(),
                literal_f32(&eye.data, &[d, d]).unwrap(),
            ],
        )
        .expect("linreg artifact");
    let w_pjrt = to_vec_f32(&out[0]).unwrap();
    assert_eq!(w_pjrt.len(), rust_w.data.len());
    let mut max_dev = 0.0f32;
    for (a, b) in rust_w.data.iter().zip(&w_pjrt) {
        max_dev = max_dev.max((a - b).abs());
    }
    assert!(
        max_dev < 5e-3,
        "linreg L-step: rust-Cholesky vs AOT-solve max dev {max_dev}"
    );
}
