//! Integration tests for the bit-sliced serving tier and the zero-copy
//! `.lcq` load path, end to end:
//!
//! * the bit-sliced engine agrees with the LUT gather tier (small
//!   tolerance — the two tiers sum in different orders) across **every**
//!   quantization scheme, in-process and over a real TCP loopback;
//! * a memory-mapped model serves **bit-identically** to the same model
//!   loaded eagerly (same kernels, same plane bytes);
//! * a corrupt plane section is *not* rejected at `load_mmap` time — the
//!   registry loads, and the damage surfaces as a checksum **error** (not
//!   a panic) on the first forward pass, in-process and through the
//!   micro-batch server;
//! * the warm serve path performs **zero heap allocations** on both
//!   tiers (counting-allocator discipline from `rust/tests/obs.rs`);
//! * `EngineMode::Auto` dispatch picks the documented per-layer paths
//!   when models arrive through `Registry::load_dir_with`;
//! * `docs/lcq-format.md` v2 and `docs/ARCHITECTURE.md` keep describing
//!   the on-disk contract and the two-tier engine (doc pinning).
//!
//! `ci.sh` and `make tier1` run this file under the default thread policy
//! and again with `LCQUANT_THREADS=2`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use lcquant::linalg::Mat;
use lcquant::net::{NetClient, NetConfig, NetServer};
use lcquant::nn::{Activation, MlpSpec};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{
    EngineMode, EngineScratch, LutEngine, MicroBatchServer, PackedModel, Registry, ServerConfig,
};
use lcquant::util::rng::Rng;

// ---- counting allocator (obs.rs discipline): thread-local counter so
//      sibling test threads can't perturb the zero-alloc assertions ------

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn thread_allocs() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// ---- fixtures -----------------------------------------------------------

/// Every scheme the quantizer knows, named for use as registry keys.
fn all_schemes() -> Vec<(&'static str, Scheme)> {
    vec![
        ("binary", Scheme::Binary),
        ("binary-scale", Scheme::BinaryScale),
        ("ternary", Scheme::Ternary),
        ("ternary-scale", Scheme::TernaryScale),
        ("pow2", Scheme::PowersOfTwo { c: 3 }),
        ("adaptive4", Scheme::AdaptiveCodebook { k: 4 }),
        ("adaptive16", Scheme::AdaptiveCodebook { k: 16 }),
        ("fixed", Scheme::FixedCodebook { codebook: vec![-0.5, 0.0, 0.5, 1.0] }),
        ("adaptive-zero4", Scheme::AdaptiveWithZero { k: 4 }),
    ]
}

fn toy_packed(name: &str, scheme: &Scheme, seed: u64, sizes: &[usize]) -> PackedModel {
    let spec = MlpSpec {
        sizes: sizes.to_vec(),
        hidden_activation: Activation::Tanh,
        dropout_keep: vec![],
    };
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn random_batch(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut x = Mat::zeros(rows, cols);
    Rng::new(seed).fill_normal(&mut x.data, 0.0, 1.0);
    x
}

/// Fresh temp dir; callers clean it up themselves.
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lcquant_bitslice_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Max |a−b| scaled by magnitude: the two tiers reduce in different
/// orders, so agreement is to float tolerance, not bit-exact.
fn assert_close(a: &Mat, b: &Mat, tol: f32, ctx: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
    for (i, (&x, &y)) in a.data.iter().zip(&b.data).enumerate() {
        let scale = 1.0f32.max(x.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "{ctx}: logit {i} diverges: lut={x} bitsliced={y}"
        );
    }
}

// ---- 1. tier parity across every scheme ---------------------------------

#[test]
fn bitsliced_matches_lut_within_tolerance_all_schemes() {
    for (name, scheme) in all_schemes() {
        let packed = toy_packed(name, &scheme, 31, &[13, 9, 5]);
        let lut = LutEngine::with_mode(&packed, EngineMode::Lut).unwrap();
        let bit = LutEngine::with_mode(&packed, EngineMode::BitSliced).unwrap();
        let auto = LutEngine::with_mode(&packed, EngineMode::Auto).unwrap();
        let x = random_batch(7, 13, 77);
        let want = lut.forward(&x).unwrap();
        assert_close(&want, &bit.forward(&x).unwrap(), 1e-3, name);
        // Auto must agree with the explicit bit-sliced tier bit for bit:
        // it picks the same per-layer paths
        let a = auto.forward(&x).unwrap();
        let b = bit.forward(&x).unwrap();
        assert_eq!(auto.layer_paths(), bit.layer_paths(), "{name}: auto vs bitsliced dispatch");
        for (p, q) in a.data.iter().zip(&b.data) {
            assert_eq!(p.to_bits(), q.to_bits(), "{name}: auto must equal bitsliced bitwise");
        }
    }
}

// ---- 2. mmap load is bit-identical to eager load ------------------------

#[test]
fn mmap_engine_is_bit_identical_to_eager_engine() {
    let dir = temp_dir("mmap_parity");
    for (name, scheme) in all_schemes() {
        let packed = toy_packed(name, &scheme, 41, &[12, 8, 4]);
        let path = dir.join(format!("{name}.lcq"));
        packed.save(&path).unwrap();
        let eager = PackedModel::load(&path).unwrap();
        let mapped = PackedModel::load_mmap(&path).unwrap();
        let x = random_batch(5, 12, 99);
        for mode in [EngineMode::Auto, EngineMode::Lut, EngineMode::BitSliced] {
            let ye = LutEngine::with_mode(&eager, mode).unwrap().forward(&x).unwrap();
            let ym = LutEngine::with_mode(&mapped, mode).unwrap().forward(&x).unwrap();
            for (e, m) in ye.data.iter().zip(&ym.data) {
                assert_eq!(
                    e.to_bits(),
                    m.to_bits(),
                    "{name}/{mode}: mmap and eager loads must serve identically"
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- 3. loopback e2e: bit-sliced registry over real TCP -----------------

#[test]
fn loopback_e2e_bitsliced_serves_all_schemes() {
    let dir = temp_dir("loopback");
    let mut reference = Vec::new();
    for (name, scheme) in all_schemes() {
        let packed = toy_packed(name, &scheme, 51, &[10, 7, 3]);
        packed.save(&dir.join(format!("{name}.lcq"))).unwrap();
        reference.push((name, LutEngine::with_mode(&packed, EngineMode::Lut).unwrap()));
    }
    let reg = Arc::new(Registry::load_dir_with(&dir, EngineMode::BitSliced).unwrap());
    let _ = std::fs::remove_dir_all(&dir); // mapped pages outlive the unlink
    let serve = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        pipeline_depth: 2,
    };
    let net = NetConfig {
        bind_addr: "127.0.0.1:0".to_string(),
        max_connections: 4,
        ..NetConfig::default()
    };
    let mut server = NetServer::start(reg, serve, net).expect("bind loopback server");
    let addr = server.local_addr().to_string();

    let mut client = NetClient::connect(&addr).expect("connect");
    let mut rng = Rng::new(500);
    for (name, lut) in &reference {
        for _ in 0..3 {
            let mut input = vec![0.0f32; lut.in_dim()];
            rng.fill_normal(&mut input, 0.0, 1.0);
            let got = client.infer(name, &input).expect("infer over TCP");
            let mut x = Mat::zeros(1, lut.in_dim());
            x.row_mut(0).copy_from_slice(&input);
            let want = lut.forward(&x).unwrap();
            assert_eq!(got.len(), want.cols);
            let mut y = Mat::zeros(1, want.cols);
            y.data.copy_from_slice(&got);
            assert_close(&want, &y, 1e-3, name);
        }
    }
    drop(client);
    server.stop();
}

// ---- 4. corruption surfaces lazily, as an error, never a panic ----------

#[test]
fn corrupt_section_loads_but_fails_at_forward_with_checksum_error() {
    let dir = temp_dir("corrupt");
    // binary → every layer takes the sign-pop bit path, so engine build
    // never touches the plane words and the damage stays latent
    toy_packed("damaged", &Scheme::Binary, 61, &[12, 8, 4])
        .save(&dir.join("damaged.lcq"))
        .unwrap();
    toy_packed("healthy", &Scheme::TernaryScale, 62, &[12, 8, 4])
        .save(&dir.join("healthy.lcq"))
        .unwrap();
    // flip one byte in the last plane section (the file ends exactly at
    // the last section's end, so the final byte is section payload)
    let path = dir.join("damaged.lcq");
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();

    // eager load rejects up front …
    let err = PackedModel::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("checksum"), "eager: {err:#}");

    // … the zero-copy registry load succeeds (header is intact) …
    let reg = Arc::new(Registry::load_dir_with(&dir, EngineMode::Auto).unwrap());
    assert_eq!(reg.names(), vec!["damaged", "healthy"]);

    // … and the first forward through the damaged plane is a loud error
    let x = random_batch(2, 12, 7);
    let err = reg.infer("damaged", &x).unwrap_err();
    assert!(format!("{err:#}").contains("checksum"), "forward: {err:#}");
    // sticky: it keeps failing, and the healthy sibling is unaffected
    assert!(reg.infer("damaged", &x).is_err());
    assert!(reg.infer("healthy", &x).is_ok());

    // the micro-batch server reports the same failure as a typed error
    // string instead of dying
    let server = MicroBatchServer::start(
        Arc::clone(&reg),
        ServerConfig { max_batch: 4, max_wait: Duration::from_millis(1), pipeline_depth: 1 },
    );
    let client = server.client();
    let err = client.infer("damaged", vec![0.0; 12]).unwrap_err();
    assert!(err.contains("checksum"), "server error: {err}");
    let ok = client.infer("healthy", vec![0.0; 12]);
    assert!(ok.is_ok(), "healthy model must keep serving: {ok:?}");
    drop(client);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- 5. warm serve path allocates nothing -------------------------------

#[test]
fn warm_forward_into_performs_zero_allocations_on_both_tiers() {
    // ternary-scale exercises the block-sums scratch; adaptive-4 takes
    // the coded-k accumulator; the LUT tier is the gather baseline.
    // Batch work (4·12·8) is far below the parallel threshold, so every
    // forward runs on the calling thread and the thread-local counter
    // sees all of it.
    for (scheme, mode) in [
        (Scheme::TernaryScale, EngineMode::BitSliced),
        (Scheme::AdaptiveCodebook { k: 4 }, EngineMode::BitSliced),
        (Scheme::Binary, EngineMode::BitSliced),
        (Scheme::PowersOfTwo { c: 3 }, EngineMode::BitSliced),
        (Scheme::AdaptiveCodebook { k: 4 }, EngineMode::Lut),
    ] {
        let packed = toy_packed("warm", &scheme, 71, &[12, 8, 4]);
        let engine = LutEngine::with_mode(&packed, mode).unwrap();
        let x = random_batch(4, 12, 13);
        let mut scratch = EngineScratch::new();
        // warm: scratch buffers and block sums size themselves here
        let _ = engine.forward_into(&x, &mut scratch).unwrap();
        let _ = engine.forward_into(&x, &mut scratch).unwrap();
        let before = thread_allocs();
        for _ in 0..50 {
            let y = engine.forward_into(&x, &mut scratch).unwrap();
            assert_eq!(y.rows, 4);
        }
        let delta = thread_allocs() - before;
        assert_eq!(delta, 0, "warm serve path must not allocate ({scheme:?} {mode})");
    }
}

// ---- 6. auto dispatch through the registry ------------------------------

#[test]
fn registry_auto_dispatch_picks_documented_paths() {
    let dir = temp_dir("dispatch");
    for (name, scheme) in [
        ("binary", Scheme::Binary),
        ("ternary", Scheme::Ternary),
        ("pow2", Scheme::PowersOfTwo { c: 3 }),
        ("adaptive4", Scheme::AdaptiveCodebook { k: 4 }),
    ] {
        toy_packed(name, &scheme, 81, &[12, 8, 4]).save(&dir.join(format!("{name}.lcq"))).unwrap();
    }
    let auto = Registry::load_dir_with(&dir, EngineMode::Auto).unwrap();
    let expect = [
        ("binary", "sign-pop"),
        ("ternary", "ternary-pop"),
        ("pow2", "coded-pow2"),
        ("adaptive4", "coded-k"),
    ];
    for (name, path) in expect {
        let m = auto.get(name).unwrap();
        assert_eq!(m.engine.mode(), EngineMode::Auto);
        assert_eq!(m.engine.layer_paths(), vec![path; 2], "auto dispatch for {name}");
    }
    // forcing the gather tier flips every layer to a lut-* path
    let lut = Registry::load_dir_with(&dir, EngineMode::Lut).unwrap();
    for name in ["binary", "ternary", "pow2", "adaptive4"] {
        for p in lut.get(name).unwrap().engine.layer_paths() {
            assert!(p.starts_with("lut-"), "{name}: forced LUT tier got '{p}'");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- 7. doc pinning -----------------------------------------------------

fn doc(path: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join(path);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

#[test]
fn format_doc_pins_the_v2_contract() {
    let text = doc("docs/lcq-format.md");
    for needle in [
        "version: u32 = 2",
        "64-byte",
        "column-major",
        "FNV-1a 64",
        "plane 0 = sign",
        "plane 1 = mask",
        "canonical",
        "spec_size_equation_matches_written_bytes",
        "payload_bits_match_ratio_accounting",
        "column_major_plane_layout_is_pinned",
        "load_mmap",
    ] {
        assert!(text.contains(needle), "lcq-format.md lost '{needle}'");
    }
}

#[test]
fn architecture_doc_describes_the_two_tier_engine() {
    let text = doc("docs/ARCHITECTURE.md");
    for needle in ["bit-sliced", "load_mmap", "sign-pop", "lazily"] {
        assert!(text.contains(needle), "ARCHITECTURE.md lost '{needle}'");
    }
}
