//! Loopback end-to-end tests for the network serving plane: real TCP
//! sockets on 127.0.0.1 (ephemeral ports), the full pack → registry →
//! `NetServer` → `NetClient` path.
//!
//! The load-bearing assertions:
//!
//! * responses are **bit-identical** to a direct `LutEngine::forward_into`
//!   on the same input (the wire encodes f32 bit patterns verbatim, and
//!   the engine's pre-staged-row path is bit-equal to the Mat path);
//! * the overload-shed paths (in-flight row budget, connection limit)
//!   answer with typed `Overloaded` errors instead of queueing or dying;
//! * malformed/truncated/oversized frames are rejected with an error
//!   frame and a closed connection — never a panic.
//!
//! `ci.sh` and `make tier1` run this file under the default thread policy
//! and again with `LCQUANT_THREADS=2` (the loopback smoke test).

use lcquant::linalg::{pool, Mat};
use lcquant::net::proto::{self, ErrorCode, ErrorFrame, Frame, FrameReader, RequestFrame};
use lcquant::net::{ClientError, NetClient, NetConfig, NetServer};
use lcquant::nn::{Activation, MlpSpec};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{EngineScratch, LutEngine, PackedModel, Registry, ServerConfig};
use lcquant::util::json::Json;
use lcquant::util::rng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn toy_packed(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec {
        sizes: vec![12, 8, 4],
        hidden_activation: Activation::Tanh,
        dropout_keep: vec![],
    };
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn toy_registry() -> (Arc<Registry>, PackedModel) {
    let packed = toy_packed("toy-k4", &Scheme::AdaptiveCodebook { k: 4 }, 11);
    let mut reg = Registry::new();
    reg.insert(packed.clone()).unwrap();
    reg.insert(toy_packed("toy-binary", &Scheme::BinaryScale, 12)).unwrap();
    (Arc::new(reg), packed)
}

/// Loopback server with an ephemeral port; returns it ready to accept.
fn start_server(reg: Arc<Registry>, net: NetConfig) -> NetServer {
    let serve = ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(1),
        pipeline_depth: 2,
    };
    NetServer::start(reg, serve, net).expect("bind loopback server")
}

fn loopback_cfg() -> NetConfig {
    NetConfig {
        bind_addr: "127.0.0.1:0".to_string(),
        // keep the per-server handler pool small: the test binary runs
        // many servers concurrently
        max_connections: 8,
        ..NetConfig::default()
    }
}

#[test]
fn loopback_roundtrip_bit_identical_to_engine() {
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();

    // N concurrent connections, each its own client + rng, every response
    // compared bit-for-bit against the in-process engine
    let n_conns = 4usize;
    let per_conn = 8usize;
    pool::run_scoped(n_conns, |c| {
        let mut client = NetClient::connect(&addr).expect("connect");
        let mut rng = Rng::new(400 + c as u64);
        let mut scratch = EngineScratch::new();
        for _ in 0..per_conn {
            let mut input = vec![0.0f32; engine.in_dim()];
            rng.fill_normal(&mut input, 0.0, 1.0);
            let got = client.infer("toy-k4", &input).expect("infer over TCP");
            let mut x = Mat::zeros(1, engine.in_dim());
            x.row_mut(0).copy_from_slice(&input);
            let want = engine.forward_into(&x, &mut scratch).unwrap();
            assert_eq!(got.len(), want.cols);
            for (g, w) in got.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), w.to_bits(), "conn {c}: logits must be bit-identical");
            }
        }
    });

    let mut server = server;
    server.stop();
    let stats = server.stats();
    assert_eq!(stats.requests_ok, (n_conns * per_conn) as u64);
    assert_eq!(stats.requests_shed, 0);
    assert_eq!(stats.requests_failed, 0);
    assert!(stats.connections >= n_conns as u64);
}

#[test]
fn hello_catalog_advertises_models_and_dims() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    let models = client.models().unwrap();
    let names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
    assert_eq!(names, vec!["toy-binary", "toy-k4"]);
    for m in &models {
        assert_eq!(m.in_dim, 12);
        assert_eq!(m.out_dim, 4);
    }
}

#[test]
fn batch_request_matches_batched_engine_forward() {
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();

    let rows = 5usize;
    let mut rng = Rng::new(77);
    let mut x = Mat::zeros(rows, engine.in_dim());
    rng.fill_normal(&mut x.data, 0.0, 1.0);
    let got = client.infer_batch("toy-k4", rows, &x.data).unwrap();
    let want = engine.forward(&x).unwrap();
    assert_eq!(got.len(), rows * engine.out_dim());
    for (g, w) in got.iter().zip(&want.data) {
        assert_eq!(g.to_bits(), w.to_bits());
    }
}

#[test]
fn unknown_model_and_wrong_dims_are_typed_errors() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    match client.infer("ghost", &[0.0; 12]) {
        Err(ClientError::Remote { code: ErrorCode::UnknownModel, .. }) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    match client.infer("toy-k4", &[0.0; 3]) {
        Err(ClientError::Remote { code: ErrorCode::WrongDims, .. }) => {}
        other => panic!("expected WrongDims, got {other:?}"),
    }
    // the connection survives typed errors: a valid request still works
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
}

#[test]
fn inflight_budget_sheds_with_overloaded() {
    let (reg, _) = toy_registry();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            inflight_budget: 1, // single rows fit; any batch ≥ 2 cannot
            ..NetConfig::default()
        },
    );
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    // one row fits the budget
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
    // a 2-row batch can never fit a budget of 1 → deterministic shed
    let err = client.infer_batch("toy-k4", 2, &[0.0; 24]).unwrap_err();
    assert!(err.is_overloaded(), "expected overload shed, got {err:?}");
    // shedding is not fatal: the connection keeps serving
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
    assert_eq!(server.stats().requests_shed, 1);
}

#[test]
fn connection_limit_sheds_at_the_door() {
    let (reg, _) = toy_registry();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 1, // one handler; accept backlog of one
            ..NetConfig::default()
        },
    );
    let addr = server.local_addr().to_string();
    // c1 completes its handshake ⇒ the single handler owns it and the
    // accept backlog is empty again
    let _c1 = NetClient::connect(&addr).expect("first connection");
    // c2 occupies the backlog (its handshake stays pending); raw TCP so
    // nothing blocks here
    let _c2 = TcpStream::connect(&addr).expect("second connection queues");
    // brief pause so the acceptor has queued c2 before c3 arrives
    std::thread::sleep(Duration::from_millis(50));
    // c3 finds handler + backlog full ⇒ shed with a typed handshake error
    match NetClient::connect(&addr) {
        Err(e) if e.is_overloaded() => {}
        other => panic!("expected Overloaded handshake, got {other:?}"),
    }
    assert_eq!(server.stats().connections_shed, 1);
}

/// Raw-socket handshake helper: returns the stream after the client
/// preamble is sent and the server preamble + hello frame are consumed.
fn raw_handshake(addr: &str) -> (TcpStream, FrameReader) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(&proto::encode_preamble()).unwrap();
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    stream.read_exact(&mut pre).unwrap();
    assert_eq!(proto::decode_preamble(&pre).unwrap(), proto::VERSION);
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Hello(_))) => return (stream, reader),
            Ok(Some(f)) => panic!("expected hello, got {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("handshake failed: {e}"),
        }
    }
}

/// Read frames until the peer closes; returns the last error frame seen.
fn read_error_then_eof(stream: &mut TcpStream, reader: &mut FrameReader) -> Option<ErrorFrame> {
    let mut last = None;
    loop {
        match reader.poll_frame(stream) {
            Ok(Some(Frame::Error(e))) => last = Some(e),
            Ok(Some(f)) => panic!("unexpected frame {f:?}"),
            Ok(None) => continue,
            Err(_) => return last, // closed (or mid-frame EOF)
        }
    }
}

#[test]
fn corrupt_checksum_answered_with_malformed_then_close() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let (mut stream, mut reader) = raw_handshake(&server.local_addr().to_string());
    // valid request frame, one payload byte flipped after checksumming
    let mut bytes = Frame::Request(RequestFrame {
        id: 5,
        model: "toy-k4".to_string(),
        rows: 1,
        cols: 12,
        data: vec![0.0; 12],
    })
    .to_bytes();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    stream.write_all(&bytes).unwrap();
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::Malformed);
}

#[test]
fn oversized_frame_answered_with_malformed_then_close() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let (mut stream, mut reader) = raw_handshake(&server.local_addr().to_string());
    // announce a payload far beyond the frame cap; send nothing else —
    // the server must reject from the prefix alone, without buffering
    stream.write_all(&(1u32 << 31).to_le_bytes()).unwrap();
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::Malformed);
}

#[test]
fn bad_magic_is_dropped_silently() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(b"HTTP/1.1").unwrap();
    // not our protocol: the server closes without writing anything
    let mut buf = [0u8; 16];
    let n = stream.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must not reply to a foreign protocol");
}

#[test]
fn version_mismatch_gets_unsupported_version() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut pre = proto::encode_preamble();
    pre[4..8].copy_from_slice(&9u32.to_le_bytes()); // future version
    stream.write_all(&pre).unwrap();
    let mut spre = [0u8; proto::PREAMBLE_LEN];
    stream.read_exact(&mut spre).unwrap();
    assert_eq!(proto::decode_preamble(&spre).unwrap(), proto::VERSION);
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::UnsupportedVersion);
}

#[test]
fn truncated_frame_then_close_is_survived() {
    // a client that dies mid-frame must not wedge or kill the handler:
    // the server just closes; a new connection still works
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();
    {
        let (mut stream, _) = raw_handshake(&addr);
        let bytes = Frame::Request(RequestFrame {
            id: 1,
            model: "toy-k4".to_string(),
            rows: 1,
            cols: 12,
            data: vec![0.0; 12],
        })
        .to_bytes();
        stream.write_all(&bytes[..bytes.len() / 2]).unwrap();
        // drop mid-frame
    }
    let mut client = NetClient::connect(&addr).expect("fresh connection after abuse");
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
}

#[test]
fn stats_frame_and_snapshots_survive_stop() {
    let (reg, _) = toy_registry();
    let mut server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();
    let mut client = NetClient::connect(&addr).unwrap();
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
    // live: the v2 stats frame answers on the inference connection
    let body = client.stats().expect("stats over the wire");
    let snap = Json::parse(&body).expect("snapshot JSON");
    assert_eq!(
        snap.get("server").unwrap().get("requests_ok").unwrap().as_f64().unwrap(),
        1.0
    );
    server.stop();
    // the snapshot path reads stats shared with the (now stopped) batch
    // server, so it stays valid after stop — no stale cached copy
    let snap = Json::parse(&server.snapshot_json()).expect("post-stop snapshot JSON");
    assert_eq!(
        snap.get("batch").unwrap().get("requests").unwrap().as_f64().unwrap(),
        1.0
    );
    assert_eq!(server.batch_stats().requests, 1);
    assert_eq!(server.stats().stats_requests, 1);
}

#[test]
fn stop_is_clean_and_idempotent() {
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let mut server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();
    let mut client = NetClient::connect(&addr).unwrap();
    let input = vec![0.25f32; engine.in_dim()];
    let got = client.infer("toy-k4", &input).unwrap();
    let mut x = Mat::zeros(1, engine.in_dim());
    x.row_mut(0).copy_from_slice(&input);
    assert_eq!(got, engine.forward(&x).unwrap().row(0).to_vec());
    server.stop();
    server.stop(); // idempotent
    // stats survive the stop: the one answered request is on record
    assert_eq!(server.stats().requests_ok, 1);
    assert_eq!(server.batch_stats().requests, 1);
    // (no assertion on post-stop connects: the ephemeral port may be
    // re-bound by a concurrently running test's server)
}
