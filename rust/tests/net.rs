//! Loopback end-to-end tests for the network serving plane: real TCP
//! sockets on 127.0.0.1 (ephemeral ports), the full pack → registry →
//! `NetServer` → `NetClient` path.
//!
//! The load-bearing assertions:
//!
//! * responses are **bit-identical** to a direct `LutEngine::forward_into`
//!   on the same input (the wire encodes f32 bit patterns verbatim, and
//!   the engine's pre-staged-row path is bit-equal to the Mat path);
//! * the overload-shed paths (in-flight row budget, connection limit)
//!   answer with typed `Overloaded` errors instead of queueing or dying;
//! * malformed/truncated/oversized frames are rejected with an error
//!   frame and a closed connection — never a panic.
//!
//! `ci.sh` and `make tier1` run this file under the default thread policy
//! and again with `LCQUANT_THREADS=2` (the loopback smoke test).

use lcquant::linalg::{pool, Mat};
use lcquant::net::proto::{
    self, ErrorCode, ErrorFrame, FleetStatsRequestFrame, FleetStatsResponseFrame, Frame,
    FrameReader, HelloFrame, ModelEntry, RequestFrame, ResponseFrame, StatsRequestFrame,
    StatsResponseFrame, TraceContext, WireError,
};
use lcquant::net::{ClientError, NetClient, NetConfig, NetServer};
use lcquant::nn::{Activation, MlpSpec};
use lcquant::quant::{LayerQuantizer, Scheme};
use lcquant::serve::{EngineScratch, LutEngine, PackedModel, Registry, ServerConfig};
use lcquant::util::json::Json;
use lcquant::util::rng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn toy_packed(name: &str, scheme: &Scheme, seed: u64) -> PackedModel {
    let spec = MlpSpec {
        sizes: vec![12, 8, 4],
        hidden_activation: Activation::Tanh,
        dropout_keep: vec![],
    };
    let mut rng = Rng::new(seed);
    let mut codebooks = Vec::new();
    let mut assignments = Vec::new();
    let mut biases = Vec::new();
    for l in 0..spec.n_layers() {
        let n = spec.sizes[l] * spec.sizes[l + 1];
        let w: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.5)).collect();
        let out = LayerQuantizer::new(scheme.clone(), seed + l as u64).compress(&w);
        codebooks.push(out.codebook);
        assignments.push(out.assignments);
        biases.push((0..spec.sizes[l + 1]).map(|_| rng.normal(0.0, 0.1)).collect());
    }
    PackedModel::from_parts(name, &spec, scheme, &codebooks, &assignments, &biases).unwrap()
}

fn toy_registry() -> (Arc<Registry>, PackedModel) {
    let packed = toy_packed("toy-k4", &Scheme::AdaptiveCodebook { k: 4 }, 11);
    let mut reg = Registry::new();
    reg.insert(packed.clone()).unwrap();
    reg.insert(toy_packed("toy-binary", &Scheme::BinaryScale, 12)).unwrap();
    (Arc::new(reg), packed)
}

/// Loopback server with an ephemeral port; returns it ready to accept.
fn start_server(reg: Arc<Registry>, net: NetConfig) -> NetServer {
    let serve = ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(1),
        pipeline_depth: 2,
    };
    NetServer::start(reg, serve, net).expect("bind loopback server")
}

fn loopback_cfg() -> NetConfig {
    NetConfig {
        bind_addr: "127.0.0.1:0".to_string(),
        // keep the per-server handler pool small: the test binary runs
        // many servers concurrently
        max_connections: 8,
        ..NetConfig::default()
    }
}

#[test]
fn loopback_roundtrip_bit_identical_to_engine() {
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();

    // N concurrent connections, each its own client + rng, every response
    // compared bit-for-bit against the in-process engine
    let n_conns = 4usize;
    let per_conn = 8usize;
    pool::run_scoped(n_conns, |c| {
        let mut client = NetClient::connect(&addr).expect("connect");
        let mut rng = Rng::new(400 + c as u64);
        let mut scratch = EngineScratch::new();
        for _ in 0..per_conn {
            let mut input = vec![0.0f32; engine.in_dim()];
            rng.fill_normal(&mut input, 0.0, 1.0);
            let got = client.infer("toy-k4", &input).expect("infer over TCP");
            let mut x = Mat::zeros(1, engine.in_dim());
            x.row_mut(0).copy_from_slice(&input);
            let want = engine.forward_into(&x, &mut scratch).unwrap();
            assert_eq!(got.len(), want.cols);
            for (g, w) in got.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), w.to_bits(), "conn {c}: logits must be bit-identical");
            }
        }
    });

    let mut server = server;
    server.stop();
    let stats = server.stats();
    assert_eq!(stats.requests_ok, (n_conns * per_conn) as u64);
    assert_eq!(stats.requests_shed, 0);
    assert_eq!(stats.requests_failed, 0);
    assert!(stats.connections >= n_conns as u64);
}

#[test]
fn hello_catalog_advertises_models_and_dims() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    let models = client.models().unwrap();
    let names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
    assert_eq!(names, vec!["toy-binary", "toy-k4"]);
    for m in &models {
        assert_eq!(m.in_dim, 12);
        assert_eq!(m.out_dim, 4);
    }
}

#[test]
fn batch_request_matches_batched_engine_forward() {
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();

    let rows = 5usize;
    let mut rng = Rng::new(77);
    let mut x = Mat::zeros(rows, engine.in_dim());
    rng.fill_normal(&mut x.data, 0.0, 1.0);
    let got = client.infer_batch("toy-k4", rows, &x.data).unwrap();
    let want = engine.forward(&x).unwrap();
    assert_eq!(got.len(), rows * engine.out_dim());
    for (g, w) in got.iter().zip(&want.data) {
        assert_eq!(g.to_bits(), w.to_bits());
    }
}

#[test]
fn unknown_model_and_wrong_dims_are_typed_errors() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    match client.infer("ghost", &[0.0; 12]) {
        Err(ClientError::Remote { code: ErrorCode::UnknownModel, .. }) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    match client.infer("toy-k4", &[0.0; 3]) {
        Err(ClientError::Remote { code: ErrorCode::WrongDims, .. }) => {}
        other => panic!("expected WrongDims, got {other:?}"),
    }
    // the connection survives typed errors: a valid request still works
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
}

#[test]
fn inflight_budget_sheds_with_overloaded() {
    let (reg, _) = toy_registry();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            inflight_budget: 1, // single rows fit; any batch ≥ 2 cannot
            ..NetConfig::default()
        },
    );
    let mut client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    // one row fits the budget
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
    // a 2-row batch can never fit a budget of 1 → deterministic shed
    let err = client.infer_batch("toy-k4", 2, &[0.0; 24]).unwrap_err();
    assert!(err.is_overloaded(), "expected overload shed, got {err:?}");
    // shedding is not fatal: the connection keeps serving
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
    assert_eq!(server.stats().requests_shed, 1);
}

#[test]
fn connection_limit_sheds_at_the_door() {
    let (reg, _) = toy_registry();
    let server = start_server(
        Arc::clone(&reg),
        NetConfig {
            bind_addr: "127.0.0.1:0".to_string(),
            max_connections: 1, // one handler; accept backlog of one
            ..NetConfig::default()
        },
    );
    let addr = server.local_addr().to_string();
    // c1 completes its handshake ⇒ the single handler owns it and the
    // accept backlog is empty again
    let _c1 = NetClient::connect(&addr).expect("first connection");
    // c2 occupies the backlog (its handshake stays pending); raw TCP so
    // nothing blocks here
    let _c2 = TcpStream::connect(&addr).expect("second connection queues");
    // brief pause so the acceptor has queued c2 before c3 arrives
    std::thread::sleep(Duration::from_millis(50));
    // c3 finds handler + backlog full ⇒ shed with a typed handshake error
    match NetClient::connect(&addr) {
        Err(e) if e.is_overloaded() => {}
        other => panic!("expected Overloaded handshake, got {other:?}"),
    }
    assert_eq!(server.stats().connections_shed, 1);
}

/// Raw-socket handshake helper: returns the stream after the client
/// preamble is sent and the server preamble + hello frame are consumed.
fn raw_handshake(addr: &str) -> (TcpStream, FrameReader) {
    raw_handshake_as(addr, proto::VERSION)
}

/// Like [`raw_handshake`] but announcing an arbitrary client protocol
/// version in the preamble (the server accepts `MIN_VERSION..=VERSION`
/// and records the peer's version for per-connection compat decisions).
fn raw_handshake_as(addr: &str, version: u32) -> (TcpStream, FrameReader) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut pre = proto::encode_preamble();
    pre[4..8].copy_from_slice(&version.to_le_bytes());
    stream.write_all(&pre).unwrap();
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    stream.read_exact(&mut pre).unwrap();
    assert_eq!(proto::decode_preamble(&pre).unwrap(), proto::VERSION);
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    loop {
        match reader.poll_frame(&mut stream) {
            Ok(Some(Frame::Hello(_))) => return (stream, reader),
            Ok(Some(f)) => panic!("expected hello, got {f:?}"),
            Ok(None) => continue,
            Err(e) => panic!("handshake failed: {e}"),
        }
    }
}

/// Read frames until the peer closes; returns the last error frame seen.
fn read_error_then_eof(stream: &mut TcpStream, reader: &mut FrameReader) -> Option<ErrorFrame> {
    let mut last = None;
    loop {
        match reader.poll_frame(stream) {
            Ok(Some(Frame::Error(e))) => last = Some(e),
            Ok(Some(f)) => panic!("unexpected frame {f:?}"),
            Ok(None) => continue,
            Err(_) => return last, // closed (or mid-frame EOF)
        }
    }
}

#[test]
fn corrupt_checksum_answered_with_malformed_then_close() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let (mut stream, mut reader) = raw_handshake(&server.local_addr().to_string());
    // valid request frame, one payload byte flipped after checksumming
    let mut bytes = Frame::Request(RequestFrame {
        id: 5,
        model: "toy-k4".to_string(),
        rows: 1,
        cols: 12,
        data: vec![0.0; 12],
        trace: None,
    })
    .to_bytes();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    stream.write_all(&bytes).unwrap();
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::Malformed);
}

#[test]
fn oversized_frame_answered_with_malformed_then_close() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let (mut stream, mut reader) = raw_handshake(&server.local_addr().to_string());
    // announce a payload far beyond the frame cap; send nothing else —
    // the server must reject from the prefix alone, without buffering
    stream.write_all(&(1u32 << 31).to_le_bytes()).unwrap();
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::Malformed);
}

#[test]
fn bad_magic_is_dropped_silently() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(b"HTTP/1.1").unwrap();
    // not our protocol: the server closes without writing anything
    let mut buf = [0u8; 16];
    let n = stream.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must not reply to a foreign protocol");
}

#[test]
fn version_mismatch_gets_unsupported_version() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut pre = proto::encode_preamble();
    pre[4..8].copy_from_slice(&9u32.to_le_bytes()); // future version
    stream.write_all(&pre).unwrap();
    let mut spre = [0u8; proto::PREAMBLE_LEN];
    stream.read_exact(&mut spre).unwrap();
    assert_eq!(proto::decode_preamble(&spre).unwrap(), proto::VERSION);
    let mut reader = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::UnsupportedVersion);
}

#[test]
fn truncated_frame_then_close_is_survived() {
    // a client that dies mid-frame must not wedge or kill the handler:
    // the server just closes; a new connection still works
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();
    {
        let (mut stream, _) = raw_handshake(&addr);
        let bytes = Frame::Request(RequestFrame {
            id: 1,
            model: "toy-k4".to_string(),
            rows: 1,
            cols: 12,
            data: vec![0.0; 12],
            trace: None,
        })
        .to_bytes();
        stream.write_all(&bytes[..bytes.len() / 2]).unwrap();
        // drop mid-frame
    }
    let mut client = NetClient::connect(&addr).expect("fresh connection after abuse");
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
}

#[test]
fn stats_frame_and_snapshots_survive_stop() {
    let (reg, _) = toy_registry();
    let mut server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();
    let mut client = NetClient::connect(&addr).unwrap();
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
    // live: the v2 stats frame answers on the inference connection
    let body = client.stats().expect("stats over the wire");
    let snap = Json::parse(&body).expect("snapshot JSON");
    assert_eq!(
        snap.get("server").unwrap().get("requests_ok").unwrap().as_f64().unwrap(),
        1.0
    );
    server.stop();
    // the snapshot path reads stats shared with the (now stopped) batch
    // server, so it stays valid after stop — no stale cached copy
    let snap = Json::parse(&server.snapshot_json()).expect("post-stop snapshot JSON");
    assert_eq!(
        snap.get("batch").unwrap().get("requests").unwrap().as_f64().unwrap(),
        1.0
    );
    assert_eq!(server.batch_stats().requests, 1);
    assert_eq!(server.stats().stats_requests, 1);
}

// ---- adversarial FrameReader split-point suite (PR 9) -------------------
//
// The event plane re-enters `FrameReader::poll_frame` with whatever bytes
// the kernel happened to deliver, so the reader must reassemble frames
// split at *any* byte boundary — and reject hostile bytes with a typed
// `WireError`, never a panic and never a desync of the frames before
// them. These tests run the reader against a byte stream served in
// hostile slices (seeded PRNG chop points, a WouldBlock before every
// slice — the nonblocking-socket waltz).

/// Serves a fixed byte stream in slices: a `WouldBlock` at every cut
/// position (each fires once), bytes between cuts, then `WouldBlock`
/// forever — or EOF (`Ok(0)`), when `eof` is set.
struct SplitReader {
    data: Vec<u8>,
    pos: usize,
    cuts: Vec<usize>, // sorted ascending; consumed front-first
    next_cut: usize,
    eof: bool,
}

impl SplitReader {
    fn new(data: Vec<u8>, mut cuts: Vec<usize>, eof: bool) -> SplitReader {
        cuts.retain(|&c| c > 0 && c < data.len());
        cuts.sort_unstable();
        cuts.dedup();
        SplitReader { data, pos: 0, cuts, next_cut: 0, eof }
    }
}

impl Read for SplitReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() {
            if self.eof {
                return Ok(0);
            }
            return Err(std::io::ErrorKind::WouldBlock.into());
        }
        if self.next_cut < self.cuts.len() && self.cuts[self.next_cut] == self.pos {
            self.next_cut += 1;
            return Err(std::io::ErrorKind::WouldBlock.into());
        }
        let stop = if self.next_cut < self.cuts.len() {
            self.cuts[self.next_cut]
        } else {
            self.data.len()
        };
        let n = stop.min(self.pos + buf.len()).min(self.data.len()) - self.pos;
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Drive one `FrameReader` over the sliced stream to the bitter end:
/// every frame it produces, plus the terminal error if the stream ends
/// in one (None = the reader just ran dry, which is the correct ending
/// for a purely valid stream).
fn run_reader(data: &[u8], cuts: Vec<usize>, eof: bool) -> (Vec<Frame>, Option<WireError>) {
    let mut src = SplitReader::new(data.to_vec(), cuts, eof);
    let mut fr = FrameReader::new(proto::DEFAULT_MAX_FRAME);
    let mut frames = Vec::new();
    let mut dry_polls = 0usize;
    loop {
        match fr.poll_frame(&mut src) {
            Ok(Some(f)) => frames.push(f),
            Ok(None) => {
                dry_polls += 1;
                if dry_polls > data.len() * 2 + 128 {
                    return (frames, None);
                }
            }
            Err(e) => return (frames, Some(e)),
        }
    }
}

/// One frame of every wire type, with awkward content on purpose:
/// id extremes, a negative-zero f32 (its sign bit must survive), empty
/// and non-ASCII strings, JSON with escapes.
fn frame_menu(rng: &mut Rng) -> Vec<Frame> {
    let data6: Vec<f32> = (0..6).map(|_| rng.normal(0.0, 1.0)).collect();
    let data4: Vec<f32> = (0..4).map(|_| rng.normal(0.0, 1.0)).collect();
    vec![
        Frame::Hello(HelloFrame {
            models: vec![
                ModelEntry { name: "alpha".to_string(), in_dim: 12, out_dim: 4 },
                ModelEntry { name: "βeta-µ".to_string(), in_dim: 300, out_dim: 10 },
            ],
        }),
        Frame::Hello(HelloFrame { models: vec![] }),
        Frame::Request(RequestFrame {
            id: u64::MAX,
            model: "toy-k4".to_string(),
            rows: 2,
            cols: 3,
            data: data6,
            trace: None,
        }),
        Frame::Request(RequestFrame {
            id: 1,
            model: "m".to_string(),
            rows: 1,
            cols: 1,
            data: vec![-0.0],
            trace: Some(TraceContext { trace_id: u64::MAX, parent_span: 1 }),
        }),
        Frame::Response(ResponseFrame { id: 7, rows: 1, cols: 4, data: data4 }),
        Frame::Error(ErrorFrame {
            id: 0,
            code: ErrorCode::Timeout,
            message: "deadline — \"quoted\"\nsecond line".to_string(),
        }),
        Frame::StatsRequest(StatsRequestFrame { id: 42 }),
        Frame::StatsResponse(StatsResponseFrame {
            id: 42,
            json: "{\"k\":[1,2,3],\"s\":\"\\\"✓\\\"\"}".to_string(),
        }),
        Frame::FleetStatsRequest(FleetStatsRequestFrame { id: u64::MAX }),
        Frame::FleetStatsResponse(FleetStatsResponseFrame {
            id: u64::MAX,
            json: "{\"fleet\":{\"backends_ok\":2},\"backends\":[]}".to_string(),
        }),
    ]
}

#[test]
fn frame_reader_decodes_every_frame_type_at_every_split_point() {
    let mut rng = Rng::new(0xC10C);
    for frame in frame_menu(&mut rng) {
        let bytes = frame.to_bytes();
        for split in 1..bytes.len() {
            let (frames, err) = run_reader(&bytes, vec![split], false);
            assert!(err.is_none(), "split {split}: unexpected error {err:?}");
            assert_eq!(frames.len(), 1, "split {split}: exactly one frame");
            // byte-identical decode: re-encoding reproduces the wire bytes
            assert_eq!(frames[0].to_bytes(), bytes, "split {split} of {frame:?}");
        }
    }
}

#[test]
fn frame_reader_survives_prng_chopped_streams() {
    for round in 0..32u64 {
        let mut rng = Rng::new(0xBEEF ^ round.wrapping_mul(0x9E37_79B9));
        let menu = frame_menu(&mut rng);
        // a random 12-frame sequence drawn from the menu, back to back
        let mut stream = Vec::new();
        let mut want: Vec<Vec<u8>> = Vec::new();
        for _ in 0..12 {
            let bytes = menu[rng.below(menu.len())].to_bytes();
            stream.extend_from_slice(&bytes);
            want.push(bytes);
        }
        // 24 random stall points — frame boundaries carry no special
        // protection; any of them may land mid-length-prefix, mid-f32,
        // mid-checksum
        let cuts: Vec<usize> = (0..24).map(|_| 1 + rng.below(stream.len() - 1)).collect();
        let (frames, err) = run_reader(&stream, cuts, false);
        assert!(err.is_none(), "round {round}: valid stream errored: {err:?}");
        assert_eq!(frames.len(), want.len(), "round {round}: frame count");
        for (i, (got, bytes)) in frames.iter().zip(&want).enumerate() {
            assert_eq!(&got.to_bytes(), bytes, "round {round} frame {i} must decode bit-identical");
        }
    }
}

#[test]
fn hostile_tails_error_typed_without_desyncing_the_valid_prefix() {
    let mut rng = Rng::new(0xD00D);
    let menu = frame_menu(&mut rng);
    let valid: Vec<u8> = menu.iter().flat_map(|f| f.to_bytes()).collect();
    let chop = |stream: &Vec<u8>, rng: &mut Rng| -> Vec<usize> {
        (0..16).map(|_| 1 + rng.below(stream.len() - 1)).collect()
    };

    // (a) corrupt checksum: a bit flipped mid-payload after checksumming
    let mut stream = valid.clone();
    let mut bad = menu[2].to_bytes();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x01;
    stream.extend_from_slice(&bad);
    let cuts = chop(&stream, &mut rng);
    let (frames, err) = run_reader(&stream, cuts, false);
    assert_eq!(frames.len(), menu.len(), "every frame before the hostile one must decode");
    for (got, f) in frames.iter().zip(&menu) {
        assert_eq!(got.to_bytes(), f.to_bytes(), "no desync before the corruption");
    }
    assert!(
        matches!(err, Some(WireError::Checksum { .. })),
        "corruption must be a typed checksum error, got {err:?}"
    );

    // (b) oversized length prefix: rejected from the prefix alone
    let mut stream = valid.clone();
    stream.extend_from_slice(&((proto::DEFAULT_MAX_FRAME as u32) + 1).to_le_bytes());
    let cuts = chop(&stream, &mut rng);
    let (frames, err) = run_reader(&stream, cuts, false);
    assert_eq!(frames.len(), menu.len());
    assert!(
        matches!(err, Some(WireError::Oversized { .. })),
        "oversized prefix must be typed, got {err:?}"
    );

    // (c) truncated payload, then EOF: a peer dying mid-frame
    let mut stream = valid.clone();
    let partial = menu[0].to_bytes();
    stream.extend_from_slice(&partial[..partial.len() * 3 / 5]);
    let cuts = chop(&stream, &mut rng);
    let (frames, err) = run_reader(&stream, cuts, true);
    assert_eq!(frames.len(), menu.len());
    assert!(
        matches!(err, Some(WireError::Closed)),
        "mid-frame EOF must be typed Closed, got {err:?}"
    );
}

// ---- LCQ-RPC v3 compat + fleet-stats hostile input (PR 10) --------------

/// Wrap an arbitrary payload in a valid envelope (`len | payload |
/// fnv1a(payload)`), mirroring the byte spec in `docs/wire-protocol.md`.
/// Putting hostile payloads behind a *correct* checksum ensures the
/// decode-level rejection is what gets exercised, not the checksum gate.
fn envelope(payload: &[u8]) -> Vec<u8> {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut out = Vec::with_capacity(payload.len() + 12);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&h.to_le_bytes());
    out
}

#[test]
fn v2_connection_roundtrips_but_rejects_trace_context() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();

    // a v2-negotiated connection still serves trace-less requests: the
    // trace tail is the only v3 addition to the Request frame
    {
        let (mut stream, mut reader) = raw_handshake_as(&addr, 2);
        let bytes = Frame::Request(RequestFrame {
            id: 21,
            model: "toy-k4".to_string(),
            rows: 1,
            cols: 12,
            data: vec![0.0; 12],
            trace: None,
        })
        .to_bytes();
        stream.write_all(&bytes).unwrap();
        loop {
            match reader.poll_frame(&mut stream) {
                Ok(Some(Frame::Response(r))) => {
                    assert_eq!(r.id, 21);
                    assert_eq!(r.cols, 4);
                    break;
                }
                Ok(Some(f)) => panic!("expected response on v2 conn, got {f:?}"),
                Ok(None) => continue,
                Err(e) => panic!("v2 round trip failed: {e}"),
            }
        }
    }

    // a trace-context tail on that same negotiated version is a protocol
    // violation: typed Malformed, then close — never a guess at the 9
    // extra bytes' meaning
    let (mut stream, mut reader) = raw_handshake_as(&addr, 2);
    let bytes = Frame::Request(RequestFrame {
        id: 22,
        model: "toy-k4".to_string(),
        rows: 1,
        cols: 12,
        data: vec![0.0; 12],
        trace: Some(TraceContext { trace_id: 0xABCD, parent_span: 0 }),
    })
    .to_bytes();
    stream.write_all(&bytes).unwrap();
    let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
    assert_eq!(err.code, ErrorCode::Malformed);
    // the abuse is contained: a fresh connection still serves
    let mut client = NetClient::connect(&addr).expect("fresh connection after abuse");
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
}

#[test]
fn partial_trace_tails_reject_malformed_at_decode() {
    // the v3 trace tail is all-or-nothing: exactly 9 bytes (u64 id + u8
    // parent span) or absent. Every partial length must be Malformed.
    let full = Frame::Request(RequestFrame {
        id: 1,
        model: "m".to_string(),
        rows: 1,
        cols: 1,
        data: vec![0.5],
        trace: Some(TraceContext { trace_id: 7, parent_span: 1 }),
    })
    .payload();
    let bare_len = full.len() - 9;
    for extra in 0..=9usize {
        let res = Frame::decode_payload(&full[..bare_len + extra]);
        if extra == 0 || extra == 9 {
            assert!(res.is_ok(), "tail of {extra} bytes must decode, got {res:?}");
        } else {
            assert!(
                matches!(res, Err(WireError::Malformed(_))),
                "tail of {extra} bytes must be Malformed, got {res:?}"
            );
        }
    }
    // a 10th byte after a complete tail is trailing garbage
    let mut over = full.clone();
    over.push(0);
    assert!(matches!(Frame::decode_payload(&over), Err(WireError::Malformed(_))));
}

#[test]
fn hostile_fleet_stats_frames_reject_malformed_without_desync() {
    let (reg, _) = toy_registry();
    let server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();

    // (a) truncated: tag 7 with a 4-byte id stub instead of 8
    {
        let (mut stream, mut reader) = raw_handshake(&addr);
        let mut payload = vec![7u8];
        payload.extend_from_slice(&42u32.to_le_bytes());
        stream.write_all(&envelope(&payload)).unwrap();
        let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
        assert_eq!(err.code, ErrorCode::Malformed);
    }
    // (b) trailing byte after a well-formed id
    {
        let (mut stream, mut reader) = raw_handshake(&addr);
        let mut payload = vec![7u8];
        payload.extend_from_slice(&42u64.to_le_bytes());
        payload.push(0xFF);
        stream.write_all(&envelope(&payload)).unwrap();
        let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
        assert_eq!(err.code, ErrorCode::Malformed);
    }
    // (c) even a well-formed FleetStatsRequest is Malformed at a backend:
    // fleet aggregation is served by fabric routers only
    {
        let (mut stream, mut reader) = raw_handshake(&addr);
        let bytes = Frame::FleetStatsRequest(FleetStatsRequestFrame { id: 9 }).to_bytes();
        stream.write_all(&bytes).unwrap();
        let err = read_error_then_eof(&mut stream, &mut reader).expect("server must report");
        assert_eq!(err.code, ErrorCode::Malformed);
    }
    // none of the abuse wedged the server
    let mut client = NetClient::connect(&addr).expect("fresh connection after abuse");
    assert!(client.infer("toy-k4", &[0.0; 12]).is_ok());
}

#[test]
fn stop_is_clean_and_idempotent() {
    let (reg, packed) = toy_registry();
    let engine = LutEngine::new(&packed).unwrap();
    let mut server = start_server(Arc::clone(&reg), loopback_cfg());
    let addr = server.local_addr().to_string();
    let mut client = NetClient::connect(&addr).unwrap();
    let input = vec![0.25f32; engine.in_dim()];
    let got = client.infer("toy-k4", &input).unwrap();
    let mut x = Mat::zeros(1, engine.in_dim());
    x.row_mut(0).copy_from_slice(&input);
    assert_eq!(got, engine.forward(&x).unwrap().row(0).to_vec());
    server.stop();
    server.stop(); // idempotent
    // stats survive the stop: the one answered request is on record
    assert_eq!(server.stats().requests_ok, 1);
    assert_eq!(server.batch_stats().requests, 1);
    // (no assertion on post-stop connects: the ephemeral port may be
    // re-bound by a concurrently running test's server)
}
