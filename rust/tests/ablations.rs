//! Ablations over the LC design choices the paper (and DESIGN.md) call
//! out: augmented Lagrangian vs quadratic penalty, μ₀ sensitivity, the
//! clipped learning rate, and warm-started k-means.

use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
use lcquant::coordinator::{lc_quantize, Backend, LcConfig, MuSchedule, NativeBackend, PenaltyMode};
use lcquant::data::synth_mnist::SynthMnist;
use lcquant::nn::sgd::ClippedLrSchedule;
use lcquant::nn::{Mlp, MlpSpec};
use lcquant::quant::Scheme;
use lcquant::util::rng::Rng;

fn trained(seed: u64) -> NativeBackend {
    let mut data = SynthMnist::generate(350, seed);
    data.subtract_mean(None);
    let mut rng = Rng::new(seed);
    let (train, test) = data.split(0.15, &mut rng);
    let net = Mlp::new(&MlpSpec::single_hidden(784, 20, 10), seed);
    let mut b = NativeBackend::new(net, train, Some(test), 64, seed);
    let mut opt = FlatNesterov::new(b.layout(), 0.9);
    run_sgd(&mut b, &mut opt, 220, 0.1, None);
    b
}

fn base_cfg(mode: PenaltyMode, mu0: f32) -> LcConfig {
    LcConfig {
        scheme: Scheme::AdaptiveCodebook { k: 2 },
        mu: MuSchedule::new(mu0, 1.5),
        iterations: 16,
        l_steps: 60,
        lr: ClippedLrSchedule { eta0: 0.1, decay: 0.98 },
        momentum: 0.9,
        mode,
        tol: 0.0,
        seed: 5,
        eval_every: 0,
        n_weight_samples: 0,
    }
}

/// Paper §5: "we use the augmented Lagrangian, because we found it not
/// only faster but far more robust than the quadratic penalty". At a
/// matched schedule, AL must reach feasibility at least as tight and a
/// loss at least as good (within noise).
#[test]
fn ablation_augmented_lagrangian_vs_quadratic_penalty() {
    let mut b = trained(101);
    let w_ref = b.weights();
    let al = lc_quantize(&mut b, &base_cfg(PenaltyMode::AugmentedLagrangian, 1e-3));
    b.set_weights(&w_ref);
    let qp = lc_quantize(&mut b, &base_cfg(PenaltyMode::QuadraticPenalty, 1e-3));
    let al_feas = al.history.last().unwrap().feasibility;
    let qp_feas = qp.history.last().unwrap().feasibility;
    assert!(
        al_feas <= qp_feas * 1.5,
        "AL feasibility {al_feas} should not be much worse than QP {qp_feas}"
    );
    assert!(
        al.train_loss <= qp.train_loss * 1.5 + 0.02,
        "AL loss {} vs QP {}",
        al.train_loss,
        qp.train_loss
    );
}

/// Paper §3.3: "it is important to use a small enough μ0 that allows the
/// algorithm to explore the solution space before committing". A μ0 that
/// is orders of magnitude too large pins the weights to the initial DC
/// assignment immediately — it must not beat the moderate schedule.
#[test]
fn ablation_mu0_too_large_commits_too_early() {
    let mut b = trained(103);
    let w_ref = b.weights();
    let moderate = lc_quantize(&mut b, &base_cfg(PenaltyMode::AugmentedLagrangian, 1e-3));
    b.set_weights(&w_ref);
    let huge = lc_quantize(&mut b, &base_cfg(PenaltyMode::AugmentedLagrangian, 1e3));
    assert!(
        moderate.train_loss <= huge.train_loss * 1.05 + 1e-4,
        "moderate mu0 {} should not lose to huge mu0 {}",
        moderate.train_loss,
        huge.train_loss
    );
}

/// The clipped lr η' = min(η, 1/μ) keeps the penalized SGD stable as μ
/// grows (paper §3.3). Verify the schedule actually clips and that the LC
/// run with clipping stays finite at an aggressive μ ramp.
#[test]
fn ablation_clipped_lr_keeps_aggressive_mu_stable() {
    let s = ClippedLrSchedule { eta0: 0.5, decay: 1.0 };
    assert_eq!(s.lr(0, 1000.0), 0.001); // clipped hard
    let mut b = trained(107);
    let mut cfg = base_cfg(PenaltyMode::AugmentedLagrangian, 10.0);
    cfg.lr = ClippedLrSchedule { eta0: 0.5, decay: 1.0 }; // reckless base lr
    cfg.mu = MuSchedule::new(10.0, 2.0); // very aggressive ramp
    cfg.iterations = 10;
    let lc = lc_quantize(&mut b, &cfg);
    assert!(
        lc.train_loss.is_finite() && lc.train_loss < 10.0,
        "clipped-lr LC diverged: {}",
        lc.train_loss
    );
    for wl in &lc.wc {
        assert!(wl.iter().all(|v| v.is_finite()));
    }
}

/// Adaptive codebook vs fixed {−1,+1} of the same size (paper §2.1:
/// "little practical reason to use certain fixed codebooks"). Raw CE loss
/// is a logit-scale artifact on tanh nets (±1 weights saturate the units
/// and push CE → 0 once error is 0), so the stable invariants are:
/// (a) the adaptive C step represents the weights with far less
/// distortion, and (b) adaptive LC matches fixed ±1 in error.
#[test]
fn ablation_adaptive_k2_beats_fixed_binary() {
    use lcquant::quant::{distortion, LayerQuantizer};
    let mut b = trained(109);
    let w_ref = b.weights();
    // (a) distortion of the C step on the reference weights
    for wl in &w_ref {
        let mut q_ad = LayerQuantizer::new(Scheme::AdaptiveCodebook { k: 2 }, 1);
        let mut q_fx = LayerQuantizer::new(Scheme::Binary, 1);
        let d_ad = distortion(wl, &q_ad.compress(wl).wc);
        let d_fx = distortion(wl, &q_fx.compress(wl).wc);
        assert!(
            d_ad < d_fx * 0.5,
            "adaptive K=2 distortion {d_ad} should be far below fixed ±1 {d_fx}"
        );
    }
    // (b) end-to-end error parity or better
    let adaptive = lc_quantize(&mut b, &base_cfg(PenaltyMode::AugmentedLagrangian, 1e-3));
    b.set_weights(&w_ref);
    let mut cfg = base_cfg(PenaltyMode::AugmentedLagrangian, 1e-3);
    cfg.scheme = Scheme::Binary;
    let fixed = lc_quantize(&mut b, &cfg);
    assert!(
        adaptive.train_err <= fixed.train_err + 1.0,
        "adaptive err {}% vs fixed ±1 err {}%",
        adaptive.train_err,
        fixed.train_err
    );
}

/// Scaled binary {−a,+a} is a strictly more expressive Δ(Θ) than ±1: the
/// optimal scale (Thm A.2) can never increase the C-step distortion, and
/// end-to-end error must not degrade.
#[test]
fn ablation_scale_helps_binarization() {
    use lcquant::quant::{binary, distortion};
    let mut b = trained(113);
    let w_ref = b.weights();
    for wl in &w_ref {
        let plain = binary::binarize(wl);
        let (_, scaled) = binary::binarize_with_scale(wl);
        assert!(
            distortion(wl, &scaled) <= distortion(wl, &plain) + 1e-9,
            "optimal scale must not increase distortion (Thm A.2)"
        );
    }
    let mut cfg = base_cfg(PenaltyMode::AugmentedLagrangian, 1e-3);
    cfg.scheme = Scheme::Binary;
    let plain = lc_quantize(&mut b, &cfg);
    b.set_weights(&w_ref);
    cfg.scheme = Scheme::BinaryScale;
    let scaled = lc_quantize(&mut b, &cfg);
    assert!(
        scaled.train_err <= plain.train_err + 1.0,
        "scaled err {}% vs plain err {}%",
        scaled.train_err,
        plain.train_err
    );
}

/// Runtime failure injection: broken manifests and missing artifacts
/// surface as errors, not panics.
#[cfg(feature = "pjrt")]
#[test]
fn runtime_failure_paths() {
    use lcquant::runtime::{Engine, Manifest};
    let dir = std::env::temp_dir().join("lcquant_bad_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // no manifest at all
    assert!(!Engine::available(&dir));
    assert!(Engine::open(&dir).is_err());
    // malformed manifest
    std::fs::write(dir.join("manifest.json"), "{oops").unwrap();
    assert!(Engine::open(&dir).is_err());
    // manifest pointing at a missing HLO file
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": {"ghost": {"path": "ghost.hlo.txt",
            "inputs": [{"name":"x","shape":[1],"dtype":"f32"}],
            "outputs": [{"name":"y","shape":[1],"dtype":"f32"}]}}}"#,
    )
    .unwrap();
    let mut e = Engine::open(&dir).unwrap();
    let lit = lcquant::runtime::literal_f32(&[1.0], &[1]).unwrap();
    assert!(e.execute("ghost", &[lit]).is_err());
    // unknown artifact name
    let lit = lcquant::runtime::literal_f32(&[1.0], &[1]).unwrap();
    assert!(e.execute("nope", &[lit]).is_err());
    // arity mismatch is caught before compilation
    assert!(e.execute("ghost", &[]).is_err());
    // manifest parse unit errors
    assert!(Manifest::parse("[]").is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
