//! L3 coordinator — the paper's system contribution.
//!
//! The LC algorithm ([`lc`]) alternates an L step (SGD on the penalized
//! loss, driven by [`sgd_driver`] over any [`Backend`]) with a C step (the
//! operators in [`crate::quant`]), plus Lagrange-multiplier updates and the
//! μ schedule ([`schedule`]). [`baselines`] implements DC, iDC and
//! BinaryConnect for the paper's comparisons.
//!
//! Backends expose their parameters as a **flat contiguous arena**
//! ([`ParamSet`]): the coordinator reads/writes per-layer views around the
//! C step and the optimizer updates the whole arena in place, so the
//! per-minibatch step path performs **no heap allocation and no
//! full-parameter copies** — gradients stream into a caller-owned
//! [`GradBuffer`] via [`Backend::next_loss_grads_into`].
//!
//! Two interchangeable backends compute loss/gradients:
//! * [`NativeBackend`] — the pure-rust MLP ([`crate::nn`]);
//! * `crate::runtime::PjrtBackend` — the AOT JAX artifact via PJRT
//!   (behind the `pjrt` cargo feature).
//!
//! The coordinator owns the optimizer state, so BinaryConnect (gradient at
//! quantized weights, update to continuous weights) works identically on
//! both backends.

pub mod baselines;
pub mod lc;
pub mod schedule;
pub mod sgd_driver;

pub use lc::{lc_quantize, LcConfig, LcRecord, LcResult, PenaltyMode};
pub use schedule::MuSchedule;

use crate::data::batcher::{Batch, Batcher};
use crate::data::Dataset;
use crate::nn::params::{GradBuffer, ParamLayout, ParamSet};
use crate::nn::{EvalScratch, Mlp, MlpScratch};
use crate::util::rng::Rng;

/// A source of minibatch loss/gradients for the L step. Implementations
/// own the model parameters as a flat [`ParamSet`] arena; the coordinator
/// and optimizer operate on views of it in place.
pub trait Backend {
    /// The flat parameter arena (weights then biases).
    fn params(&self) -> &ParamSet;

    /// Mutable access to the arena — the optimizer's in-place update path.
    fn params_mut(&mut self) -> &mut ParamSet;

    /// Loss at the current parameters on the next minibatch; gradients are
    /// written (overwriting) into `grads`. Steady-state allocation-free on
    /// the native backend.
    fn next_loss_grads_into(&mut self, grads: &mut GradBuffer) -> f32;

    /// (loss, error %) on the training set.
    fn eval_train(&mut self) -> (f32, f32);

    /// (loss, error %) on the test set, if one exists.
    fn eval_test(&mut self) -> Option<(f32, f32)>;

    // ---- provided conveniences (API edges; allocating forms are not on
    //      the step path) ------------------------------------------------

    fn layout(&self) -> &ParamLayout {
        self.params().layout()
    }

    fn n_layers(&self) -> usize {
        self.layout().n_layers()
    }

    /// Per-layer clones of the multiplicative weights.
    fn weights(&self) -> Vec<Vec<f32>> {
        self.params().w_cloned()
    }

    fn set_weights(&mut self, w: &[Vec<f32>]) {
        self.params_mut().set_w_per_layer(w);
    }

    /// Overwrite all weights from a flat weight-arena-length slice — one
    /// memcpy, no per-layer traffic.
    fn set_weights_flat(&mut self, w: &[f32]) {
        self.params_mut().w_flat_mut().copy_from_slice(w);
    }

    /// Per-layer clones of the biases.
    fn biases(&self) -> Vec<Vec<f32>> {
        self.params().b_cloned()
    }

    fn set_biases(&mut self, b: &[Vec<f32>]) {
        self.params_mut().set_b_per_layer(b);
    }

    /// Allocating convenience around [`Backend::next_loss_grads_into`].
    fn next_loss_grads(&mut self) -> (f32, GradBuffer) {
        let mut grads = GradBuffer::zeros(self.layout().clone());
        let loss = self.next_loss_grads_into(&mut grads);
        (loss, grads)
    }
}

/// Pure-rust backend over [`Mlp`] + a minibatcher, with reusable batch and
/// activation scratch so the step path never allocates.
pub struct NativeBackend {
    pub net: Mlp,
    pub train: Dataset,
    pub test: Option<Dataset>,
    batcher: Batcher,
    rng: Rng,
    scratch: MlpScratch,
    batch_buf: Batch,
    /// Staging buffers for chunked dataset evaluation (warm after the
    /// first eval, so periodic evals stop allocating).
    eval_scratch: EvalScratch,
    /// Chunk size for dataset evaluation.
    pub eval_chunk: usize,
}

impl NativeBackend {
    pub fn new(net: Mlp, train: Dataset, test: Option<Dataset>, batch: usize, seed: u64) -> Self {
        let batcher = Batcher::new(train.len(), batch.min(train.len()), seed);
        NativeBackend {
            net,
            train,
            test,
            batcher,
            rng: Rng::new(seed ^ 0xABCD),
            scratch: MlpScratch::new(),
            batch_buf: Batch::empty(),
            eval_scratch: EvalScratch::new(),
            eval_chunk: 1024,
        }
    }
}

impl Backend for NativeBackend {
    fn params(&self) -> &ParamSet {
        self.net.params()
    }
    fn params_mut(&mut self) -> &mut ParamSet {
        self.net.params_mut()
    }
    fn next_loss_grads_into(&mut self, grads: &mut GradBuffer) -> f32 {
        self.batcher.next_batch_into(&self.train, &mut self.batch_buf);
        let has_dropout = self.net.has_dropout();
        let rng = if has_dropout { Some(&mut self.rng) } else { None };
        let (loss, _err) = self.net.loss_grads_into(
            &self.batch_buf.x,
            &self.batch_buf.y,
            &self.batch_buf.labels,
            has_dropout,
            rng,
            &mut self.scratch,
            grads,
        );
        loss
    }
    fn eval_train(&mut self) -> (f32, f32) {
        self.net
            .evaluate_dataset_into(&self.train, self.eval_chunk, &mut self.eval_scratch)
    }
    fn eval_test(&mut self) -> Option<(f32, f32)> {
        let scratch = &mut self.eval_scratch;
        self.test
            .as_ref()
            .map(|t| self.net.evaluate_dataset_into(t, self.eval_chunk, scratch))
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::synth_mnist::SynthMnist;
    use crate::nn::MlpSpec;

    pub fn small_backend(seed: u64) -> NativeBackend {
        let data = SynthMnist::generate(200, seed);
        let mut rng = Rng::new(seed);
        let (train, test) = data.split(0.2, &mut rng);
        let spec = MlpSpec::single_hidden(784, 16, 10);
        let net = Mlp::new(&spec, seed);
        NativeBackend::new(net, train, Some(test), 32, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::small_backend;
    use super::*;

    #[test]
    fn backend_roundtrips_params() {
        let mut b = small_backend(1);
        let mut w = b.weights();
        w[0][0] = 42.0;
        b.set_weights(&w);
        assert_eq!(b.weights()[0][0], 42.0);
        assert_eq!(b.params().w_flat()[0], 42.0);
        let mut bias = b.biases();
        bias[1][2] = -1.0;
        b.set_biases(&bias);
        assert_eq!(b.biases()[1][2], -1.0);
        assert_eq!(b.params().b_layer(1)[2], -1.0);
    }

    #[test]
    fn flat_set_matches_per_layer_set() {
        let mut b = small_backend(5);
        let mut flat = b.params().w_flat().to_vec();
        for (i, v) in flat.iter_mut().enumerate() {
            *v = i as f32 * 0.01;
        }
        b.set_weights_flat(&flat);
        let per_layer = b.weights();
        let layout = b.layout().clone();
        for l in 0..layout.n_layers() {
            assert_eq!(per_layer[l].as_slice(), layout.w_slice(&flat, l));
        }
    }

    #[test]
    fn grads_have_matching_shapes() {
        let mut b = small_backend(2);
        let (loss, g) = b.next_loss_grads();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(g.layout(), b.layout());
        assert_eq!(g.w_flat().len(), b.params().w_flat().len());
        assert_eq!(g.b_flat().len(), b.params().b_flat().len());
    }

    #[test]
    fn grads_into_reuses_buffer_and_overwrites() {
        let mut b = small_backend(4);
        let mut g = GradBuffer::zeros(b.layout().clone());
        let l1 = b.next_loss_grads_into(&mut g);
        let first = g.w_flat().to_vec();
        let l2 = b.next_loss_grads_into(&mut g);
        assert!(l1.is_finite() && l2.is_finite());
        // different minibatch ⇒ overwritten gradients, same buffer
        assert_ne!(first, g.w_flat());
    }

    #[test]
    fn eval_returns_finite_metrics() {
        let mut b = small_backend(3);
        let (l, e) = b.eval_train();
        assert!(l.is_finite());
        assert!((0.0..=100.0).contains(&e));
        let (lt, et) = b.eval_test().unwrap();
        assert!(lt.is_finite());
        assert!((0.0..=100.0).contains(&et));
    }
}
