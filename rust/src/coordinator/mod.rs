//! L3 coordinator — the paper's system contribution.
//!
//! The LC algorithm ([`lc`]) alternates an L step (SGD on the penalized
//! loss, driven by [`sgd_driver`] over any [`Backend`]) with a C step (the
//! operators in [`crate::quant`]), plus Lagrange-multiplier updates and the
//! μ schedule ([`schedule`]). [`baselines`] implements DC, iDC and
//! BinaryConnect for the paper's comparisons.
//!
//! Two interchangeable backends compute loss/gradients:
//! * [`NativeBackend`] — the pure-rust MLP ([`crate::nn`]);
//! * [`crate::runtime::PjrtBackend`] — the AOT JAX artifact via PJRT.
//!
//! The coordinator owns the optimizer state, so BinaryConnect (gradient at
//! quantized weights, update to continuous weights) works identically on
//! both backends.

pub mod baselines;
pub mod lc;
pub mod schedule;
pub mod sgd_driver;

pub use lc::{lc_quantize, LcConfig, LcRecord, LcResult, PenaltyMode};
pub use schedule::MuSchedule;

use crate::data::batcher::Batcher;
use crate::data::Dataset;
use crate::nn::Mlp;
use crate::util::rng::Rng;

/// Loss gradients in backend-independent form: per-layer weight and bias
/// gradient vectors (row-major, matching the layer's weight layout).
#[derive(Clone, Debug)]
pub struct FlatGrads {
    pub dw: Vec<Vec<f32>>,
    pub db: Vec<Vec<f32>>,
}

/// A source of minibatch loss/gradients for the L step. Implementations
/// hold the model parameters; the coordinator reads/writes them around the
/// C step.
pub trait Backend {
    fn n_layers(&self) -> usize;
    /// Per-layer multiplicative weights.
    fn weights(&self) -> Vec<Vec<f32>>;
    fn set_weights(&mut self, w: &[Vec<f32>]);
    /// Per-layer biases.
    fn biases(&self) -> Vec<Vec<f32>>;
    fn set_biases(&mut self, b: &[Vec<f32>]);
    /// Loss and gradients at the current parameters on the next minibatch.
    fn next_loss_grads(&mut self) -> (f32, FlatGrads);
    /// (loss, error %) on the training set.
    fn eval_train(&mut self) -> (f32, f32);
    /// (loss, error %) on the test set, if one exists.
    fn eval_test(&mut self) -> Option<(f32, f32)>;
}

/// Pure-rust backend over [`Mlp`] + a minibatcher.
pub struct NativeBackend {
    pub net: Mlp,
    pub train: Dataset,
    pub test: Option<Dataset>,
    batcher: Batcher,
    rng: Rng,
    /// Chunk size for dataset evaluation.
    pub eval_chunk: usize,
}

impl NativeBackend {
    pub fn new(net: Mlp, train: Dataset, test: Option<Dataset>, batch: usize, seed: u64) -> Self {
        let batcher = Batcher::new(train.len(), batch.min(train.len()), seed);
        NativeBackend { net, train, test, batcher, rng: Rng::new(seed ^ 0xABCD), eval_chunk: 1024 }
    }
}

impl Backend for NativeBackend {
    fn n_layers(&self) -> usize {
        self.net.n_layers()
    }
    fn weights(&self) -> Vec<Vec<f32>> {
        self.net.weights_cloned()
    }
    fn set_weights(&mut self, w: &[Vec<f32>]) {
        self.net.set_weights(w);
    }
    fn biases(&self) -> Vec<Vec<f32>> {
        self.net.layers.iter().map(|l| l.b.clone()).collect()
    }
    fn set_biases(&mut self, b: &[Vec<f32>]) {
        for (l, bb) in self.net.layers.iter_mut().zip(b) {
            l.b.copy_from_slice(bb);
        }
    }
    fn next_loss_grads(&mut self) -> (f32, FlatGrads) {
        let batch = self.batcher.next_batch(&self.train);
        let has_dropout = self.net.layers.iter().any(|l| l.keep < 1.0);
        let rng = if has_dropout { Some(&mut self.rng) } else { None };
        let (loss, _err, grads) =
            self.net
                .loss_and_grads(&batch.x, &batch.y, &batch.labels, has_dropout, rng);
        (
            loss,
            FlatGrads {
                dw: grads.dw.into_iter().map(|m| m.data).collect(),
                db: grads.db,
            },
        )
    }
    fn eval_train(&mut self) -> (f32, f32) {
        self.net.evaluate_dataset(&self.train, self.eval_chunk)
    }
    fn eval_test(&mut self) -> Option<(f32, f32)> {
        self.test
            .as_ref()
            .map(|t| self.net.evaluate_dataset(t, self.eval_chunk))
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::synth_mnist::SynthMnist;
    use crate::nn::MlpSpec;

    pub fn small_backend(seed: u64) -> NativeBackend {
        let data = SynthMnist::generate(200, seed);
        let mut rng = Rng::new(seed);
        let (train, test) = data.split(0.2, &mut rng);
        let spec = MlpSpec::single_hidden(784, 16, 10);
        let net = Mlp::new(&spec, seed);
        NativeBackend::new(net, train, Some(test), 32, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::small_backend;
    use super::*;

    #[test]
    fn backend_roundtrips_params() {
        let mut b = small_backend(1);
        let mut w = b.weights();
        w[0][0] = 42.0;
        b.set_weights(&w);
        assert_eq!(b.weights()[0][0], 42.0);
        let mut bias = b.biases();
        bias[1][2] = -1.0;
        b.set_biases(&bias);
        assert_eq!(b.biases()[1][2], -1.0);
    }

    #[test]
    fn grads_have_matching_shapes() {
        let mut b = small_backend(2);
        let (loss, g) = b.next_loss_grads();
        assert!(loss.is_finite() && loss > 0.0);
        let w = b.weights();
        assert_eq!(g.dw.len(), w.len());
        for (gw, ww) in g.dw.iter().zip(&w) {
            assert_eq!(gw.len(), ww.len());
        }
    }

    #[test]
    fn eval_returns_finite_metrics() {
        let mut b = small_backend(3);
        let (l, e) = b.eval_train();
        assert!(l.is_finite());
        assert!((0.0..=100.0).contains(&e));
        let (lt, et) = b.eval_test().unwrap();
        assert!(lt.is_finite());
        assert!((0.0..=100.0).contains(&et));
    }
}
