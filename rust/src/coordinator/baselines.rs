//! Baselines the paper compares against (§5):
//!
//! * **DC** (direct compression) — quantize the reference net once,
//!   regardless of the loss (Gong et al. 2015).
//! * **iDC** (iterated DC) — alternate re-training (no penalty) and
//!   quantization (Han et al. 2015's "trained quantization").
//! * **BinaryConnect** — gradient at quantized weights, update to
//!   continuous weights (Courbariaux et al. 2015).
//!
//! All three consume the flat parameter plane: quantizers read per-layer
//! arena views, quantized weights accumulate in one flat buffer, and
//! swapping parameter sets for evaluation is a flat memcpy
//! (`set_weights_flat`) rather than per-layer vector traffic.

use super::sgd_driver::{run_quantized_grad_sgd, run_sgd, FlatNesterov};
use super::Backend;
use crate::nn::sgd::ClippedLrSchedule;
use crate::quant::{LayerQuantizer, QuantOut, Scheme};

/// Result common to the baselines.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    pub wc: Vec<Vec<f32>>,
    pub codebooks: Vec<Vec<f32>>,
    pub train_loss: f32,
    pub train_err: f32,
    pub test_err: Option<f32>,
    /// Per-outer-iteration quantized-net training loss (iDC/BC curves).
    pub loss_history: Vec<f32>,
    /// Per-outer-iteration codebook snapshots (iDC; Figs. 12–13).
    pub codebook_history: Vec<Vec<Vec<f32>>>,
}

/// Evaluate with `wc` in the arena, then restore `restore` (both flat).
fn eval_with(
    backend: &mut dyn Backend,
    wc: &[f32],
    restore: &[f32],
) -> (f32, f32, Option<f32>) {
    backend.set_weights_flat(wc);
    let (l, e) = backend.eval_train();
    let te = backend.eval_test().map(|(_, e)| e);
    backend.set_weights_flat(restore);
    (l, e, te)
}

/// DC: quantize the (already trained) reference weights once.
/// Leaves the backend holding the quantized weights.
pub fn direct_compression(backend: &mut dyn Backend, scheme: &Scheme, seed: u64) -> BaselineResult {
    let layout = backend.layout().clone();
    let mut wc_flat = vec![0.0f32; layout.w_len()];
    let mut codebooks = Vec::with_capacity(layout.n_layers());
    let mut out = QuantOut::default();
    for l in 0..layout.n_layers() {
        let mut q = LayerQuantizer::new(scheme.clone(), seed.wrapping_add(l as u64));
        q.compress_into(backend.params().w_layer(l), &mut out);
        wc_flat[layout.w_range(l)].copy_from_slice(&out.wc);
        codebooks.push(out.codebook.clone());
    }
    let (train_loss, train_err, test_err) = eval_with(backend, &wc_flat, &wc_flat);
    BaselineResult {
        wc: layout.w_per_layer(&wc_flat),
        codebooks,
        train_loss,
        train_err,
        test_err,
        loss_history: vec![train_loss],
        codebook_history: Vec::new(),
    }
}

/// iDC: alternate (a) SGD on the unpenalized loss starting from the
/// quantized weights, (b) re-quantization. `iterations` outer loops of
/// `l_steps` SGD steps each — matched to the LC algorithm's budget for a
/// fair comparison.
#[allow(clippy::too_many_arguments)]
pub fn iterated_direct_compression(
    backend: &mut dyn Backend,
    scheme: &Scheme,
    iterations: usize,
    l_steps: usize,
    lr: ClippedLrSchedule,
    momentum: f32,
    seed: u64,
    eval_every: usize,
) -> BaselineResult {
    let layout = backend.layout().clone();
    let n_layers = layout.n_layers();
    let mut quantizers: Vec<LayerQuantizer> = (0..n_layers)
        .map(|l| LayerQuantizer::new(scheme.clone(), seed.wrapping_add(l as u64)))
        .collect();
    let mut outs: Vec<QuantOut> = (0..n_layers).map(|_| QuantOut::default()).collect();
    let mut opt = FlatNesterov::new(&layout, momentum);
    let mut loss_history = Vec::new();
    let mut codebook_history: Vec<Vec<Vec<f32>>> = Vec::new();

    let mut wc_flat = vec![0.0f32; layout.w_len()];
    let mut w_snap = vec![0.0f32; layout.w_len()];

    // initial DC
    for l in 0..n_layers {
        quantizers[l].compress_into(backend.params().w_layer(l), &mut outs[l]);
        wc_flat[layout.w_range(l)].copy_from_slice(&outs[l].wc);
    }

    for j in 0..iterations {
        // (a) retrain from the quantized weights, no penalty
        backend.set_weights_flat(&wc_flat);
        opt.reset();
        run_sgd(backend, &mut opt, l_steps, lr.lr(j, 0.0), None);
        // (b) re-quantize
        for l in 0..n_layers {
            quantizers[l].compress_into(backend.params().w_layer(l), &mut outs[l]);
            wc_flat[layout.w_range(l)].copy_from_slice(&outs[l].wc);
        }
        codebook_history.push(outs.iter().map(|o| o.codebook.clone()).collect());
        if eval_every > 0 && (j % eval_every == 0 || j + 1 == iterations) {
            w_snap.copy_from_slice(backend.params().w_flat());
            let (l, _, _) = eval_with(backend, &wc_flat, &w_snap);
            loss_history.push(l);
        }
    }
    w_snap.copy_from_slice(backend.params().w_flat());
    let (train_loss, train_err, test_err) = eval_with(backend, &wc_flat, &w_snap);
    backend.set_weights_flat(&wc_flat);
    BaselineResult {
        wc: layout.w_per_layer(&wc_flat),
        codebooks: outs.iter().map(|o| o.codebook.clone()).collect(),
        train_loss,
        train_err,
        test_err,
        loss_history,
        codebook_history,
    }
}

/// BinaryConnect (generalized to any fixed scheme): `steps` minibatch
/// updates with gradients taken at the quantized weights, followed by a
/// final hard quantization.
pub fn binary_connect(
    backend: &mut dyn Backend,
    scheme: &Scheme,
    steps: usize,
    lr: f32,
    momentum: f32,
    seed: u64,
) -> BaselineResult {
    let layout = backend.layout().clone();
    let mut opt = FlatNesterov::new(&layout, momentum);
    run_quantized_grad_sgd(backend, &mut opt, steps, lr, scheme, seed);
    // final drastic quantization (the deployed net must be quantized)
    let mut wc_flat = vec![0.0f32; layout.w_len()];
    let mut codebooks = Vec::with_capacity(layout.n_layers());
    let mut out = QuantOut::default();
    for l in 0..layout.n_layers() {
        let mut q = LayerQuantizer::new(scheme.clone(), seed.wrapping_add(100 + l as u64));
        q.compress_into(backend.params().w_layer(l), &mut out);
        wc_flat[layout.w_range(l)].copy_from_slice(&out.wc);
        codebooks.push(out.codebook.clone());
    }
    let w_snap = backend.params().w_flat().to_vec();
    let (train_loss, train_err, test_err) = eval_with(backend, &wc_flat, &w_snap);
    backend.set_weights_flat(&wc_flat);
    BaselineResult {
        wc: layout.w_per_layer(&wc_flat),
        codebooks,
        train_loss,
        train_err,
        test_err,
        loss_history: vec![train_loss],
        codebook_history: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_support::small_backend;

    fn trained(seed: u64) -> crate::coordinator::NativeBackend {
        let mut b = small_backend(seed);
        let mut opt = FlatNesterov::new(b.layout(), 0.9);
        run_sgd(&mut b, &mut opt, 150, 0.1, None);
        b
    }

    #[test]
    fn dc_outputs_quantized_weights() {
        let mut b = trained(30);
        let r = direct_compression(&mut b, &Scheme::AdaptiveCodebook { k: 4 }, 1);
        for (wl, cb) in r.wc.iter().zip(&r.codebooks) {
            for v in wl {
                assert!(cb.iter().any(|c| (c - v).abs() < 1e-6));
            }
        }
        assert!(r.train_loss.is_finite());
    }

    #[test]
    fn dc_with_large_k_barely_hurts() {
        let mut b = trained(31);
        let (l_ref, _) = b.eval_train();
        let r = direct_compression(&mut b, &Scheme::AdaptiveCodebook { k: 64 }, 2);
        assert!(
            r.train_loss < l_ref * 1.5 + 0.05,
            "K=64 DC loss {} vs ref {}",
            r.train_loss,
            l_ref
        );
    }

    #[test]
    fn idc_improves_over_dc_at_small_k() {
        let mut b = trained(32);
        let w_ref = b.weights();
        let dc = direct_compression(&mut b, &Scheme::AdaptiveCodebook { k: 2 }, 3);
        b.set_weights(&w_ref);
        let idc = iterated_direct_compression(
            &mut b,
            &Scheme::AdaptiveCodebook { k: 2 },
            10,
            40,
            ClippedLrSchedule { eta0: 0.05, decay: 0.98 },
            0.9,
            3,
            0,
        );
        // paper: iDC improves somewhat over DC (but less than LC)
        assert!(
            idc.train_loss < dc.train_loss,
            "iDC {} should improve on DC {}",
            idc.train_loss,
            dc.train_loss
        );
    }

    #[test]
    fn binary_connect_produces_binary_net() {
        let mut b = trained(33);
        let r = binary_connect(&mut b, &Scheme::Binary, 60, 0.05, 0.9, 4);
        for wl in &r.wc {
            for v in wl {
                assert!(v.abs() == 1.0, "non-binary weight {v}");
            }
        }
    }

    #[test]
    fn baselines_leave_backend_on_quantized_weights() {
        let mut b = trained(34);
        let r = direct_compression(&mut b, &Scheme::Ternary, 5);
        assert_eq!(b.weights(), r.wc);
    }
}
