//! The learning-compression (LC) algorithm (paper §3, Figs. 2–4).
//!
//! Augmented-Lagrangian version:
//!
//! ```text
//! w ← reference net
//! (C, Z) ← Π(w)                       # first C step = direct compression
//! λ ← 0
//! for μ = μ₀ < μ₁ < …:
//!     w ← argmin_w L(w) + μ/2 ‖w − w_C − λ/μ‖²     # L step (SGD)
//!     (C, Z) ← Π(w − λ/μ)                          # C step (quantize)
//!     λ ← λ − μ(w − w_C)                           # multiplier update
//!     stop when ‖w − w_C‖ small
//! ```
//!
//! The quadratic-penalty variant keeps λ ≡ 0. The C step dispatches on
//! [`Scheme`]: k-means (warm-started) for adaptive codebooks, the closed
//! forms of Fig. 5 for fixed ones.
//!
//! Everything runs on the flat parameter plane: `w` lives in the backend's
//! [`crate::nn::params::ParamSet`] arena (updated in place by the fused
//! optimizer), while `w_C`, `λ` and the shifted weights are three flat
//! weight-arena-length buffers allocated **once** for the whole run. The
//! [`PenaltyState`] handed to the L step borrows them — the per-iteration
//! `wc.clone()`/`lambda.clone()` of the per-layer representation is gone —
//! and the multiplier update + feasibility norm fuse into one pass
//! ([`crate::linalg::vecops::update_multipliers_fused`]).

use super::schedule::MuSchedule;
use super::sgd_driver::{run_sgd, FlatNesterov, PenaltyState};
use super::Backend;
use crate::linalg::vecops;
use crate::nn::sgd::ClippedLrSchedule;
use crate::quant::{LayerQuantizer, QuantOut, Scheme};

/// Penalty method used by the outer loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PenaltyMode {
    /// Augmented Lagrangian (paper's choice: "faster and far more robust").
    AugmentedLagrangian,
    /// Quadratic penalty (λ ≡ 0).
    QuadraticPenalty,
}

/// LC hyper-parameters.
#[derive(Clone, Debug)]
pub struct LcConfig {
    pub scheme: Scheme,
    pub mu: MuSchedule,
    /// Outer LC iterations (the paper uses 30).
    pub iterations: usize,
    /// SGD minibatch steps per L step (paper: 2k–4k).
    pub l_steps: usize,
    /// Base learning-rate schedule for the L step; clipped at 1/μ.
    pub lr: ClippedLrSchedule,
    pub momentum: f32,
    pub mode: PenaltyMode,
    /// Stop when ‖w − w_C‖ / ‖w‖ falls below this.
    pub tol: f32,
    pub seed: u64,
    /// Evaluate train/test metrics every `eval_every` LC iterations
    /// (0 = only at the end).
    pub eval_every: usize,
    /// Record this many per-layer continuous-weight trajectories in the
    /// history (sampled at evenly spaced indices; Fig. 11's right panels).
    pub n_weight_samples: usize,
}

impl Default for LcConfig {
    fn default() -> LcConfig {
        LcConfig {
            scheme: Scheme::AdaptiveCodebook { k: 2 },
            mu: MuSchedule::new(9.76e-5, 1.1),
            iterations: 30,
            l_steps: 200,
            lr: ClippedLrSchedule { eta0: 0.1, decay: 0.99 },
            momentum: 0.95,
            mode: PenaltyMode::AugmentedLagrangian,
            tol: 1e-4,
            seed: 0,
            eval_every: 1,
            n_weight_samples: 0,
        }
    }
}

/// Per-iteration telemetry.
#[derive(Clone, Debug)]
pub struct LcRecord {
    pub iter: usize,
    pub mu: f32,
    /// Average minibatch loss during this L step (continuous weights).
    pub lstep_loss: f32,
    /// ‖w − w_C‖ over all layers.
    pub feasibility: f32,
    /// k-means iterations per layer in this C step.
    pub kmeans_iters: Vec<usize>,
    /// Loss/error of the *quantized* net, when evaluated.
    pub train_loss_wc: Option<f32>,
    pub train_err_wc: Option<f32>,
    pub test_err_wc: Option<f32>,
    /// Codebook snapshot per layer.
    pub codebooks: Vec<Vec<f32>>,
    /// Sampled continuous weights per layer (empty unless
    /// `n_weight_samples > 0`).
    pub weight_samples: Vec<Vec<f32>>,
}

/// Final result.
#[derive(Clone, Debug)]
pub struct LcResult {
    /// Quantized weights per layer (the deliverable).
    pub wc: Vec<Vec<f32>>,
    /// Final codebook per layer.
    pub codebooks: Vec<Vec<f32>>,
    /// Per-layer codebook indices from the final C step
    /// (`wc[l][i] == codebooks[l][assignments[l][i]]`). This is the low-bit
    /// representation [`crate::serve`] packs to disk — kept so packing never
    /// re-runs nearest-centroid search over every weight.
    pub assignments: Vec<Vec<u32>>,
    /// The scheme the run used (recorded for packaging/serving).
    pub scheme: Scheme,
    /// Continuous weights at termination.
    pub w: Vec<Vec<f32>>,
    pub history: Vec<LcRecord>,
    /// (loss, err%) of the quantized net on train, and err% on test.
    pub train_loss: f32,
    pub train_err: f32,
    pub test_err: Option<f32>,
}

/// Evaluate the quantized net without disturbing the continuous weights:
/// snapshot the weight arena into `w_snap`, swap in `wc`, evaluate, swap
/// back. Flat memcpys, no per-layer traffic.
fn eval_quantized(
    backend: &mut dyn Backend,
    wc: &[f32],
    w_snap: &mut [f32],
) -> (f32, f32, Option<f32>) {
    w_snap.copy_from_slice(backend.params().w_flat());
    backend.set_weights_flat(wc);
    let (l, e) = backend.eval_train();
    let te = backend.eval_test().map(|(_, e)| e);
    backend.set_weights_flat(w_snap);
    (l, e, te)
}

/// Run the LC algorithm on a (trained) reference net held by `backend`.
pub fn lc_quantize(backend: &mut dyn Backend, cfg: &LcConfig) -> LcResult {
    let layout = backend.layout().clone();
    let n_layers = layout.n_layers();
    let w_len = layout.w_len();
    let mut quantizers: Vec<LayerQuantizer> = (0..n_layers)
        .map(|l| LayerQuantizer::new(cfg.scheme.clone(), cfg.seed.wrapping_add(l as u64)))
        .collect();
    // Per-layer C-step outputs, reused across all iterations.
    let mut outs: Vec<QuantOut> = (0..n_layers).map(|_| QuantOut::default()).collect();

    // The run's flat buffers, allocated once: quantized weights, Lagrange
    // multipliers, shifted weights (C-step input), and an eval snapshot.
    let mut wc = vec![0.0f32; w_len];
    let mut lambda = vec![0.0f32; w_len];
    let mut shifted = vec![0.0f32; w_len];
    let mut w_snap = vec![0.0f32; w_len];

    // --- initial C step (μ → 0⁺): direct compression of the reference ---
    for l in 0..n_layers {
        quantizers[l].compress_into(backend.params().w_layer(l), &mut outs[l]);
        wc[layout.w_range(l)].copy_from_slice(&outs[l].wc);
    }

    let mut opt = FlatNesterov::new(&layout, cfg.momentum);
    let mut history: Vec<LcRecord> = Vec::with_capacity(cfg.iterations);

    for j in 0..cfg.iterations {
        let mu = cfg.mu.mu(j);
        let lr = cfg.lr.lr(j, mu);

        // ---- L step: SGD on L(w) + μ/2 ‖w − w_C − λ/μ‖² ----
        // fresh velocities: the penalized objective changed (new μ, w_C, λ)
        opt.reset();
        let lstep_t = std::time::Instant::now();
        let lstep_loss = {
            let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu };
            run_sgd(backend, &mut opt, cfg.l_steps, lr, Some(&penalty))
        };
        let lstep_ns = u64::try_from(lstep_t.elapsed().as_nanos()).unwrap_or(u64::MAX);

        // ---- C step: Θ = Π(w − λ/μ) ----
        let cstep_t = std::time::Instant::now();
        let mut kmeans_iters = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let range = layout.w_range(l);
            match cfg.mode {
                PenaltyMode::AugmentedLagrangian => {
                    vecops::shift_by_multipliers(
                        backend.params().w_layer(l),
                        &lambda[range.clone()],
                        mu,
                        &mut shifted[range.clone()],
                    );
                }
                PenaltyMode::QuadraticPenalty => {
                    shifted[range.clone()].copy_from_slice(backend.params().w_layer(l));
                }
            }
            quantizers[l].compress_into(&shifted[range.clone()], &mut outs[l]);
            wc[range].copy_from_slice(&outs[l].wc);
            kmeans_iters.push(outs[l].iterations);
        }

        // ---- multiplier update λ ← λ − μ(w − w_C), fused with the
        //      feasibility norms (one pass over the weight arena) ----
        let (dist, norm) = match cfg.mode {
            PenaltyMode::AugmentedLagrangian => {
                vecops::update_multipliers_fused(&mut lambda, backend.params().w_flat(), &wc, mu)
            }
            PenaltyMode::QuadraticPenalty => {
                vecops::feasibility(backend.params().w_flat(), &wc)
            }
        };
        let cstep_ns = u64::try_from(cstep_t.elapsed().as_nanos()).unwrap_or(u64::MAX);

        // Live observability: mirror this iteration into the global metrics
        // registry (gauges hold the exact f64 bit patterns of the same casts
        // the run history records, so snapshots are bit-identical to it).
        crate::obs::lc_iteration(j, mu as f64, lstep_loss as f64, dist as f64, lstep_ns, cstep_ns);

        let do_eval = cfg.eval_every > 0 && (j % cfg.eval_every == 0 || j + 1 == cfg.iterations);
        let (tl, te, tst) = if do_eval {
            let (a, b, c) = eval_quantized(backend, &wc, &mut w_snap);
            (Some(a), Some(b), c)
        } else {
            (None, None, None)
        };
        let weight_samples = if cfg.n_weight_samples > 0 {
            (0..n_layers)
                .map(|l| {
                    let wl = backend.params().w_layer(l);
                    let stride = (wl.len() / cfg.n_weight_samples).max(1);
                    wl.iter().step_by(stride).take(cfg.n_weight_samples).copied().collect()
                })
                .collect()
        } else {
            Vec::new()
        };
        history.push(LcRecord {
            iter: j,
            mu,
            lstep_loss,
            feasibility: dist,
            kmeans_iters,
            train_loss_wc: tl,
            train_err_wc: te,
            test_err_wc: tst,
            codebooks: outs.iter().map(|o| o.codebook.clone()).collect(),
            weight_samples,
        });
        crate::debug!(
            "LC iter {j}: mu={mu:.4e} lr={lr:.4e} lstep_loss={lstep_loss:.5} ||w-wc||={dist:.4e}"
        );

        if dist <= cfg.tol * norm.max(1e-12) {
            break;
        }
    }

    // Final: adopt the quantized weights (the solution is w_C = Δ(C, Z)).
    let (train_loss, train_err, test_err) = eval_quantized(backend, &wc, &mut w_snap);
    let w_per_layer = layout.w_per_layer(backend.params().w_flat());
    backend.set_weights_flat(&wc);
    LcResult {
        wc: layout.w_per_layer(&wc),
        codebooks: outs.iter().map(|o| o.codebook.clone()).collect(),
        assignments: outs.iter().map(|o| o.assignments.clone()).collect(),
        scheme: cfg.scheme.clone(),
        w: w_per_layer,
        history,
        train_loss,
        train_err,
        test_err,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sgd_driver::{run_sgd, FlatNesterov};
    use crate::coordinator::test_support::small_backend;

    fn trained_backend(seed: u64) -> crate::coordinator::NativeBackend {
        let mut b = small_backend(seed);
        let mut opt = FlatNesterov::new(b.layout(), 0.9);
        run_sgd(&mut b, &mut opt, 150, 0.1, None);
        b
    }

    fn quick_cfg(scheme: Scheme) -> LcConfig {
        LcConfig {
            scheme,
            mu: MuSchedule::new(0.001, 1.4),
            iterations: 14,
            l_steps: 40,
            lr: ClippedLrSchedule { eta0: 0.05, decay: 0.98 },
            momentum: 0.9,
            mode: PenaltyMode::AugmentedLagrangian,
            tol: 1e-4,
            seed: 7,
            eval_every: 0,
            n_weight_samples: 0,
        }
    }

    #[test]
    fn output_weights_are_quantized() {
        let mut b = trained_backend(20);
        let res = lc_quantize(&mut b, &quick_cfg(Scheme::AdaptiveCodebook { k: 4 }));
        for (wl, cb) in res.wc.iter().zip(&res.codebooks) {
            assert!(cb.len() <= 4);
            for v in wl {
                assert!(
                    cb.iter().any(|c| (c - v).abs() < 1e-6),
                    "{v} not in codebook {cb:?}"
                );
            }
        }
        // backend ends up holding the quantized weights
        let bw = b.weights();
        assert_eq!(bw, res.wc);
        // the recorded assignments reproduce wc exactly (what `serve` packs)
        assert_eq!(res.scheme, Scheme::AdaptiveCodebook { k: 4 });
        assert_eq!(res.assignments.len(), res.wc.len());
        for l in 0..res.wc.len() {
            assert_eq!(res.assignments[l].len(), res.wc[l].len());
            for (i, &a) in res.assignments[l].iter().enumerate() {
                assert_eq!(res.wc[l][i], res.codebooks[l][a as usize]);
            }
        }
    }

    #[test]
    fn feasibility_decreases_over_iterations() {
        let mut b = trained_backend(21);
        let res = lc_quantize(&mut b, &quick_cfg(Scheme::AdaptiveCodebook { k: 2 }));
        let first = res.history.first().unwrap().feasibility;
        let last = res.history.last().unwrap().feasibility;
        assert!(
            last < first * 0.7,
            "||w-wc|| {first} -> {last} did not shrink"
        );
    }

    #[test]
    fn lc_beats_direct_compression_at_k2() {
        // The paper's headline claim: LC << DC at high compression.
        let mut b = trained_backend(22);
        let w_ref = b.weights();
        // DC: quantize reference, evaluate
        let dc = crate::coordinator::baselines::direct_compression(
            &mut b,
            &Scheme::AdaptiveCodebook { k: 2 },
            99,
        );
        b.set_weights(&w_ref);
        let mut cfg = quick_cfg(Scheme::AdaptiveCodebook { k: 2 });
        cfg.iterations = 20;
        let lc = lc_quantize(&mut b, &cfg);
        assert!(
            lc.train_loss < dc.train_loss,
            "LC {} should beat DC {}",
            lc.train_loss,
            dc.train_loss
        );
    }

    #[test]
    fn binarization_with_scale_converges_to_two_values() {
        let mut b = trained_backend(23);
        let res = lc_quantize(&mut b, &quick_cfg(Scheme::BinaryScale));
        for (wl, cb) in res.wc.iter().zip(&res.codebooks) {
            assert_eq!(cb.len(), 2);
            assert!((cb[0] + cb[1]).abs() < 1e-5, "scaled binary: ±a, got {cb:?}");
            let distinct: std::collections::BTreeSet<i64> =
                wl.iter().map(|v| (v * 1e7) as i64).collect();
            assert!(distinct.len() <= 2);
        }
    }

    #[test]
    fn quadratic_penalty_mode_runs() {
        let mut b = trained_backend(24);
        let mut cfg = quick_cfg(Scheme::AdaptiveCodebook { k: 4 });
        cfg.mode = PenaltyMode::QuadraticPenalty;
        let res = lc_quantize(&mut b, &cfg);
        assert!(res.train_loss.is_finite());
        assert_eq!(res.history.last().unwrap().kmeans_iters.len(), 2);
    }

    #[test]
    fn history_records_telemetry() {
        let mut b = trained_backend(25);
        let mut cfg = quick_cfg(Scheme::AdaptiveCodebook { k: 4 });
        cfg.eval_every = 2;
        cfg.iterations = 6;
        cfg.tol = 0.0; // force all iterations
        let res = lc_quantize(&mut b, &cfg);
        assert_eq!(res.history.len(), 6);
        for (j, rec) in res.history.iter().enumerate() {
            assert_eq!(rec.iter, j);
            assert!(rec.mu > 0.0);
            let evaluated = rec.train_loss_wc.is_some();
            assert_eq!(evaluated, j % 2 == 0 || j == 5);
        }
        // mu grows geometrically
        assert!(res.history[5].mu > res.history[0].mu);
    }

    #[test]
    fn warm_started_kmeans_needs_few_iterations_later() {
        // paper Fig. 10: once the LC run settles (large μ), warm-started C
        // steps take ~1 k-means iteration, vs tens for the cold k-means++
        // start on the reference weights.
        let mut b = trained_backend(26);
        let cold_max = b
            .weights()
            .iter()
            .map(|wl| {
                let mut q = LayerQuantizer::new(Scheme::AdaptiveCodebook { k: 4 }, 99);
                q.compress(wl).iterations
            })
            .max()
            .unwrap();
        let mut cfg = quick_cfg(Scheme::AdaptiveCodebook { k: 4 });
        cfg.iterations = 20;
        cfg.mu = MuSchedule::new(0.001, 1.7); // drive to convergence
        cfg.tol = 0.0;
        let res = lc_quantize(&mut b, &cfg);
        let late_max = *res
            .history
            .last()
            .unwrap()
            .kmeans_iters
            .iter()
            .max()
            .unwrap();
        assert!(
            late_max <= 3 && late_max < cold_max.max(2),
            "late kmeans iters {late_max} vs cold {cold_max}"
        );
    }
}
