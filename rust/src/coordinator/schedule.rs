//! Penalty-parameter and learning-rate schedules (paper §3.3).

/// Multiplicative μ schedule: μ_j = μ₀ · aʲ (paper: e.g. μ₀ = 9.76e-5,
/// a = 1.1 for the LeNet experiments; μ₀ = 10, a = 1.1 for linreg).
#[derive(Clone, Copy, Debug)]
pub struct MuSchedule {
    pub mu0: f32,
    pub mult: f32,
}

impl MuSchedule {
    pub fn new(mu0: f32, mult: f32) -> MuSchedule {
        assert!(mu0 > 0.0, "mu0 must be positive");
        assert!(mult >= 1.0, "mu must be non-decreasing");
        MuSchedule { mu0, mult }
    }

    pub fn mu(&self, j: usize) -> f32 {
        self.mu0 * self.mult.powi(j as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometric_growth() {
        let s = MuSchedule::new(10.0, 1.1);
        assert_eq!(s.mu(0), 10.0);
        assert!((s.mu(1) - 11.0).abs() < 1e-5);
        assert!((s.mu(2) - 12.1).abs() < 1e-4);
        // paper's LeNet schedule
        let p = MuSchedule::new(9.76e-5, 1.1);
        assert!(p.mu(30) > p.mu(0) * 15.0);
    }

    #[test]
    fn monotone() {
        let s = MuSchedule::new(0.001, 1.2);
        for j in 0..40 {
            assert!(s.mu(j + 1) > s.mu(j));
        }
    }

    #[test]
    #[should_panic]
    fn rejects_decreasing() {
        let _ = MuSchedule::new(1.0, 0.9);
    }
}
