//! Backend-independent SGD loop used by the L step, the reference-net
//! trainer and the BinaryConnect baseline. Owns the Nesterov velocity state
//! over flat per-layer parameter vectors.

use super::{Backend, FlatGrads};
use crate::quant::{LayerQuantizer, Scheme};

/// Per-layer penalty targets for the L step (the μ/2‖w − w_C − λ/μ‖² term).
pub struct PenaltyState {
    pub wc: Vec<Vec<f32>>,
    pub lambda: Vec<Vec<f32>>,
    pub mu: f32,
}

impl PenaltyState {
    pub fn zeros_like(w: &[Vec<f32>]) -> PenaltyState {
        PenaltyState {
            wc: w.iter().map(|l| vec![0.0; l.len()]).collect(),
            lambda: w.iter().map(|l| vec![0.0; l.len()]).collect(),
            mu: 0.0,
        }
    }
}

/// Nesterov-momentum optimizer over flat per-layer parameters.
pub struct FlatNesterov {
    vw: Vec<Vec<f32>>,
    vb: Vec<Vec<f32>>,
    pub momentum: f32,
}

impl FlatNesterov {
    pub fn new(w: &[Vec<f32>], b: &[Vec<f32>], momentum: f32) -> FlatNesterov {
        FlatNesterov {
            vw: w.iter().map(|l| vec![0.0; l.len()]).collect(),
            vb: b.iter().map(|l| vec![0.0; l.len()]).collect(),
            momentum,
        }
    }

    pub fn reset(&mut self) {
        for v in self.vw.iter_mut() {
            v.fill(0.0);
        }
        for v in self.vb.iter_mut() {
            v.fill(0.0);
        }
    }

    /// In-place Nesterov update of (w, b) given gradients, lr, and an
    /// optional penalty (applied to weights only).
    pub fn step(
        &mut self,
        w: &mut [Vec<f32>],
        b: &mut [Vec<f32>],
        grads: &FlatGrads,
        lr: f32,
        penalty: Option<&PenaltyState>,
    ) {
        let m = self.momentum;
        for l in 0..w.len() {
            let (wl, gl, vl) = (&mut w[l], &grads.dw[l], &mut self.vw[l]);
            match penalty {
                Some(p) if p.mu > 0.0 => {
                    let (wc, lam, mu) = (&p.wc[l], &p.lambda[l], p.mu);
                    for i in 0..wl.len() {
                        let g = gl[i] + mu * (wl[i] - wc[i]) - lam[i];
                        vl[i] = m * vl[i] - lr * g;
                        wl[i] += m * vl[i] - lr * g;
                    }
                }
                _ => {
                    for i in 0..wl.len() {
                        vl[i] = m * vl[i] - lr * gl[i];
                        wl[i] += m * vl[i] - lr * gl[i];
                    }
                }
            }
            let (bl, gbl, vbl) = (&mut b[l], &grads.db[l], &mut self.vb[l]);
            for i in 0..bl.len() {
                vbl[i] = m * vbl[i] - lr * gbl[i];
                bl[i] += m * vbl[i] - lr * gbl[i];
            }
        }
    }
}

/// Run `steps` SGD minibatch updates on the backend's parameters.
/// Returns the average minibatch loss (without the penalty term).
pub fn run_sgd(
    backend: &mut dyn Backend,
    opt: &mut FlatNesterov,
    steps: usize,
    lr: f32,
    penalty: Option<&PenaltyState>,
) -> f32 {
    let mut w = backend.weights();
    let mut b = backend.biases();
    let mut loss_sum = 0.0f64;
    for _ in 0..steps {
        let (loss, grads) = backend.next_loss_grads();
        loss_sum += loss as f64;
        opt.step(&mut w, &mut b, &grads, lr, penalty);
        backend.set_weights(&w);
        backend.set_biases(&b);
    }
    (loss_sum / steps.max(1) as f64) as f32
}

/// Run `steps` BinaryConnect-style updates: the gradient is evaluated at the
/// *quantized* parameters, the update is applied to the *continuous* ones
/// (Courbariaux et al. 2015, deterministic rounding; generalized to any
/// fixed quantization scheme).
pub fn run_quantized_grad_sgd(
    backend: &mut dyn Backend,
    opt: &mut FlatNesterov,
    steps: usize,
    lr: f32,
    scheme: &Scheme,
    seed: u64,
) -> f32 {
    let mut w = backend.weights();
    let mut b = backend.biases();
    let mut quantizers: Vec<LayerQuantizer> = (0..w.len())
        .map(|l| LayerQuantizer::new(scheme.clone(), seed.wrapping_add(l as u64)))
        .collect();
    let mut loss_sum = 0.0f64;
    for _ in 0..steps {
        // forward/backward at quantized weights
        let wq: Vec<Vec<f32>> = w
            .iter()
            .zip(quantizers.iter_mut())
            .map(|(wl, q)| q.compress(wl).wc)
            .collect();
        backend.set_weights(&wq);
        let (loss, grads) = backend.next_loss_grads();
        loss_sum += loss as f64;
        // update applied to continuous weights
        opt.step(&mut w, &mut b, &grads, lr, None);
        backend.set_weights(&w);
        backend.set_biases(&b);
    }
    (loss_sum / steps.max(1) as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_support::small_backend;
    use crate::coordinator::Backend;

    #[test]
    fn sgd_reduces_training_loss() {
        let mut b = small_backend(10);
        let (l0, _) = b.eval_train();
        let mut opt = FlatNesterov::new(&b.weights(), &b.biases(), 0.9);
        run_sgd(&mut b, &mut opt, 60, 0.1, None);
        let (l1, _) = b.eval_train();
        assert!(l1 < l0 * 0.8, "loss {l0} -> {l1}");
    }

    #[test]
    fn penalty_with_huge_mu_dominates() {
        let mut b = small_backend(11);
        let w0 = b.weights();
        let target: Vec<Vec<f32>> = w0.iter().map(|l| vec![0.25; l.len()]).collect();
        let penalty = PenaltyState {
            wc: target.clone(),
            lambda: w0.iter().map(|l| vec![0.0; l.len()]).collect(),
            mu: 1000.0,
        };
        let mut opt = FlatNesterov::new(&b.weights(), &b.biases(), 0.9);
        // clipped lr: 1/mu
        run_sgd(&mut b, &mut opt, 150, 1.0 / 1000.0, Some(&penalty));
        // weights should be pulled near 0.25 everywhere
        let w = b.weights();
        let mean_dev: f32 = w
            .iter()
            .flat_map(|l| l.iter().map(|v| (v - 0.25).abs()))
            .sum::<f32>()
            / w.iter().map(|l| l.len()).sum::<usize>() as f32;
        assert!(mean_dev < 0.05, "mean deviation from target {mean_dev}");
    }

    #[test]
    fn quantized_grad_sgd_keeps_continuous_weights() {
        let mut b = small_backend(12);
        let mut opt = FlatNesterov::new(&b.weights(), &b.biases(), 0.9);
        run_quantized_grad_sgd(&mut b, &mut opt, 30, 0.05, &Scheme::Binary, 1);
        // Continuous weights are restored on the backend after each step,
        // and should NOT be binary.
        let w = b.weights();
        let distinct: std::collections::BTreeSet<i64> = w[0]
            .iter()
            .map(|v| (v * 1e6) as i64)
            .collect();
        assert!(distinct.len() > 2, "weights collapsed to binary");
    }

    #[test]
    fn bc_training_reduces_loss_of_binarized_net() {
        let mut b = small_backend(13);
        // loss of binarized initial net
        let quantize_all = |w: &[Vec<f32>]| -> Vec<Vec<f32>> {
            w.iter()
                .map(|wl| {
                    let (a, wc) = crate::quant::binary::binarize_with_scale(wl);
                    let _ = a;
                    wc
                })
                .collect()
        };
        let w0 = b.weights();
        b.set_weights(&quantize_all(&w0));
        let (l0, _) = b.eval_train();
        b.set_weights(&w0);
        let mut opt = FlatNesterov::new(&b.weights(), &b.biases(), 0.9);
        run_quantized_grad_sgd(&mut b, &mut opt, 120, 0.1, &Scheme::BinaryScale, 2);
        let w = b.weights();
        b.set_weights(&quantize_all(&w));
        let (l1, _) = b.eval_train();
        assert!(l1 < l0, "binarized-net loss {l0} -> {l1}");
    }
}
