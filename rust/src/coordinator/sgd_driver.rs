//! Backend-independent SGD loop used by the L step, the reference-net
//! trainer and the BinaryConnect baseline, over the flat parameter plane.
//!
//! The per-minibatch step is `next_loss_grads_into` (gradients into a
//! reusable [`GradBuffer`]) followed by the fused [`FlatNesterov::step`]
//! directly on the backend's [`crate::nn::params::ParamSet`] arena — no
//! `set_weights` copies, no per-step allocation. The [`PenaltyState`]
//! borrows the coordinator's flat `w_C`/`λ` buffers, so starting an L step
//! clones nothing.

use super::Backend;
use crate::nn::params::GradBuffer;
use crate::quant::{LayerQuantizer, QuantOut, Scheme};

pub use crate::nn::sgd::{FlatNesterov, PenaltyState};

/// Run `steps` SGD minibatch updates in place on the backend's parameters.
/// Returns the average minibatch loss (without the penalty term).
///
/// One [`GradBuffer`] is allocated per call (not per step); the step loop
/// itself is allocation- and copy-free.
pub fn run_sgd(
    backend: &mut dyn Backend,
    opt: &mut FlatNesterov,
    steps: usize,
    lr: f32,
    penalty: Option<&PenaltyState>,
) -> f32 {
    let mut grads = GradBuffer::zeros(backend.layout().clone());
    let mut loss_sum = 0.0f64;
    for _ in 0..steps {
        let loss = backend.next_loss_grads_into(&mut grads);
        loss_sum += loss as f64;
        opt.step(backend.params_mut(), &grads, lr, penalty);
    }
    (loss_sum / steps.max(1) as f64) as f32
}

/// Run `steps` BinaryConnect-style updates: the gradient is evaluated at the
/// *quantized* parameters, the update is applied to the *continuous* ones
/// (Courbariaux et al. 2015, deterministic rounding; generalized to any
/// fixed quantization scheme). The continuous weights are kept in a flat
/// side buffer; quantized weights are written into the backend's arena
/// layer by layer through reusable [`QuantOut`] buffers.
pub fn run_quantized_grad_sgd(
    backend: &mut dyn Backend,
    opt: &mut FlatNesterov,
    steps: usize,
    lr: f32,
    scheme: &Scheme,
    seed: u64,
) -> f32 {
    let layout = backend.layout().clone();
    let n_layers = layout.n_layers();
    let mut quantizers: Vec<LayerQuantizer> = (0..n_layers)
        .map(|l| LayerQuantizer::new(scheme.clone(), seed.wrapping_add(l as u64)))
        .collect();
    let mut w_cont: Vec<f32> = backend.params().w_flat().to_vec();
    let mut outs: Vec<QuantOut> = (0..n_layers).map(|_| QuantOut::default()).collect();
    let mut grads = GradBuffer::zeros(layout.clone());
    let mut loss_sum = 0.0f64;
    for _ in 0..steps {
        // forward/backward at quantized weights
        for l in 0..n_layers {
            quantizers[l].compress_into(layout.w_slice(&w_cont, l), &mut outs[l]);
            backend.params_mut().w_layer_mut(l).copy_from_slice(&outs[l].wc);
        }
        let loss = backend.next_loss_grads_into(&mut grads);
        loss_sum += loss as f64;
        // update applied to the continuous weights
        backend.set_weights_flat(&w_cont);
        opt.step(backend.params_mut(), &grads, lr, None);
        w_cont.copy_from_slice(backend.params().w_flat());
    }
    (loss_sum / steps.max(1) as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_support::small_backend;
    use crate::coordinator::Backend;

    #[test]
    fn sgd_reduces_training_loss() {
        let mut b = small_backend(10);
        let (l0, _) = b.eval_train();
        let mut opt = FlatNesterov::new(b.layout(), 0.9);
        run_sgd(&mut b, &mut opt, 60, 0.1, None);
        let (l1, _) = b.eval_train();
        assert!(l1 < l0 * 0.8, "loss {l0} -> {l1}");
    }

    #[test]
    fn penalty_with_huge_mu_dominates() {
        let mut b = small_backend(11);
        let w_len = b.layout().w_len();
        let target = vec![0.25f32; w_len];
        let lambda = vec![0.0f32; w_len];
        let mut opt = FlatNesterov::new(b.layout(), 0.9);
        let penalty = PenaltyState { wc: &target, lambda: &lambda, mu: 1000.0 };
        // clipped lr: 1/mu
        run_sgd(&mut b, &mut opt, 150, 1.0 / 1000.0, Some(&penalty));
        // weights should be pulled near 0.25 everywhere
        let w = b.params().w_flat();
        let mean_dev: f32 =
            w.iter().map(|v| (v - 0.25).abs()).sum::<f32>() / w.len() as f32;
        assert!(mean_dev < 0.05, "mean deviation from target {mean_dev}");
    }

    #[test]
    fn quantized_grad_sgd_keeps_continuous_weights() {
        let mut b = small_backend(12);
        let mut opt = FlatNesterov::new(b.layout(), 0.9);
        run_quantized_grad_sgd(&mut b, &mut opt, 30, 0.05, &Scheme::Binary, 1);
        // Continuous weights are restored on the backend after each step,
        // and should NOT be binary.
        let distinct: std::collections::BTreeSet<i64> = b
            .params()
            .w_layer(0)
            .iter()
            .map(|v| (v * 1e6) as i64)
            .collect();
        assert!(distinct.len() > 2, "weights collapsed to binary");
    }

    #[test]
    fn bc_training_reduces_loss_of_binarized_net() {
        let mut b = small_backend(13);
        // loss of binarized initial net
        let quantize_all = |w: &[Vec<f32>]| -> Vec<Vec<f32>> {
            w.iter()
                .map(|wl| {
                    let (a, wc) = crate::quant::binary::binarize_with_scale(wl);
                    let _ = a;
                    wc
                })
                .collect()
        };
        let w0 = b.weights();
        b.set_weights(&quantize_all(&w0));
        let (l0, _) = b.eval_train();
        b.set_weights(&w0);
        let mut opt = FlatNesterov::new(b.layout(), 0.9);
        run_quantized_grad_sgd(&mut b, &mut opt, 120, 0.1, &Scheme::BinaryScale, 2);
        let w = b.weights();
        b.set_weights(&quantize_all(&w));
        let (l1, _) = b.eval_train();
        assert!(l1 < l0, "binarized-net loss {l0} -> {l1}");
    }

    #[test]
    fn run_sgd_leaves_arena_in_sync_with_views() {
        let mut b = small_backend(14);
        let mut opt = FlatNesterov::new(b.layout(), 0.9);
        run_sgd(&mut b, &mut opt, 5, 0.05, None);
        // flat arena and per-layer clones must agree (no stale copies)
        let flat = b.params().w_flat().to_vec();
        let per = b.weights();
        let layout = b.layout().clone();
        for l in 0..layout.n_layers() {
            assert_eq!(per[l].as_slice(), layout.w_slice(&flat, l));
        }
    }
}
