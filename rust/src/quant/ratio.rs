//! Compression-ratio accounting, eq. (14) of the paper:
//!
//! ρ(K) = #bits(reference) / #bits(quantized), with
//! #bits(reference) = (P1 + P0)·b and
//! #bits(quantized) = P1·⌈log2 K⌉ + (P0 + K)·b,
//! where P1 = multiplicative weights, P0 = biases, b = 32 (float32).

pub const FLOAT_BITS: usize = 32;

/// ⌈log2 K⌉ (bits per quantized weight).
pub fn bits_per_weight(k: usize) -> usize {
    assert!(k >= 1);
    (usize::BITS - (k - 1).leading_zeros()) as usize
}

/// Compression ratio ρ(K) per eq. (14). `codebooks` is the number of
/// separate codebooks stored (the paper's nets use one per layer; eq. (14)
/// as printed uses one).
pub fn compression_ratio(p1: usize, p0: usize, k: usize, codebooks: usize) -> f64 {
    let b = FLOAT_BITS;
    let ref_bits = (p1 + p0) * b;
    let q_bits = p1 * bits_per_weight(k) + (p0 + codebooks * k) * b;
    ref_bits as f64 / q_bits as f64
}

/// Size in bits of a quantized net (used by the Fig. 6 tradeoff study:
/// C(K,H) ≈ (D+d)·H·log2(K) + (H+d)·b + K·b).
pub fn quantized_bits(p1: usize, p0: usize, k: usize, codebooks: usize) -> usize {
    p1 * bits_per_weight(k) + (p0 + codebooks * k) * FLOAT_BITS
}

/// Size in bits of the float32 reference net.
pub fn reference_bits(p1: usize, p0: usize) -> usize {
    (p1 + p0) * FLOAT_BITS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_weight_values() {
        assert_eq!(bits_per_weight(1), 0);
        assert_eq!(bits_per_weight(2), 1);
        assert_eq!(bits_per_weight(3), 2);
        assert_eq!(bits_per_weight(4), 2);
        assert_eq!(bits_per_weight(5), 3);
        assert_eq!(bits_per_weight(64), 6);
    }

    #[test]
    fn lenet300_ratios_match_paper_fig9() {
        // Paper Fig. 9 (LeNet300, P1=266200, P0=410, per-layer codebooks=3):
        // K=2 → ×30.5, K=4 → ×15.6, K=8 → ×10.5, K=16 → ×7.9,
        // K=32 → ×6.3, K=64 → ×5.3
        let (p1, p0) = (266_200usize, 410usize);
        let expect = [(2, 30.5), (4, 15.6), (8, 10.5), (16, 7.9), (32, 6.3), (64, 5.3)];
        for (k, rho) in expect {
            let r = compression_ratio(p1, p0, k, 3);
            assert!(
                (r - rho).abs() < 0.1,
                "K={k}: computed {r:.2} vs paper {rho}"
            );
        }
    }

    #[test]
    fn lenet5_ratios_match_paper_fig9() {
        // Paper: LeNet5 P1=430500, P0=580: K=4 → ×15.7, K=2 → ×30.7
        let (p1, p0) = (430_500usize, 580usize);
        // LeNet5 has 4 weight layers → 4 codebooks
        let r2 = compression_ratio(p1, p0, 2, 4);
        let r4 = compression_ratio(p1, p0, 4, 4);
        assert!((r2 - 30.7).abs() < 0.2, "K=2: {r2:.2}");
        assert!((r4 - 15.7).abs() < 0.2, "K=4: {r4:.2}");
    }

    #[test]
    fn approx_b_over_log2k_when_p0_small() {
        // paper: since P0 ≪ P1, ρ(K) ≈ b / log2 K
        let r = compression_ratio(1_000_000, 100, 16, 1);
        assert!((r - 8.0).abs() < 0.1, "{r}");
    }

    #[test]
    fn ratio_monotone_decreasing_in_k() {
        let mut prev = f64::INFINITY;
        for k in [2usize, 4, 8, 16, 32, 64, 256] {
            let r = compression_ratio(266_200, 410, k, 3);
            assert!(r < prev);
            prev = r;
        }
    }

    #[test]
    fn sizes_consistent() {
        let p1 = 1000;
        let p0 = 10;
        let rb = reference_bits(p1, p0);
        let qb = quantized_bits(p1, p0, 4, 1);
        assert_eq!(rb, (1010) * 32);
        assert_eq!(qb, 1000 * 2 + (10 + 4) * 32);
        let ratio = compression_ratio(p1, p0, 4, 1);
        assert!((ratio - rb as f64 / qb as f64).abs() < 1e-12);
    }
}
