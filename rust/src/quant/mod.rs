//! The C step: compression by quantization (paper §4).
//!
//! Every operator here solves `min_Θ ‖w − Δ(Θ)‖²` exactly (fixed codebooks,
//! Thms A.1–A.3) or to a k-means local optimum (adaptive codebook), as the
//! constrained-optimization formulation dictates — no ad-hoc rounding.

pub mod binary;
pub mod fixed;
pub mod kmeans;
pub mod pow2;
pub mod ratio;
pub mod scale_alt;
pub mod ternary;

use crate::util::rng::Rng;

/// Quantization scheme (what Δ(Θ) looks like).
#[derive(Clone, Debug, PartialEq)]
pub enum Scheme {
    /// Adaptive codebook with K entries, learned by k-means (§4.1).
    AdaptiveCodebook { k: usize },
    /// Fixed, user-supplied codebook (§4.2); entries need not be sorted.
    FixedCodebook { codebook: Vec<f32> },
    /// {−1, +1}.
    Binary,
    /// {−a, +a} with learned scale (Thm A.2).
    BinaryScale,
    /// {−1, 0, +1}.
    Ternary,
    /// {−a, 0, +a} with learned scale (Thm A.3).
    TernaryScale,
    /// {0, ±1, ±2⁻¹, …, ±2⁻ᶜ} (Thm A.1).
    PowersOfTwo { c: u32 },
    /// Adaptive codebook with one centroid pinned at zero — quantization
    /// *plus pruning* (paper §4.2, footnote 2: the future-work extension).
    AdaptiveWithZero { k: usize },
}

impl Scheme {
    /// Effective codebook size K (for the compression-ratio formula).
    pub fn codebook_size(&self) -> usize {
        match self {
            Scheme::AdaptiveCodebook { k } | Scheme::AdaptiveWithZero { k } => *k,
            Scheme::FixedCodebook { codebook } => codebook.len(),
            Scheme::Binary | Scheme::BinaryScale => 2,
            Scheme::Ternary | Scheme::TernaryScale => 3,
            Scheme::PowersOfTwo { c } => 2 * (*c as usize + 1) + 1,
        }
    }

    /// Number of *learned* shared parameters stored alongside assignments
    /// (adaptive codebook entries, or the scale).
    pub fn shared_params(&self) -> usize {
        match self {
            Scheme::AdaptiveCodebook { k } => *k,
            Scheme::AdaptiveWithZero { k } => *k - 1,
            Scheme::FixedCodebook { .. } | Scheme::Binary | Scheme::Ternary
            | Scheme::PowersOfTwo { .. } => 0,
            Scheme::BinaryScale | Scheme::TernaryScale => 1,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Scheme::AdaptiveCodebook { k } => format!("adaptive K={k}"),
            Scheme::FixedCodebook { codebook } => format!("fixed K={}", codebook.len()),
            Scheme::Binary => "binary {-1,+1}".into(),
            Scheme::BinaryScale => "binary scale {-a,+a}".into(),
            Scheme::Ternary => "ternary {-1,0,+1}".into(),
            Scheme::TernaryScale => "ternary scale {-a,0,+a}".into(),
            Scheme::PowersOfTwo { c } => format!("pow2 C={c}"),
            Scheme::AdaptiveWithZero { k } => format!("adaptive+zero K={k}"),
        }
    }
}

/// Result of one C step on one layer. The buffers are **reusable**: the LC
/// loop keeps one `QuantOut` per layer and calls
/// [`LayerQuantizer::compress_into`] every iteration, so the C step
/// allocates nothing in steady state.
#[derive(Clone, Debug, Default)]
pub struct QuantOut {
    /// Quantized weights w_C = Δ(Θ), same length as the input.
    pub wc: Vec<f32>,
    /// The codebook actually used (learned or fixed; scaled codebooks
    /// report the scaled entries). Always sorted ascending.
    pub codebook: Vec<f32>,
    /// Codebook index per weight: `wc[i] == codebook[assignments[i]]`.
    /// This is the low-bit representation the packed serving format stores
    /// (⌈log₂K⌉ bits each, paper §5) — kept here so packing never has to
    /// re-derive nearest-centroid assignments from the dense `wc`.
    pub assignments: Vec<u32>,
    /// Inner iterations spent (k-means iterations; 1 for closed forms).
    pub iterations: usize,
}

/// Stateful per-layer quantizer: adaptive codebooks warm-start from the
/// previous C step's centroids (paper §3.3: "k-means is initialized from
/// the previous iteration's codebook"); fixed codebooks cache their sorted
/// form + Voronoi midpoints.
pub struct LayerQuantizer {
    pub scheme: Scheme,
    /// Warm-start centroids for the adaptive scheme.
    state: Option<Vec<f32>>,
    /// (sorted codebook, midpoints) cache for `Scheme::FixedCodebook`.
    fixed: Option<(Vec<f32>, Vec<f32>)>,
    /// Codebook cache for `Scheme::PowersOfTwo`.
    pow2_cb: Option<Vec<f32>>,
    /// Reusable Lloyd-pass buffers (midpoints + per-part reductions), so
    /// steady-state adaptive C steps allocate nothing — even the threaded
    /// assignment passes above the 2M-weight threshold.
    scratch: kmeans::AssignScratch,
    rng: Rng,
}

impl LayerQuantizer {
    pub fn new(scheme: Scheme, seed: u64) -> LayerQuantizer {
        let fixed = if let Scheme::FixedCodebook { codebook } = &scheme {
            let mut sorted = codebook.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mids = kmeans::midpoints(&sorted);
            Some((sorted, mids))
        } else {
            None
        };
        let pow2_cb = if let Scheme::PowersOfTwo { c } = &scheme {
            Some(pow2::codebook(*c))
        } else {
            None
        };
        LayerQuantizer {
            scheme,
            state: None,
            fixed,
            pow2_cb,
            scratch: kmeans::AssignScratch::default(),
            rng: Rng::new(seed),
        }
    }

    /// Solve the C step for this layer's (shifted) weights, writing the
    /// result into the reusable `out` buffers — the non-allocating form the
    /// LC loop uses on its per-layer arena views.
    pub fn compress_into(&mut self, w: &[f32], out: &mut QuantOut) {
        out.iterations = 1;
        match &self.scheme {
            Scheme::AdaptiveCodebook { k } => {
                let mut centroids = match self.state.take() {
                    Some(c) if c.len() == *k => c,
                    _ => kmeans::kmeans_pp_init(w, *k, &mut self.rng),
                };
                out.iterations = kmeans::kmeans_1d_scratch(
                    w,
                    &mut centroids,
                    200,
                    &mut out.wc,
                    &mut out.assignments,
                    &mut self.scratch,
                );
                out.codebook.clear();
                out.codebook.extend_from_slice(&centroids);
                self.state = Some(centroids);
            }
            Scheme::FixedCodebook { .. } => {
                let (sorted, mids) = self.fixed.as_ref().expect("fixed codebook cache");
                out.assignments.clear();
                out.assignments
                    .extend(w.iter().map(|&x| kmeans::nearest_via_mids(mids, x) as u32));
                out.wc.clear();
                out.wc.extend(out.assignments.iter().map(|&a| sorted[a as usize]));
                out.codebook.clear();
                out.codebook.extend_from_slice(sorted);
            }
            Scheme::Binary => {
                binary::binarize_into(w, &mut out.wc);
                sign_assignments_into(&out.wc, &mut out.assignments);
                set_codebook(&mut out.codebook, &[-1.0, 1.0]);
            }
            Scheme::BinaryScale => {
                let a = binary::optimal_scale(w);
                binary::scaled_binarize_into(w, a, &mut out.wc);
                // a == mean|w| ≥ 0, so [-a, a] is sorted; the sign of the
                // *input* picks the entry (wc is ±a, possibly ±0).
                sign_assignments_into(w, &mut out.assignments);
                set_codebook(&mut out.codebook, &[-a, a]);
            }
            Scheme::Ternary => {
                ternary::scaled_ternarize_into(w, 1.0, &mut out.wc);
                ternary_assignments_into(&out.wc, &mut out.assignments);
                set_codebook(&mut out.codebook, &[-1.0, 0.0, 1.0]);
            }
            Scheme::TernaryScale => {
                let a = ternary::optimal_scale(w);
                ternary::scaled_ternarize_into(w, a, &mut out.wc);
                ternary_assignments_into(&out.wc, &mut out.assignments);
                set_codebook(&mut out.codebook, &[-a, 0.0, a]);
            }
            Scheme::PowersOfTwo { c } => {
                out.wc.clear();
                out.assignments.clear();
                for &t in w {
                    let v = pow2::q_pow2(t, *c);
                    out.wc.push(v);
                    out.assignments.push(pow2::index_in_codebook(v, *c));
                }
                let cb = self
                    .pow2_cb
                    .as_ref()
                    .expect("pow2 codebook cache");
                debug_assert_eq!(cb.len(), 2 * (*c as usize + 1) + 1);
                set_codebook(&mut out.codebook, cb);
            }
            Scheme::AdaptiveWithZero { k } => {
                let mut centroids = match self.state.take() {
                    Some(c) if c.len() == *k => c,
                    _ => {
                        let mut c = kmeans::kmeans_pp_init(w, *k, &mut self.rng);
                        // pin the entry nearest zero to exactly zero
                        let nearest = (0..c.len())
                            .min_by(|&a, &b| c[a].abs().partial_cmp(&c[b].abs()).unwrap())
                            .unwrap();
                        c[nearest] = 0.0;
                        c
                    }
                };
                out.iterations = kmeans::kmeans_1d_zero_pinned_scratch(
                    w,
                    &mut centroids,
                    200,
                    &mut out.wc,
                    &mut out.assignments,
                    &mut self.scratch,
                );
                out.codebook.clear();
                out.codebook.extend_from_slice(&centroids);
                self.state = Some(centroids);
            }
        }
    }

    /// Solve the C step, returning fresh buffers (allocating convenience).
    pub fn compress(&mut self, w: &[f32]) -> QuantOut {
        let mut out = QuantOut::default();
        self.compress_into(w, &mut out);
        out
    }

    /// Reset warm-start state (e.g. when restarting the LC loop).
    pub fn reset(&mut self) {
        self.state = None;
    }
}

/// Overwrite a reusable codebook buffer with the given entries.
fn set_codebook(dst: &mut Vec<f32>, entries: &[f32]) {
    dst.clear();
    dst.extend_from_slice(entries);
}

/// Codebook index from the sign convention of eq. (12): negative → entry 0,
/// non-negative (sgn(0) = +1) → entry 1 of a `[-a, a]` codebook.
fn sign_assignments_into(w: &[f32], out: &mut Vec<u32>) {
    out.clear();
    out.extend(w.iter().map(|&t| (t >= 0.0) as u32));
}

/// Codebook index for ternarized values in `[-a, 0, a]`.
fn ternary_assignments_into(wc: &[f32], out: &mut Vec<u32>) {
    out.clear();
    out.extend(wc.iter().map(|&v| {
        if v == 0.0 {
            1u32
        } else if v < 0.0 {
            0
        } else {
            2
        }
    }));
}

/// Squared distortion ‖w − wc‖² — the quantity the C step minimizes.
pub fn distortion(w: &[f32], wc: &[f32]) -> f64 {
    w.iter()
        .zip(wc)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn codebook_sizes() {
        assert_eq!(Scheme::AdaptiveCodebook { k: 4 }.codebook_size(), 4);
        assert_eq!(Scheme::Binary.codebook_size(), 2);
        assert_eq!(Scheme::TernaryScale.codebook_size(), 3);
        // C=2: {0, ±1, ±1/2, ±1/4} → 7 entries
        assert_eq!(Scheme::PowersOfTwo { c: 2 }.codebook_size(), 7);
    }

    #[test]
    fn quantizer_outputs_live_in_codebook() {
        check("wc ⊆ codebook", 60, |g| {
            let w = g.weights(200, 1.0);
            let schemes = [
                Scheme::AdaptiveCodebook { k: g.usize_in(1, 6) },
                Scheme::Binary,
                Scheme::BinaryScale,
                Scheme::Ternary,
                Scheme::TernaryScale,
                Scheme::PowersOfTwo { c: 3 },
                Scheme::FixedCodebook { codebook: vec![-0.7, 0.1, 0.9] },
            ];
            for scheme in schemes {
                let mut q = LayerQuantizer::new(scheme.clone(), 1 + g.case as u64);
                let out = q.compress(&w);
                assert_eq!(out.wc.len(), w.len());
                for &v in &out.wc {
                    assert!(
                        out.codebook.iter().any(|&c| (c - v).abs() < 1e-6),
                        "{scheme:?}: {v} not in {:?}",
                        out.codebook
                    );
                }
            }
        });
    }

    #[test]
    fn assignments_index_sorted_codebook() {
        // wc[i] == codebook[assignments[i]] for every scheme — the
        // invariant the packed serving format depends on.
        check("assignments consistent", 40, |g| {
            let w = g.weights(150, 1.0);
            let schemes = [
                Scheme::AdaptiveCodebook { k: g.usize_in(1, 6) },
                Scheme::AdaptiveWithZero { k: g.usize_in(2, 6) },
                Scheme::Binary,
                Scheme::BinaryScale,
                Scheme::Ternary,
                Scheme::TernaryScale,
                Scheme::PowersOfTwo { c: g.usize_in(0, 5) as u32 },
                Scheme::FixedCodebook { codebook: vec![0.4, -0.7, 0.0] },
            ];
            for scheme in schemes {
                let mut q = LayerQuantizer::new(scheme.clone(), 3 + g.case as u64);
                let out = q.compress(&w);
                assert_eq!(out.assignments.len(), w.len());
                assert!(
                    out.codebook.windows(2).all(|p| p[0] <= p[1]),
                    "{scheme:?}: codebook not sorted: {:?}",
                    out.codebook
                );
                for (i, &a) in out.assignments.iter().enumerate() {
                    assert!(
                        (a as usize) < out.codebook.len(),
                        "{scheme:?}: index {a} out of range"
                    );
                    assert_eq!(
                        out.wc[i], out.codebook[a as usize],
                        "{scheme:?}: wc[{i}]={} != codebook[{a}]",
                        out.wc[i]
                    );
                }
            }
        });
    }

    #[test]
    fn compress_into_reuses_buffers_and_matches_compress() {
        // one QuantOut recycled across schemes and inputs must equal the
        // allocating form every time (the LC loop's usage pattern)
        check("compress_into == compress", 40, |g| {
            let schemes = [
                Scheme::AdaptiveCodebook { k: g.usize_in(1, 6) },
                Scheme::AdaptiveWithZero { k: g.usize_in(2, 6) },
                Scheme::Binary,
                Scheme::BinaryScale,
                Scheme::Ternary,
                Scheme::TernaryScale,
                Scheme::PowersOfTwo { c: g.usize_in(0, 4) as u32 },
                Scheme::FixedCodebook { codebook: vec![0.4, -0.7, 0.0] },
            ];
            for scheme in schemes {
                let mut q_into = LayerQuantizer::new(scheme.clone(), 10 + g.case as u64);
                let mut q_alloc = LayerQuantizer::new(scheme.clone(), 10 + g.case as u64);
                let mut out = QuantOut::default();
                // two rounds with different lengths: buffers shrink/grow
                for len in [120usize, 80] {
                    let w = g.weights(len, 1.0);
                    q_into.compress_into(&w, &mut out);
                    let fresh = q_alloc.compress(&w);
                    assert_eq!(out.wc, fresh.wc, "{scheme:?} wc");
                    assert_eq!(out.codebook, fresh.codebook, "{scheme:?} codebook");
                    assert_eq!(out.assignments, fresh.assignments, "{scheme:?} assignments");
                    assert_eq!(out.iterations, fresh.iterations, "{scheme:?} iterations");
                }
            }
        });
    }

    #[test]
    fn adaptive_warm_start_reduces_iterations() {
        let mut rng = crate::util::rng::Rng::new(5);
        let w: Vec<f32> = (0..5000).map(|_| rng.normal(0.0, 1.0)).collect();
        let mut q = LayerQuantizer::new(Scheme::AdaptiveCodebook { k: 8 }, 7);
        let first = q.compress(&w);
        let second = q.compress(&w); // same data, warm centroids
        assert!(second.iterations <= 2, "warm start took {}", second.iterations);
        assert!(first.iterations >= second.iterations);
    }

    #[test]
    fn adaptive_with_zero_prunes() {
        let mut rng = crate::util::rng::Rng::new(8);
        // mixture: many near-zero weights + two shifted clusters
        let mut w: Vec<f32> = (0..1500).map(|_| rng.normal(0.0, 0.02)).collect();
        w.extend((0..250).map(|_| rng.normal(0.6, 0.05)));
        w.extend((0..250).map(|_| rng.normal(-0.6, 0.05)));
        let mut q = LayerQuantizer::new(Scheme::AdaptiveWithZero { k: 3 }, 4);
        let out = q.compress(&w);
        // exactly one centroid at 0, and most small weights pruned to it
        assert_eq!(out.codebook.iter().filter(|&&c| c == 0.0).count(), 1);
        let pruned = out.wc.iter().filter(|&&v| v == 0.0).count();
        assert!(pruned > 1200, "only {pruned} weights pruned");
        // cluster centroids recovered
        assert!(out.codebook.iter().any(|&c| (c - 0.6).abs() < 0.1));
        assert!(out.codebook.iter().any(|&c| (c + 0.6).abs() < 0.1));
        // warm start converges immediately on a second call
        let again = q.compress(&w);
        assert!(again.iterations <= 2);
    }

    #[test]
    fn adaptive_with_zero_never_beats_free_adaptive_on_distortion() {
        check("zero-pinned >= free", 20, |g| {
            let w = g.weights(300, 0.5);
            let mut q_free = LayerQuantizer::new(Scheme::AdaptiveCodebook { k: 4 }, 9);
            let mut q_zero = LayerQuantizer::new(Scheme::AdaptiveWithZero { k: 4 }, 9);
            let d_free = distortion(&w, &q_free.compress(&w).wc);
            let d_zero = distortion(&w, &q_zero.compress(&w).wc);
            // pinning is a constraint: allow local-optimum noise but the
            // pinned variant should not be dramatically better
            assert!(d_zero + 1e-9 >= d_free * 0.5, "free {d_free} zero {d_zero}");
        });
    }

    #[test]
    fn distortion_zero_iff_equal() {
        let w = [0.5f32, -0.25];
        assert_eq!(distortion(&w, &w), 0.0);
        assert!(distortion(&w, &[0.5, 0.25]) > 0.0);
    }
}
