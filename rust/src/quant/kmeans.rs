//! 1-D k-means for the adaptive-codebook C step (paper §4.1).
//!
//! The paper notes that scalar k-means admits an `O(P log K)` assignment
//! step: sort the K centroids, then each weight's nearest centroid is found
//! by binary search over the K−1 midpoints (the Voronoi boundaries of a
//! 1-D codebook are the midpoints, eq. 11). The centroid step is `O(P)`.
//! Initialization is k-means++ (Arthur & Vassilvitskii 2007) on the first
//! compression, warm-started thereafter (§3.3).

use crate::util::rng::Rng;

/// Result of a k-means run.
pub struct KmeansResult {
    /// Quantized weights (each input mapped to its centroid).
    pub wc: Vec<f32>,
    /// Assignment index per weight (into the *final sorted* centroid array).
    pub assignments: Vec<u32>,
    /// Iterations until convergence.
    pub iterations: usize,
}

/// k-means++ seeding for scalar data.
pub fn kmeans_pp_init(data: &[f32], k: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(k >= 1);
    assert!(!data.is_empty());
    let mut centroids = Vec::with_capacity(k);
    centroids.push(data[rng.below(data.len())]);
    // squared distance to the nearest chosen centroid
    let mut d2: Vec<f64> = data
        .iter()
        .map(|&x| ((x - centroids[0]) as f64).powi(2))
        .collect();
    while centroids.len() < k {
        let idx = rng.sample_weighted(&d2);
        let c = data[idx];
        centroids.push(c);
        for (di, &x) in d2.iter_mut().zip(data) {
            let nd = ((x - c) as f64).powi(2);
            if nd < *di {
                *di = nd;
            }
        }
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    centroids
}

/// Index of the nearest centroid via binary search over midpoints.
/// `centroids` must be sorted ascending.
#[inline]
pub fn nearest_sorted(centroids: &[f32], x: f32) -> usize {
    // partition_point gives the count of midpoints <= x; that count is the
    // Voronoi cell index (eq. 11 with half-open cells).
    let k = centroids.len();
    if k == 1 {
        return 0;
    }
    // binary search over implicit midpoints m_i = (c_i + c_{i+1})/2
    let mut lo = 0usize;
    let mut hi = k - 1; // cell index range [0, k-1]
    while lo < hi {
        let mid = (lo + hi) / 2;
        let boundary = 0.5 * (centroids[mid] + centroids[mid + 1]);
        if x < boundary {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// Voronoi boundaries (midpoints) of a sorted codebook — precompute once,
/// assign many (§Perf optimization #3).
pub fn midpoints(centroids: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    midpoints_into(centroids, &mut out);
    out
}

/// [`midpoints`] into a reusable buffer (the per-Lloyd-pass form: no
/// allocation once the buffer is warm).
pub fn midpoints_into(centroids: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(centroids.windows(2).map(|p| 0.5 * (p[0] + p[1])));
}

/// Cell index from precomputed midpoints: count of boundaries ≤ x
/// (eq. 11's upper-cell tie-break). For small K a branchless linear scan
/// beats binary search (no mispredicted branches, autovectorizes); large K
/// falls back to `partition_point`.
#[inline]
pub fn nearest_via_mids(mids: &[f32], x: f32) -> usize {
    if mids.len() <= 32 {
        let mut idx = 0usize;
        for &m in mids {
            idx += (x >= m) as usize;
        }
        idx
    } else {
        mids.partition_point(|&m| m <= x)
    }
}

/// Data size above which the assignment step fans out across the worker
/// pool. Dispatch through the persistent pool costs only a few µs (no
/// spawns — cf. the ~50µs/thread `thread::scope` it replaced), and the
/// per-part `sums`/`counts` reduction regions live in a reusable
/// [`AssignScratch`] (no allocation when warm) — but the O(parts·K) merge
/// and the cache cost of splitting the scan remain, so threading only wins
/// when each Lloyd pass is ≫ the scan cost of a LeNet-scale layer (266k
/// weights ≈ 1.5ms). Crossover measured at ≈ 2M — VGG-scale layers
/// (§Perf optimization #4).
const PAR_MIN_DATA: usize = 2_000_000;

/// Reusable buffers for the assignment+accumulate pass: Voronoi midpoints
/// plus flat `parts × K` per-part reduction regions (`sums`/`counts`) and
/// per-part changed flags. One scratch lives on each
/// [`crate::quant::LayerQuantizer`], so steady-state Lloyd passes allocate
/// nothing — including the threaded passes above the 2M-weight threshold
/// (asserted with the counting allocator in `rust/tests/flat_params.rs`).
#[derive(Default)]
pub struct AssignScratch {
    mids: Vec<f32>,
    /// Flat `parts × K` partial sums; region `0..K` holds the merged total
    /// after a pass.
    sums: Vec<f64>,
    /// Flat `parts × K` partial counts, merged like `sums`.
    counts: Vec<usize>,
    /// Per-part "some assignment changed" flags.
    changed: Vec<bool>,
}

/// One assignment+accumulate pass (threaded above [`PAR_MIN_DATA`]).
/// Returns whether any assignment changed; the merged per-centroid sums
/// and counts are left in `scratch.sums[..k]` / `scratch.counts[..k]`.
fn assign_pass(
    data: &[f32],
    centroids: &[f32],
    assignments: &mut [u32],
    scratch: &mut AssignScratch,
) -> bool {
    let k = centroids.len();
    let AssignScratch { mids, sums, counts, changed } = scratch;
    midpoints_into(centroids, mids);
    let nt = crate::linalg::num_threads();
    let parts = if data.len() < PAR_MIN_DATA || nt == 1 {
        1
    } else {
        crate::linalg::pool::global().width()
    };
    sums.clear();
    sums.resize(parts * k, 0.0);
    counts.clear();
    counts.resize(parts * k, 0);
    changed.clear();
    changed.resize(parts, false);
    if parts == 1 {
        for (i, &x) in data.iter().enumerate() {
            let a = nearest_via_mids(mids, x) as u32;
            if a != assignments[i] {
                assignments[i] = a;
                changed[0] = true;
            }
            sums[a as usize] += x as f64;
            counts[a as usize] += 1;
        }
        return changed[0];
    }
    let chunk = data.len().div_ceil(parts);
    {
        use crate::linalg::pool::DisjointMut;
        let assign_parts = DisjointMut::new(assignments);
        let sum_parts = DisjointMut::new(sums);
        let count_parts = DisjointMut::new(counts);
        let changed_parts = DisjointMut::new(changed);
        let mids: &[f32] = mids;
        crate::linalg::pool::run(parts, |p| {
            let lo = p * chunk;
            let hi = data.len().min(lo + chunk);
            if lo >= hi {
                return;
            }
            // SAFETY: part `p` runs exactly once and owns data chunk
            // `lo..hi`, reduction region `p*k..(p+1)*k` and changed slot
            // `p` exclusively.
            let sums = unsafe { sum_parts.take(p * k..(p + 1) * k) };
            let counts = unsafe { count_parts.take(p * k..(p + 1) * k) };
            let changed = unsafe { &mut changed_parts.take(p..p + 1)[0] };
            let ahead = unsafe { assign_parts.take(lo..hi) };
            for (i, &x) in data[lo..hi].iter().enumerate() {
                let a = nearest_via_mids(mids, x) as u32;
                if a != ahead[i] {
                    ahead[i] = a;
                    *changed = true;
                }
                sums[a as usize] += x as f64;
                counts[a as usize] += 1;
            }
        });
    }
    // merge part regions 1.. into region 0 (fixed order: deterministic for
    // a given thread policy)
    let (head_s, tail_s) = sums.split_at_mut(k);
    let (head_c, tail_c) = counts.split_at_mut(k);
    for p in 0..parts - 1 {
        for j in 0..k {
            head_s[j] += tail_s[p * k + j];
            head_c[j] += tail_c[p * k + j];
        }
    }
    changed.iter().any(|&c| c)
}

/// Lloyd iterations until assignments stabilize, writing the quantized
/// weights and assignment indices into **reusable buffers** (the C step
/// calls this once per layer per LC iteration; in steady state the buffers
/// are already sized and nothing allocates). `centroids` is used as the
/// warm start and overwritten with the final (sorted) codebook. Returns the
/// iteration count.
pub fn kmeans_1d_into(
    data: &[f32],
    centroids: &mut Vec<f32>,
    max_iter: usize,
    wc: &mut Vec<f32>,
    assignments: &mut Vec<u32>,
) -> usize {
    let mut scratch = AssignScratch::default();
    kmeans_1d_scratch(data, centroids, max_iter, wc, assignments, &mut scratch)
}

/// [`kmeans_1d_into`] with caller-owned [`AssignScratch`] — the fully
/// non-allocating form: warm-started C steps reuse the midpoint and
/// reduction buffers across Lloyd passes *and* across LC iterations
/// ([`crate::quant::LayerQuantizer`] owns one scratch per layer).
pub fn kmeans_1d_scratch(
    data: &[f32],
    centroids: &mut Vec<f32>,
    max_iter: usize,
    wc: &mut Vec<f32>,
    assignments: &mut Vec<u32>,
    scratch: &mut AssignScratch,
) -> usize {
    let k = centroids.len();
    assert!(k >= 1);
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assignments.clear();
    assignments.resize(data.len(), u32::MAX);
    let mut iterations = 0;
    for _ in 0..max_iter {
        iterations += 1;
        // assignment step: O(P log K), threaded (§Perf #3/#4)
        let changed = assign_pass(data, centroids, assignments, scratch);
        if !changed && iterations > 1 {
            iterations -= 1; // final pass only verified convergence
            break;
        }
        // centroid step: empty clusters keep their previous value
        for j in 0..k {
            if scratch.counts[j] > 0 {
                centroids[j] = (scratch.sums[j] / scratch.counts[j] as f64) as f32;
            }
        }
        // means of ordered cells stay ordered, but empty-cluster carry-over
        // can break ties; re-sort defensively (cheap: K is tiny).
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if !changed {
            break;
        }
    }
    wc.clear();
    wc.extend(assignments.iter().map(|&a| centroids[a as usize]));
    iterations
}

/// Lloyd iterations until assignments stabilize (allocating convenience
/// around [`kmeans_1d_into`]).
pub fn kmeans_1d(data: &[f32], centroids: &mut Vec<f32>, max_iter: usize) -> KmeansResult {
    let mut wc = Vec::new();
    let mut assignments = Vec::new();
    let iterations = kmeans_1d_into(data, centroids, max_iter, &mut wc, &mut assignments);
    KmeansResult { wc, assignments, iterations }
}

/// Convenience: full k-means from k-means++ init.
pub fn kmeans(data: &[f32], k: usize, rng: &mut Rng, max_iter: usize) -> (Vec<f32>, KmeansResult) {
    let mut centroids = kmeans_pp_init(data, k, rng);
    let res = kmeans_1d(data, &mut centroids, max_iter);
    (centroids, res)
}

/// k-means with one centroid **pinned at zero** — the paper's footnote 2:
/// "we can also achieve *pruning* together with quantization by having one
/// centroid be fixed to zero". Lloyd iterations where the zero centroid
/// never moves; weights assigned to it are pruned.
pub fn kmeans_1d_zero_pinned(
    data: &[f32],
    centroids: &mut Vec<f32>,
    max_iter: usize,
) -> KmeansResult {
    let mut wc = Vec::new();
    let mut assignments = Vec::new();
    let iterations =
        kmeans_1d_zero_pinned_into(data, centroids, max_iter, &mut wc, &mut assignments);
    KmeansResult { wc, assignments, iterations }
}

/// Buffer-reusing form of [`kmeans_1d_zero_pinned`]; returns the iteration
/// count.
pub fn kmeans_1d_zero_pinned_into(
    data: &[f32],
    centroids: &mut Vec<f32>,
    max_iter: usize,
    wc: &mut Vec<f32>,
    assignments: &mut Vec<u32>,
) -> usize {
    let mut scratch = AssignScratch::default();
    kmeans_1d_zero_pinned_scratch(data, centroids, max_iter, wc, assignments, &mut scratch)
}

/// [`kmeans_1d_zero_pinned_into`] with caller-owned [`AssignScratch`].
/// Shares the assignment pass with the free-codebook form, so the
/// zero-pinned C step also threads above the 2M threshold and allocates
/// nothing when warm; only the centroid step differs (the zero entry
/// never moves).
pub fn kmeans_1d_zero_pinned_scratch(
    data: &[f32],
    centroids: &mut Vec<f32>,
    max_iter: usize,
    wc: &mut Vec<f32>,
    assignments: &mut Vec<u32>,
    scratch: &mut AssignScratch,
) -> usize {
    let k = centroids.len();
    assert!(k >= 1);
    // ensure exactly one entry is 0 (insert if absent, replacing nearest)
    if !centroids.iter().any(|&c| c == 0.0) {
        let nearest = (0..k)
            .min_by(|&a, &b| {
                centroids[a]
                    .abs()
                    .partial_cmp(&centroids[b].abs())
                    .unwrap()
            })
            .unwrap();
        centroids[nearest] = 0.0;
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assignments.clear();
    assignments.resize(data.len(), u32::MAX);
    let mut iterations = 0;
    for _ in 0..max_iter {
        iterations += 1;
        let changed = assign_pass(data, centroids, assignments, scratch);
        if !changed && iterations > 1 {
            iterations -= 1;
            break;
        }
        for j in 0..k {
            if centroids[j] != 0.0 && scratch.counts[j] > 0 {
                centroids[j] = (scratch.sums[j] / scratch.counts[j] as f64) as f32;
            }
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if !changed {
            break;
        }
    }
    wc.clear();
    wc.extend(assignments.iter().map(|&a| centroids[a as usize]));
    iterations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::distortion;
    use crate::util::prop::check;

    #[test]
    fn nearest_sorted_matches_linear_scan() {
        check("nearest==scan", 200, |g| {
            let k = g.usize_in(1, 9);
            let c = g.sorted_codebook(k, -2.0, 2.0);
            let x = g.f32_in(-3.0, 3.0);
            let fast = nearest_sorted(&c, x);
            let slow = (0..k)
                .min_by(|&a, &b| {
                    (c[a] - x)
                        .abs()
                        .partial_cmp(&(c[b] - x).abs())
                        .unwrap()
                })
                .unwrap();
            // ties can go either way; accept equal distance
            assert!(
                ((c[fast] - x).abs() - (c[slow] - x).abs()).abs() < 1e-6,
                "x={x} c={c:?} fast={fast} slow={slow}"
            );
        });
    }

    #[test]
    fn k1_centroid_is_mean() {
        let data = [1.0f32, 2.0, 3.0, 6.0];
        let mut c = vec![0.0f32];
        let res = kmeans_1d(&data, &mut c, 10);
        assert!((c[0] - 3.0).abs() < 1e-6);
        assert!(res.wc.iter().all(|&v| (v - 3.0).abs() < 1e-6));
    }

    #[test]
    fn recovers_well_separated_clusters() {
        let mut rng = Rng::new(1);
        let mut data = Vec::new();
        for &centre in &[-5.0f32, 0.0, 5.0] {
            for _ in 0..200 {
                data.push(centre + rng.normal(0.0, 0.1));
            }
        }
        let (centroids, _res) = kmeans(&data, 3, &mut rng, 100);
        assert!((centroids[0] + 5.0).abs() < 0.1, "{centroids:?}");
        assert!(centroids[1].abs() < 0.1, "{centroids:?}");
        assert!((centroids[2] - 5.0).abs() < 0.1, "{centroids:?}");
    }

    #[test]
    fn monotone_distortion_over_iterations() {
        // each full Lloyd iteration must not increase distortion
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..2000).map(|_| rng.normal(0.0, 1.0)).collect();
        let mut centroids = kmeans_pp_init(&data, 8, &mut rng);
        let mut prev = f64::INFINITY;
        for _ in 0..20 {
            let res = kmeans_1d(&data, &mut centroids, 1);
            let d = distortion(&data, &res.wc);
            assert!(d <= prev + 1e-9, "distortion increased {prev} -> {d}");
            prev = d;
        }
    }

    #[test]
    fn kmeanspp_centroids_come_from_data() {
        let mut rng = Rng::new(5);
        let data: Vec<f32> = (0..100).map(|_| rng.normal(0.0, 2.0)).collect();
        let c = kmeans_pp_init(&data, 10, &mut rng);
        assert_eq!(c.len(), 10);
        for v in &c {
            assert!(data.iter().any(|d| (d - v).abs() < 1e-7));
        }
        assert!(c.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn more_centroids_never_hurt_distortion() {
        check("K monotone", 20, |g| {
            let mut rng = g.rng.split();
            let data: Vec<f32> = (0..500).map(|_| rng.normal(0.0, 1.0)).collect();
            let (_, r2) = kmeans(&data, 2, &mut rng, 100);
            let (_, r8) = kmeans(&data, 8, &mut rng, 100);
            let d2 = distortion(&data, &r2.wc);
            let d8 = distortion(&data, &r8.wc);
            // k-means++ with more K should be clearly better on gaussian data
            assert!(d8 < d2, "d8={d8} d2={d2}");
        });
    }

    #[test]
    fn assignments_index_final_codebook() {
        let mut rng = Rng::new(9);
        let data: Vec<f32> = (0..300).map(|_| rng.normal(0.0, 1.0)).collect();
        let (centroids, res) = kmeans(&data, 4, &mut rng, 100);
        for (i, &a) in res.assignments.iter().enumerate() {
            assert_eq!(res.wc[i], centroids[a as usize]);
        }
    }

    #[test]
    fn converged_state_is_fixed_point() {
        let mut rng = Rng::new(11);
        let data: Vec<f32> = (0..1000).map(|_| rng.normal(0.0, 1.0)).collect();
        let (mut centroids, _) = kmeans(&data, 5, &mut rng, 200);
        let before = centroids.clone();
        let res = kmeans_1d(&data, &mut centroids, 200);
        assert_eq!(res.iterations, 1, "re-running converged kmeans should stop at once");
        for (a, b) in before.iter().zip(&centroids) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn duplicate_data_more_k_than_distinct_values() {
        let data = vec![1.0f32; 50];
        let mut rng = Rng::new(13);
        let (centroids, res) = kmeans(&data, 4, &mut rng, 50);
        // all assignments map to a centroid equal to 1.0
        assert!(res.wc.iter().all(|&v| (v - 1.0).abs() < 1e-6));
        assert!(centroids.iter().any(|&c| (c - 1.0).abs() < 1e-6));
    }
}
