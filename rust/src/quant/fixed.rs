//! Fixed-codebook C step (paper §4.2, eq. 10–11): each weight maps to its
//! nearest codebook entry. For scalar weights the solution is independent
//! of the penalty choice (the real line is totally ordered).

use super::kmeans::{midpoints, nearest_via_mids};

/// Quantize to the nearest entry of a **sorted** codebook (eq. 11).
pub fn quantize_fixed(w: &[f32], sorted_codebook: &[f32]) -> Vec<f32> {
    assert!(!sorted_codebook.is_empty());
    debug_assert!(sorted_codebook.windows(2).all(|p| p[0] <= p[1]));
    let mids = midpoints(sorted_codebook);
    w.iter()
        .map(|&x| sorted_codebook[nearest_via_mids(&mids, x)])
        .collect()
}

/// Assignment indices rather than values.
pub fn assign_fixed(w: &[f32], sorted_codebook: &[f32]) -> Vec<u32> {
    let mids = midpoints(sorted_codebook);
    w.iter()
        .map(|&x| nearest_via_mids(&mids, x) as u32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::distortion;
    use crate::util::prop::check;

    #[test]
    fn voronoi_boundaries_are_midpoints() {
        let cb = [-1.0f32, 0.0, 2.0];
        // midpoints: -0.5 and 1.0
        assert_eq!(quantize_fixed(&[-0.6], &cb), vec![-1.0]);
        assert_eq!(quantize_fixed(&[-0.4], &cb), vec![0.0]);
        assert_eq!(quantize_fixed(&[0.99], &cb), vec![0.0]);
        assert_eq!(quantize_fixed(&[1.01], &cb), vec![2.0]);
        // exactly at boundary: eq. 11 assigns the upper cell
        assert_eq!(quantize_fixed(&[1.0], &cb), vec![2.0]);
    }

    #[test]
    fn optimality_vs_brute_force() {
        check("fixed quantization optimal", 150, |g| {
            let k = g.usize_in(1, 8);
            let cb = g.sorted_codebook(k, -2.0, 2.0);
            let w = g.weights(64, 1.5);
            let wc = quantize_fixed(&w, &cb);
            // per-element: no codebook entry is strictly closer
            for (x, q) in w.iter().zip(&wc) {
                for c in &cb {
                    assert!(
                        (x - q).abs() <= (x - c).abs() + 1e-6,
                        "x={x} q={q} better c={c}"
                    );
                }
            }
            // global: distortion ≤ any single-entry assignment
            for c in &cb {
                let alt: Vec<f32> = vec![*c; w.len()];
                assert!(distortion(&w, &wc) <= distortion(&w, &alt) + 1e-6);
            }
        });
    }

    #[test]
    fn idempotent() {
        check("quantize idempotent", 80, |g| {
            let k = g.usize_in(1, 6);
            let cb = g.sorted_codebook(k, -1.0, 1.0);
            let w = g.weights(32, 1.0);
            let q1 = quantize_fixed(&w, &cb);
            let q2 = quantize_fixed(&q1, &cb);
            assert_eq!(q1, q2);
        });
    }

    #[test]
    fn assignments_match_values() {
        let cb = [-0.5f32, 0.5];
        let w = [-1.0f32, -0.1, 0.2, 3.0];
        let idx = assign_fixed(&w, &cb);
        let q = quantize_fixed(&w, &cb);
        for (i, &a) in idx.iter().enumerate() {
            assert_eq!(q[i], cb[a as usize]);
        }
        assert_eq!(idx, vec![0, 0, 1, 1]);
    }
}
