//! Binarization operators (paper Fig. 5, Thm A.2).

/// sgn as defined in eq. (12): sgn(0) = +1.
#[inline]
pub fn sgn(t: f32) -> f32 {
    if t < 0.0 {
        -1.0
    } else {
        1.0
    }
}

/// Binarize into a reusable buffer (scale 1): `out[i] = sgn(w[i])`.
pub fn binarize_into(w: &[f32], out: &mut Vec<f32>) {
    scaled_binarize_into(w, 1.0, out);
}

/// Binarize to {−1, +1}.
pub fn binarize(w: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    binarize_into(w, &mut out);
    out
}

/// The optimal binarization scale a = mean |wᵢ| (Thm A.2).
pub fn optimal_scale(w: &[f32]) -> f32 {
    crate::linalg::vecops::mean_abs(w)
}

/// `out[i] = a · sgn(w[i])` into a reusable buffer.
pub fn scaled_binarize_into(w: &[f32], a: f32, out: &mut Vec<f32>) {
    out.clear();
    out.extend(w.iter().map(|&t| a * sgn(t)));
}

/// Binarize to {−a, +a} with the optimal scale a = mean |wᵢ| (Thm A.2).
/// Returns (a, quantized weights).
pub fn binarize_with_scale(w: &[f32]) -> (f32, Vec<f32>) {
    let a = optimal_scale(w);
    let mut out = Vec::new();
    scaled_binarize_into(w, a, &mut out);
    (a, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::distortion;
    use crate::util::prop::check;

    #[test]
    fn sgn_convention() {
        assert_eq!(sgn(-0.1), -1.0);
        assert_eq!(sgn(0.0), 1.0); // eq. (12): sgn(0) = +1
        assert_eq!(sgn(0.1), 1.0);
    }

    #[test]
    fn binarize_values() {
        assert_eq!(binarize(&[-2.0, 0.0, 3.0]), vec![-1.0, 1.0, 1.0]);
    }

    #[test]
    fn scale_is_mean_abs() {
        let (a, wc) = binarize_with_scale(&[-2.0, 4.0]);
        assert_eq!(a, 3.0);
        assert_eq!(wc, vec![-3.0, 3.0]);
    }

    #[test]
    fn scale_optimality_thm_a2() {
        // E(a) = Σ(wᵢ − a·sgn(wᵢ))² is minimized at a* = mean|wᵢ|:
        // check a* beats a dense grid of alternative scales.
        check("thm A.2 optimal", 100, |g| {
            let w = g.weights(64, 1.0);
            let (a_star, wc) = binarize_with_scale(&w);
            let e_star = distortion(&w, &wc);
            for i in 0..=50 {
                let a = a_star.max(0.1) * 2.0 * (i as f32) / 50.0;
                let alt: Vec<f32> = w.iter().map(|&t| a * sgn(t)).collect();
                let e_alt = distortion(&w, &alt);
                // tolerance is relative: near the flat minimum f32 rounding
                // of a* can differ from the grid point by O(eps)
                assert!(
                    e_star <= e_alt + 1e-5 + 1e-5 * e_alt,
                    "a={a} (E={e_alt}) beats a*={a_star} (E={e_star})"
                );
            }
        });
    }

    #[test]
    fn binary_beats_no_assignment_flip() {
        // For the optimal a, flipping any single sign must not help.
        check("sign assignment optimal", 60, |g| {
            let w = g.weights(20, 1.0);
            let (a, wc) = binarize_with_scale(&w);
            let base = distortion(&w, &wc);
            for i in 0..w.len() {
                let mut alt = wc.clone();
                alt[i] = -alt[i];
                assert!(base <= distortion(&w, &alt) + 1e-6, "flip {i} helps; a={a}");
            }
        });
    }

    #[test]
    fn empty_input() {
        let (a, wc) = binarize_with_scale(&[]);
        assert_eq!(a, 0.0);
        assert!(wc.is_empty());
    }
}
