//! Powers-of-two quantization (paper Fig. 5, Thm A.1): codebook
//! {0, ±1, ±2⁻¹, …, ±2⁻ᶜ}, solved in O(1) per weight.

use super::binary::sgn;

/// The explicit codebook for a given C, sorted ascending.
pub fn codebook(c: u32) -> Vec<f32> {
    let mut cb = vec![0.0f32];
    for i in 0..=c {
        let v = 2.0f32.powi(-(i as i32));
        cb.push(v);
        cb.push(-v);
    }
    cb.sort_by(|a, b| a.partial_cmp(b).unwrap());
    cb
}

/// Optimal quantization operator q(t) from Thm A.1 — reference form
/// (explicit `log2`, matches the theorem statement line by line).
#[inline]
pub fn q_pow2_reference(t: f32, c: u32) -> f32 {
    if t == 0.0 {
        return 0.0;
    }
    let f = -t.abs().log2();
    let cf = c as f32;
    let alpha = if f > cf + 1.0 {
        0.0
    } else if f <= 0.0 {
        1.0
    } else if f > cf {
        // f ∈ (C, C+1]
        2.0f32.powi(-(c as i32))
    } else {
        // f ∈ (0, C]: α = 2^−⌊f + log2(3/2)⌋
        let i = (f + (1.5f32).log2()).floor() as i32;
        2.0f32.powi(-i)
    };
    alpha * sgn(t)
}

/// Optimal quantization operator q(t) from Thm A.1 — branch-light bit form
/// (§Perf optimization #2; ~2.5× over the reference).
///
/// Derivation: with |t| = m·2ᵉ (m ∈ [1,2)), ⌊f + log₂(3/2)⌋ =
/// ⌊−e + (log₂1.5 − log₂ m)⌋ = −e − [m > 1.5], so the cell index is
/// `clamp(−e − [m > 1.5], 0, C)` after handling the prune region
/// |t| < 2^(−C−1) with one compare. The resulting power of two is built
/// directly from its exponent bits. Exactly equal to the reference
/// (property-tested, including the 3·2^(−i−2) boundaries and subnormals).
#[inline]
pub fn q_pow2(t: f32, c: u32) -> f32 {
    let u = t.abs();
    // prune region: f > C+1  ⇔  u < 2^(−C−1); also catches 0 and subnormals
    let zero_thresh = f32::from_bits((126 - c) << 23); // 2^(−C−1)
    if u < zero_thresh {
        return 0.0;
    }
    let bits = u.to_bits();
    let e = ((bits >> 23) & 0xff) as i32 - 127;
    let m_gt_15 = ((bits & 0x7f_ffff) > 0x40_0000) as i32;
    let i = (-e - m_gt_15).clamp(0, c as i32);
    let alpha = f32::from_bits(((127 - i) as u32) << 23); // 2^(−i)
    if t < 0.0 {
        -alpha
    } else {
        alpha
    }
}

/// Quantize a slice.
pub fn quantize_pow2(w: &[f32], c: u32) -> Vec<f32> {
    w.iter().map(|&t| q_pow2(t, c)).collect()
}

/// Index of a quantized value `v` (an exact output of [`q_pow2`]) in the
/// sorted [`codebook`], computed in O(1) from the exponent bits: the
/// ascending order is `[-2⁰, …, -2⁻ᶜ, 0, 2⁻ᶜ, …, 2⁰]`, so `-2⁻ⁱ` sits at
/// `i` and `+2⁻ⁱ` at `2C+2-i`.
#[inline]
pub fn index_in_codebook(v: f32, c: u32) -> u32 {
    if v == 0.0 {
        return c + 1;
    }
    let i = 127 - ((v.abs().to_bits() >> 23) & 0xff); // v = ±2^(−i)
    debug_assert!(i <= c, "value {v} not in pow2 codebook C={c}");
    if v < 0.0 {
        i
    } else {
        2 * c + 2 - i
    }
}

/// Quantize a slice and also return codebook indices (for bit-packing).
pub fn quantize_pow2_with_assignments(w: &[f32], c: u32) -> (Vec<f32>, Vec<u32>) {
    let mut wc = Vec::with_capacity(w.len());
    let mut idx = Vec::with_capacity(w.len());
    for &t in w {
        let v = q_pow2(t, c);
        wc.push(v);
        idx.push(index_in_codebook(v, c));
    }
    (wc, idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fixed::quantize_fixed;
    use crate::util::prop::check;

    #[test]
    fn codebook_contents() {
        let cb = codebook(2);
        assert_eq!(cb, vec![-1.0, -0.5, -0.25, 0.0, 0.25, 0.5, 1.0]);
    }

    #[test]
    fn zero_maps_to_zero() {
        assert_eq!(q_pow2(0.0, 3), 0.0);
    }

    #[test]
    fn saturation_regions() {
        // |t| >= 1 saturates to ±1 (f <= 0)
        assert_eq!(q_pow2(5.0, 3), 1.0);
        assert_eq!(q_pow2(-1.0, 3), -1.0);
        // very small |t| maps to 0 (f > C+1)
        assert_eq!(q_pow2(1e-6, 3), 0.0);
        assert_eq!(q_pow2(-1e-6, 3), 0.0);
    }

    #[test]
    fn closed_form_matches_nearest_entry() {
        // Thm A.1's O(1) formula must agree with brute nearest-codebook
        // assignment (ties: both are valid minimizers; compare distances).
        check("pow2 == nearest", 400, |g| {
            let c = g.usize_in(0, 6) as u32;
            let cb = codebook(c);
            let t = match g.usize_in(0, 2) {
                0 => g.f32_in(-2.0, 2.0),
                1 => g.f32_in(-0.01, 0.01),
                _ => g.f32_in(-2.0, 2.0) * 2.0f32.powi(-(g.usize_in(0, 8) as i32)),
            };
            let fast = q_pow2(t, c);
            let near = quantize_fixed(&[t], &cb)[0];
            assert!(
                ((t - fast).abs() - (t - near).abs()).abs() < 1e-6,
                "t={t} C={c}: fast={fast} near={near}"
            );
        });
    }

    #[test]
    fn boundary_cases_exact() {
        // boundary between 2^-i and 2^-(i+1) is at 3·2^-(i+2) (midpoint)
        let c = 4u32;
        for i in 0..3i32 {
            let boundary = 3.0 * 2.0f32.powi(-i - 2);
            let eps = boundary * 1e-4;
            let hi = q_pow2(boundary + eps, c);
            let lo = q_pow2(boundary - eps, c);
            assert_eq!(hi, 2.0f32.powi(-i), "above boundary i={i}");
            assert_eq!(lo, 2.0f32.powi(-i - 1), "below boundary i={i}");
        }
    }

    #[test]
    fn c_zero_is_signed_binary_with_zero() {
        // C=0: codebook {−1, 0, +1} but boundaries from pow2 geometry:
        // |t| <= 1/2 → 0, |t| ∈ (1/2, ...] → ±1
        assert_eq!(codebook(0), vec![-1.0, 0.0, 1.0]);
        assert_eq!(q_pow2(0.4, 0), 0.0);
        assert_eq!(q_pow2(0.6, 0), 1.0);
        assert_eq!(q_pow2(-0.7, 0), -1.0);
    }

    #[test]
    fn fast_form_equals_reference_everywhere() {
        check("pow2 fast == reference", 600, |g| {
            let c = g.usize_in(0, 8) as u32;
            let t = match g.usize_in(0, 3) {
                0 => g.f32_in(-2.0, 2.0),
                1 => g.f32_in(-1e-8, 1e-8),
                2 => {
                    // exact boundaries 3·2^(−i−2) and centroids 2^(−i)
                    let i = g.usize_in(0, 9) as i32;
                    let base = if g.bool() { 3.0 * 2.0f32.powi(-i - 2) } else { 2.0f32.powi(-i) };
                    if g.bool() { base } else { -base }
                }
                _ => g.f32_in(-2.0, 2.0) * 2.0f32.powi(-(g.usize_in(0, 12) as i32)),
            };
            let fast = q_pow2(t, c);
            let slow = q_pow2_reference(t, c);
            // both must be optimal; at exact ties they may pick either
            // neighbour, so compare distances, not values
            assert!(
                ((t - fast).abs() - (t - slow).abs()).abs() < 1e-12,
                "t={t} C={c}: fast={fast} ref={slow}"
            );
        });
    }

    #[test]
    fn fast_form_handles_subnormals_and_extremes() {
        assert_eq!(q_pow2(f32::MIN_POSITIVE / 2.0, 6), 0.0); // subnormal
        assert_eq!(q_pow2(1e30, 3), 1.0);
        assert_eq!(q_pow2(-1e30, 3), -1.0);
        assert_eq!(q_pow2(0.0, 0), 0.0);
    }

    #[test]
    fn index_in_codebook_matches_position() {
        check("pow2 index", 300, |g| {
            let c = g.usize_in(0, 6) as u32;
            let cb = codebook(c);
            let t = g.f32_in(-2.0, 2.0) * 2.0f32.powi(-(g.usize_in(0, 8) as i32));
            let v = q_pow2(t, c);
            let idx = index_in_codebook(v, c) as usize;
            assert!(idx < cb.len(), "t={t} C={c} idx={idx}");
            assert_eq!(cb[idx], v, "t={t} C={c}");
        });
    }

    #[test]
    fn assignments_index_codebook() {
        let w = [0.9f32, -0.3, 0.0, 1e-6, -1.4];
        let c = 3;
        let cb = codebook(c);
        let (wc, idx) = quantize_pow2_with_assignments(&w, c);
        assert_eq!(wc, quantize_pow2(&w, c));
        for (v, &a) in wc.iter().zip(&idx) {
            assert_eq!(cb[a as usize], *v);
        }
    }

    #[test]
    fn idempotent() {
        check("pow2 idempotent", 100, |g| {
            let c = g.usize_in(0, 5) as u32;
            let t = g.f32_in(-2.0, 2.0);
            let q1 = q_pow2(t, c);
            assert_eq!(q_pow2(q1, c), q1, "t={t} C={c}");
        });
    }
}
