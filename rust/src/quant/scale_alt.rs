//! General fixed-codebook-with-adaptive-scale solver (paper §4.2.1,
//! eq. 13): alternate the assignment step and the closed-form scale step
//! until fixed point. Binarization/ternarization with scale have exact
//! closed forms (Thms A.2/A.3, in `binary`/`ternary`); this module covers
//! arbitrary fixed codebooks rescaled by a learned a > 0 — and serves as an
//! independent oracle for those closed forms in tests.

use super::kmeans::nearest_sorted;

/// Result of the alternating solve.
pub struct ScaledQuant {
    pub a: f32,
    pub wc: Vec<f32>,
    pub iterations: usize,
}

/// Solve min_{Z,a} Σ‖wᵢ − a·c_{κ(i)}‖² for a fixed codebook (sorted
/// ascending) by alternating optimization. `a0` is the initial scale.
pub fn quantize_fixed_with_scale(
    w: &[f32],
    sorted_codebook: &[f32],
    a0: f32,
    max_iter: usize,
) -> ScaledQuant {
    assert!(!sorted_codebook.is_empty());
    let mut a = if a0 > 0.0 { a0 } else { 1.0 };
    let mut assign: Vec<usize> = vec![usize::MAX; w.len()];
    let mut iterations = 0;
    for _ in 0..max_iter {
        iterations += 1;
        // assignment step: nearest a·c_k — equivalently nearest c_k to w/a
        let mut changed = false;
        for (i, &x) in w.iter().enumerate() {
            let k = nearest_sorted(sorted_codebook, x / a);
            if k != assign[i] {
                assign[i] = k;
                changed = true;
            }
        }
        // scale step: a = Σ wᵢ·c_{κ(i)} / Σ c_{κ(i)}²
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (i, &x) in w.iter().enumerate() {
            let c = sorted_codebook[assign[i]] as f64;
            num += x as f64 * c;
            den += c * c;
        }
        let new_a = if den > 0.0 { (num / den) as f32 } else { a };
        let done = !changed && (new_a - a).abs() <= 1e-7 * a.abs().max(1.0);
        a = new_a;
        if done {
            break;
        }
    }
    let wc = assign
        .iter()
        .map(|&k| a * sorted_codebook[k])
        .collect();
    ScaledQuant { a, wc, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::distortion;
    use crate::util::prop::check;

    #[test]
    fn binary_scale_matches_thm_a2_closed_form() {
        check("alt == A.2", 60, |g| {
            let w = g.weights(64, 1.0);
            if w.is_empty() {
                return;
            }
            let (a_cf, wc_cf) = crate::quant::binary::binarize_with_scale(&w);
            let alt = quantize_fixed_with_scale(&w, &[-1.0, 1.0], a_cf.max(0.1), 100);
            // alternating optimization can only match or (in odd local
            // optima) slightly trail the exact solution
            let (e_cf, e_alt) = (distortion(&w, &wc_cf), distortion(&w, &alt.wc));
            assert!(e_cf <= e_alt + 1e-5, "closed form {e_cf} vs alt {e_alt}");
            // seeded at the optimum, alternation must stay there
            assert!((alt.a - a_cf).abs() < 1e-4 * a_cf.abs().max(1e-3));
        });
    }

    #[test]
    fn ternary_scale_alternation_not_better_than_thm_a3() {
        check("A.3 >= alt", 60, |g| {
            let w = g.weights(64, 1.0);
            if w.is_empty() {
                return;
            }
            let (a_cf, wc_cf) = crate::quant::ternary::ternarize_with_scale(&w);
            if a_cf == 0.0 {
                return;
            }
            // alternation from several starts; A.3 (exact) must beat or tie all
            let e_cf = distortion(&w, &wc_cf);
            for mult in [0.3f32, 1.0, 2.0] {
                let alt =
                    quantize_fixed_with_scale(&w, &[-1.0, 0.0, 1.0], a_cf * mult, 200);
                let e_alt = distortion(&w, &alt.wc);
                assert!(e_cf <= e_alt + 1e-4 + 1e-4 * e_alt.abs(), "A.3 {e_cf} vs alt {e_alt} (mult {mult})");
            }
        });
    }

    #[test]
    fn alternation_monotone_distortion() {
        // one outer iteration at a time must never increase distortion
        let mut rng = crate::util::rng::Rng::new(3);
        let w: Vec<f32> = (0..500).map(|_| rng.normal(0.0, 1.0)).collect();
        let cb = [-1.0f32, -0.25, 0.25, 1.0];
        let mut prev = f64::INFINITY;
        let mut a = 0.7f32;
        for _ in 0..10 {
            let r = quantize_fixed_with_scale(&w, &cb, a, 1);
            let d = distortion(&w, &r.wc);
            assert!(d <= prev + 1e-6, "{prev} -> {d}");
            prev = d;
            a = r.a;
        }
    }

    #[test]
    fn converges_quickly_on_easy_data() {
        // data at exact ±2 with codebook {−1,+1} → a = 2 in one shot
        let w = vec![2.0f32, -2.0, 2.0, -2.0];
        let r = quantize_fixed_with_scale(&w, &[-1.0, 1.0], 1.0, 50);
        assert!((r.a - 2.0).abs() < 1e-6);
        assert_eq!(r.wc, vec![2.0, -2.0, 2.0, -2.0]);
        assert!(r.iterations <= 3);
    }
}
