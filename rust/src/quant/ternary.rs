//! Ternarization operators (paper Fig. 5, Thm A.3).
//!
//! Without scale, the codebook {−1, 0, +1} quantizes by eq. (11):
//! |t| < 1/2 → 0, else sgn(t). With scale, Thm A.3 gives the exact
//! solution: sort |w| descending, pick j* = argmax_j (1/√j)·Σ_{i≤j}|w_i|,
//! set a* as the mean magnitude of those j* weights, and zero every weight
//! with |w| < a*/2. (Li et al. 2016 use an approximation; this is the
//! optimal solution.)

use super::binary::sgn;

/// `out[i] = 0 if |w[i]| < a/2 else a·sgn(w[i])` into a reusable buffer —
/// the eq. (11) assignment for the {−a, 0, +a} codebook.
pub fn scaled_ternarize_into(w: &[f32], a: f32, out: &mut Vec<f32>) {
    let half = 0.5 * a;
    out.clear();
    out.extend(w.iter().map(|&t| if t.abs() < half { 0.0 } else { a * sgn(t) }));
}

/// Ternarize to {−1, 0, +1}.
pub fn ternarize(w: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    scaled_ternarize_into(w, 1.0, &mut out);
    out
}

/// The exact optimal ternarization scale (Thm A.3): sort |w| descending,
/// j* = argmax_j (1/√j)·Σ_{i≤j}|w_i|, a* = mean magnitude of those j*.
/// Runtime O(P log P) (dominated by the sort).
pub fn optimal_scale(w: &[f32]) -> f32 {
    if w.is_empty() {
        return 0.0;
    }
    // Sort magnitudes descending. §Perf optimization #1: non-negative f32
    // order equals their bit-pattern order as u32, so sort integer keys
    // (pdqsort on u32 beats the float comparator by ~3×).
    let mut mags: Vec<u32> = w.iter().map(|t| t.abs().to_bits()).collect();
    mags.sort_unstable_by(|a, b| b.cmp(a));
    // prefix sums; j* = argmax (1/sqrt(j)) * prefix[j]
    let mut best_j = 1usize;
    let mut best_val = f64::NEG_INFINITY;
    let mut prefix = 0.0f64;
    let mut best_prefix = 0.0f64;
    for (j, &m) in mags.iter().enumerate() {
        prefix += f32::from_bits(m) as f64;
        let val = prefix / ((j + 1) as f64).sqrt();
        if val > best_val {
            best_val = val;
            best_j = j + 1;
            best_prefix = prefix;
        }
    }
    (best_prefix / best_j as f64) as f32
}

/// Ternarize to {−a, 0, +a} with the exact optimal scale (Thm A.3).
/// Returns (a, quantized weights).
pub fn ternarize_with_scale(w: &[f32]) -> (f32, Vec<f32>) {
    let a = optimal_scale(w);
    let mut wc = Vec::new();
    scaled_ternarize_into(w, a, &mut wc);
    (a, wc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::distortion;
    use crate::util::prop::check;

    #[test]
    fn ternarize_thresholds() {
        assert_eq!(
            ternarize(&[-0.6, -0.4, 0.0, 0.49, 0.5, 2.0]),
            vec![-1.0, 0.0, 0.0, 0.0, 1.0, 1.0]
        );
    }

    /// Brute-force solver for Thm A.3: try every candidate support size and
    /// dense grid of scales.
    fn brute_force(w: &[f32]) -> (f32, f64) {
        let mut best = (0.0f32, f64::INFINITY);
        // candidate scales: from the theorem's structure, a is a mean of a
        // magnitude prefix — but scan a dense grid too for safety.
        let max_mag = w.iter().fold(0.0f32, |m, &t| m.max(t.abs()));
        for i in 0..=400 {
            let a = max_mag * 1.2 * i as f32 / 400.0;
            let wc: Vec<f32> = w
                .iter()
                .map(|&t| if t.abs() < 0.5 * a { 0.0 } else { a * sgn(t) })
                .collect();
            let e = distortion(w, &wc);
            if e < best.1 {
                best = (a, e);
            }
        }
        best
    }

    #[test]
    fn thm_a3_matches_brute_force() {
        check("thm A.3 optimal", 80, |g| {
            let w = g.weights(40, 1.0);
            let (a, wc) = ternarize_with_scale(&w);
            let e = distortion(&w, &wc);
            let (a_bf, e_bf) = brute_force(&w);
            assert!(
                e <= e_bf + 1e-4 + 1e-3 * e_bf,
                "analytic a={a} E={e} vs brute a={a_bf} E={e_bf}"
            );
        });
    }

    #[test]
    fn scale_positive_for_nonzero_input() {
        let (a, _) = ternarize_with_scale(&[0.1, -0.2, 0.3]);
        assert!(a > 0.0);
    }

    #[test]
    fn all_zero_input() {
        let (a, wc) = ternarize_with_scale(&[0.0, 0.0]);
        assert_eq!(a, 0.0);
        assert_eq!(wc, vec![0.0, 0.0]);
    }

    #[test]
    fn consistency_property_from_proof() {
        // The proof shows |w_{j*}| > a/2 > |w_{j*+1}|: the support selected
        // by the threshold equals the argmax prefix.
        check("A.3 support consistent", 60, |g| {
            let w = g.weights(50, 1.0);
            if w.is_empty() {
                return;
            }
            let (a, wc) = ternarize_with_scale(&w);
            if a == 0.0 {
                return;
            }
            // recompute support from threshold; mean of |w| on support == a
            let support: Vec<f32> = w
                .iter()
                .zip(&wc)
                .filter(|(_, &q)| q != 0.0)
                .map(|(&t, _)| t.abs())
                .collect();
            if support.is_empty() {
                return;
            }
            let mean: f32 = support.iter().sum::<f32>() / support.len() as f32;
            assert!((mean - a).abs() < 1e-4, "mean {mean} vs a {a}");
        });
    }

    #[test]
    fn single_weight() {
        let (a, wc) = ternarize_with_scale(&[-0.7]);
        assert!((a - 0.7).abs() < 1e-6);
        assert_eq!(wc, vec![-0.7]);
    }

    #[test]
    fn ternary_with_scale_beats_binary_with_scale_when_many_zeros() {
        // weights clustered at 0 plus a few large: ternary should win
        let mut w = vec![0.01f32; 100];
        w.extend_from_slice(&[1.0, -1.0, 1.0, -1.0]);
        let (_, tern) = ternarize_with_scale(&w);
        let (_, bin) = crate::quant::binary::binarize_with_scale(&w);
        assert!(distortion(&w, &tern) < distortion(&w, &bin));
    }
}
