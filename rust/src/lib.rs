//! # lcquant — Learning-Compression quantization of neural nets
//!
//! Reproduction of Carreira-Perpiñán & Idelbayev (2017), *"Model compression
//! as constrained optimization, with application to neural nets. Part II:
//! quantization"*.
//!
//! The library is organised as a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   augmented-Lagrangian LC loop ([`coordinator`]), the C-step quantization
//!   operators ([`quant`]), the DC / iDC / BinaryConnect baselines, the
//!   experiment harness ([`experiments`]) and every substrate they need
//!   ([`linalg`], [`nn`], [`data`], [`util`], [`config`], [`metrics`]).
//! * **L2** — a JAX training graph (`python/compile/model.py`), lowered once
//!   (AOT) to HLO text and executed from rust via PJRT ([`runtime`]).
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the codebook
//!   matmul hot-spot, validated against a pure-jnp oracle at build time.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## Quickstart
//!
//! ```no_run
//! use lcquant::coordinator::{LcConfig, lc_quantize};
//! use lcquant::nn::{Mlp, MlpSpec};
//! use lcquant::data::synth_mnist::SynthMnist;
//! use lcquant::quant::Scheme;
//!
//! let data = SynthMnist::generate(2_000, 42);
//! let mut net = Mlp::new(&MlpSpec::lenet300(), 1);
//! // ... train the reference net, then:
//! let cfg = LcConfig { scheme: Scheme::AdaptiveCodebook { k: 2 }, ..LcConfig::default() };
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod nn;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
