//! # lcquant — Learning-Compression quantization of neural nets
//!
//! Reproduction of Carreira-Perpiñán & Idelbayev (2017), *"Model compression
//! as constrained optimization, with application to neural nets. Part II:
//! quantization"*.
//!
//! The library is organised as a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution *and its
//!   deployment story*: the augmented-Lagrangian LC loop ([`coordinator`]),
//!   the C-step quantization operators ([`quant`]), the DC / iDC /
//!   BinaryConnect baselines, the experiment harness ([`experiments`]),
//!   the **serving subsystem** ([`serve`]: packed `.lcq` model artifacts
//!   at ⌈log₂K⌉ bits/weight, a LUT inference engine that never expands
//!   dense weights, a micro-batching server and a multi-model registry),
//!   the **network plane** ([`net`]: the LCQ-RPC framed wire protocol
//!   over TCP, a connection plane with bounded in-flight budgets and
//!   explicit overload shedding, a blocking client library and a load
//!   generator — see `docs/wire-protocol.md`),
//!   and every substrate they need ([`linalg`], [`nn`], [`data`],
//!   [`util`], [`config`], [`metrics`]).
//! * **L2** — a JAX training graph (`python/compile/model.py`), lowered once
//!   (AOT) to HLO text and executed from rust via PJRT (the `runtime`
//!   module, behind the `pjrt` cargo feature; stubbed unless real xla-rs
//!   bindings are linked — see `vendor/xla/README.md`).
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the codebook
//!   matmul hot-spot, validated against a pure-jnp oracle at build time.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## The flat parameter plane
//!
//! Every backend stores its parameters in one contiguous arena
//! ([`nn::params::ParamSet`]): all weights first, then all biases, with a
//! [`nn::params::ParamLayout`] offset table handing out per-layer
//! `&[f32]` views. The whole LC hot path runs on it in place:
//!
//! * [`coordinator::Backend::next_loss_grads_into`] streams gradients into
//!   a reusable [`nn::params::GradBuffer`] (same layout);
//! * [`nn::sgd::FlatNesterov::step`] is one fused loop over the arena —
//!   penalty gradient `μ(w − w_C) − λ` included — so a minibatch step does
//!   **zero heap allocation and zero full-parameter copies**
//!   (`benches/bench_lstep.rs` measures this and emits `BENCH_lstep.json`);
//! * the C step quantizes per-layer views and writes back through the same
//!   layout; `w_C` and `λ` are flat buffers allocated once per LC run.
//!
//! ## Threading: one persistent multi-task pool, explicit SIMD
//!
//! All data-parallel compute kernels — the gemm cores, the k-means
//! assignment pass, the serve engine's LUT matvec — dispatch through one
//! lazily-initialized persistent worker pool ([`linalg::pool`]), sized by
//! [`linalg::num_threads`] (override with `LCQUANT_THREADS`, clamped
//! `1..=16`). Dispatch takes *borrowed* closures published into a small
//! ring of task slots: **no thread spawns and no heap allocation per
//! call**, so the per-minibatch step path stays allocation-free even when
//! threaded (asserted in `rust/tests/flat_params.rs`; measured against the
//! old per-call `thread::scope` fan-out in `benches/bench_lstep.rs` →
//! `BENCH_pool.json`). The pool runs up to [`linalg::pool::TASK_SLOTS`]
//! tasks **concurrently** — workers claim parts across all live tasks and
//! completion is per-task — so the serve plane pipelines layer bands of
//! different requests (`benches/bench_serve.rs` →
//! `BENCH_serve_pipeline.json`), and nested dispatch fans out instead of
//! serializing (a full ring degrades to inline execution, never a
//! deadlock). Blocking request drivers (the serve smoke clients) use
//! [`linalg::pool::run_scoped`], keeping the pool free for the engine.
//! The [`linalg::vecops`] hot kernels are SIMD-explicit 8-lane forms with
//! bit-exact [`linalg::vecops::scalar`] references (golden-pinned, so the
//! LC parity tests stay bit-for-bit); `gather_sum` upgrades itself to an
//! AVX2 `vgatherdps` form at runtime, same 8-lane reduction definition.
//!
//! ## Documentation plane
//!
//! Standalone documents live in `docs/` and are kept in lockstep with the
//! code by CI (`cargo doc --no-deps` runs with `-D warnings`; format tests
//! pin the written spec):
//!
//! * `docs/ARCHITECTURE.md` — module map, the L step → C step → pack →
//!   serve dataflow, the [`nn::params::ParamSet`] arena layout, and the
//!   pool dispatch state machine;
//! * `docs/lcq-format.md` — the byte-level `.lcq` specification for
//!   third-party readers, including the exact size equation cross-checked
//!   against [`quant::ratio`] (eq. 14) in unit tests;
//! * `docs/wire-protocol.md` — the LCQ-RPC v2 byte-level contract,
//!   including the `Stats` exposition frames;
//! * `docs/OBSERVABILITY.md` — the metrics registry, trace spans and
//!   snapshot schema served by the [`obs`] plane (its claims — zero-alloc
//!   hot path, percentile parity, exact-count Stats round-trips — are
//!   pinned by `rust/tests/obs.rs`).
//!
//! ## Quickstart: train → quantize → pack → serve
//!
//! ```no_run
//! use lcquant::coordinator::sgd_driver::{run_sgd, FlatNesterov};
//! use lcquant::coordinator::{lc_quantize, Backend, LcConfig, NativeBackend};
//! use lcquant::data::synth_mnist::SynthMnist;
//! use lcquant::nn::{Mlp, MlpSpec};
//! use lcquant::quant::Scheme;
//! use lcquant::serve::{MicroBatchServer, PackedModel, Registry, ServerConfig};
//! use std::sync::Arc;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut data = SynthMnist::generate(2_000, 42);
//! data.subtract_mean(None);
//! let spec = MlpSpec::lenet300();
//! let net = Mlp::new(&spec, 1);
//! let mut backend = NativeBackend::new(net, data, None, 128, 1);
//!
//! // train the reference net: the optimizer state mirrors the flat arena
//! let mut opt = FlatNesterov::new(backend.layout(), 0.95);
//! run_sgd(&mut backend, &mut opt, 600, 0.1, None);
//!
//! // LC-quantize to 1 bit/weight (w_C, λ and the penalized SGD all run
//! // over the flat parameter plane — no per-step parameter copies)
//! let cfg = LcConfig { scheme: Scheme::AdaptiveCodebook { k: 2 }, ..LcConfig::default() };
//! let lc = lc_quantize(&mut backend, &cfg);
//!
//! // pack the final C step (log2(K) bits/weight + codebook, paper §5);
//! // biases come straight from the backend's arena views
//! let model = PackedModel::from_lc("lenet300-k2", &spec, &lc, backend.params())?;
//! model.save(std::path::Path::new("models/lenet300-k2.lcq"))?;
//!
//! // serve it (lookup-based forward, micro-batched; paper §2.1)
//! let registry = Arc::new(Registry::load_dir(std::path::Path::new("models"))?);
//! let server = MicroBatchServer::start(registry, ServerConfig::default());
//! let logits = server.client().infer("lenet300-k2", vec![0.0; 784]);
//! # let _ = logits;
//! # Ok(())
//! # }
//! ```

// The numeric kernels index several parallel slices per loop iteration and
// pass warm-start `&mut Vec` buffers by design; clippy's
// `needless_range_loop`/`ptr_arg` flag those idioms even where the
// alternative is worse, so they are allowed crate-wide.
#![allow(clippy::needless_range_loop, clippy::ptr_arg)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
