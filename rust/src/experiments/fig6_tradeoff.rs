//! E1 (paper Fig. 6): interplay between loss, model complexity (hidden
//! units H) and compression level (codebook size K) for a single-hidden-
//! layer net. For each (H, K) we train a reference and LC-quantize it, then
//! report the loss surface L(K,H), the size surface C(K,H) and the best
//! operational point (K*, H*) for a set of target losses.

use super::common::{train_reference, Protocol};
use super::Scale;
use crate::coordinator::lc_quantize;
use crate::metrics::History;
use crate::nn::MlpSpec;
use crate::quant::ratio::quantized_bits;
use crate::quant::Scheme;
use crate::report::{f, Table};
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let mut p = Protocol::for_scale(scale);
    // Fig. 6 trains many small nets; trim per-net budget at quick scale.
    let (hs, log2ks): (Vec<usize>, Vec<usize>) = match scale {
        Scale::Quick => {
            p.n_data = 1_200;
            p.ref_steps = 250;
            p.lc_iterations = 12;
            p.l_steps = 40;
            (vec![2, 5, 10, 20, 40], vec![1, 2, 4, 8])
        }
        Scale::Full => (vec![2, 4, 8, 12, 16, 24, 32, 40], (1..=8).collect()),
    };

    let mut hist = History::new(&["h", "log2k", "loss", "err", "bits"]);
    for &h in &hs {
        let spec = MlpSpec::single_hidden(784, h, 10);
        let (p1, p0) = spec.param_counts();
        let mut tr = train_reference(&spec, &p, seed + h as u64);
        // K = ∞ (reference, uncompressed): bits = (P1+P0)*32
        hist.push(vec![
            h as f64,
            f64::INFINITY,
            tr.ref_train_loss as f64,
            tr.ref_train_err as f64,
            crate::quant::ratio::reference_bits(p1, p0) as f64,
        ]);
        for &l2k in &log2ks {
            let k = 1usize << l2k;
            tr.reset();
            let mut cfg = p.lc_config(Scheme::AdaptiveCodebook { k }, seed);
            cfg.eval_every = 0;
            let lc = lc_quantize(&mut tr.backend, &cfg);
            let bits = quantized_bits(p1, p0, k, spec.n_layers());
            hist.push(vec![
                h as f64,
                l2k as f64,
                lc.train_loss as f64,
                lc.train_err as f64,
                bits as f64,
            ]);
            crate::info!("fig6 H={h} K={k}: loss={:.4} bits={bits}", lc.train_loss);
        }
    }
    hist.save_csv(&Path::new(out_dir).join("fig6_surface.csv"))?;

    // Best operational point (K*, H*) for target losses (Fig. 6 middle).
    let targets = [0.05f64, 0.1, 0.3, 0.7];
    let mut t = Table::new(&["L_max", "H*", "log2K*", "bits", "loss"]);
    for &lmax in &targets {
        let best = hist
            .rows
            .iter()
            .filter(|r| r[2] <= lmax)
            .min_by(|a, b| a[4].partial_cmp(&b[4]).unwrap());
        match best {
            Some(r) => t.row(vec![
                f(lmax, 3),
                f(r[0], 0),
                if r[1].is_infinite() { "inf".into() } else { f(r[1], 0) },
                f(r[4], 0),
                f(r[2], 4),
            ]),
            None => t.row(vec![f(lmax, 3), "-".into(), "-".into(), "-".into(), "-".into()]),
        }
    }
    println!("\nFig. 6 — best operational points (smallest net with L <= L_max):\n{}", t.render());
    Ok(())
}
