//! E8 (paper Table 2): binarization of LeNet300 — LC with an adaptive K=2
//! codebook vs BinaryConnect vs the reference, plus the per-layer codebook
//! values LC learns (which differ markedly from ±1, especially in the
//! output layer).

use super::common::{train_reference, Protocol};
use super::Scale;
use crate::coordinator::baselines;
use crate::coordinator::lc_quantize;
use crate::metrics::History;
use crate::nn::MlpSpec;
use crate::quant::ratio::compression_ratio;
use crate::quant::Scheme;
use crate::report::{f, Table};
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let p = Protocol::for_scale(scale);
    let spec = MlpSpec::lenet300();
    let mut tr = train_reference(&spec, &p, seed);
    let (p1, p0) = spec.param_counts();
    let rho = compression_ratio(p1, p0, 2, spec.n_layers());

    // LC with adaptive K=2
    tr.reset();
    let lc = lc_quantize(
        &mut tr.backend,
        &p.lc_config(Scheme::AdaptiveCodebook { k: 2 }, seed),
    );

    // BinaryConnect under a matched step budget
    tr.reset();
    let bc_steps = p.lc_iterations * p.l_steps;
    let bc = baselines::binary_connect(
        &mut tr.backend,
        &Scheme::Binary,
        bc_steps,
        p.lr0 * 0.1,
        p.momentum,
        seed,
    );

    let log = |l: f32| (l.max(1e-12) as f64).log10();
    let mut t = Table::new(&["method", "logL", "E_train %", "E_test %"]);
    t.row(vec![
        "reference".into(),
        f(log(tr.ref_train_loss), 2),
        f(tr.ref_train_err as f64, 3),
        f(tr.ref_test_err.unwrap_or(f32::NAN) as f64, 2),
    ]);
    t.row(vec![
        "LC (K=2)".into(),
        f(log(lc.train_loss), 2),
        f(lc.train_err as f64, 3),
        f(lc.test_err.unwrap_or(f32::NAN) as f64, 2),
    ]);
    t.row(vec![
        "BinaryConnect".into(),
        f(log(bc.train_loss), 2),
        f(bc.train_err as f64, 3),
        f(bc.test_err.unwrap_or(f32::NAN) as f64, 2),
    ]);
    println!("\nTable 2 — binarization of LeNet300 (rho ~ x{rho:.1}):\n{}", t.render());

    let mut cb = Table::new(&["layer", "LC codebook values"]);
    for (l, c) in lc.codebooks.iter().enumerate() {
        cb.row(vec![
            format!("{}", l + 1),
            c.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>().join(", "),
        ]);
    }
    println!("{}", cb.render());

    let mut hist = History::new(&["method", "logL", "etrain", "etest"]);
    hist.push(vec![0.0, log(tr.ref_train_loss), tr.ref_train_err as f64, tr.ref_test_err.unwrap_or(f32::NAN) as f64]);
    hist.push(vec![1.0, log(lc.train_loss), lc.train_err as f64, lc.test_err.unwrap_or(f32::NAN) as f64]);
    hist.push(vec![2.0, log(bc.train_loss), bc.train_err as f64, bc.test_err.unwrap_or(f32::NAN) as f64]);
    hist.save_csv(&Path::new(out_dir).join("table2_binary.csv"))?;

    let mut cbh = History::new(&["layer", "c1", "c2"]);
    for (l, c) in lc.codebooks.iter().enumerate() {
        cbh.push(vec![l as f64, c[0] as f64, *c.get(1).unwrap_or(&f32::NAN) as f64]);
    }
    cbh.save_csv(&Path::new(out_dir).join("table2_codebooks.csv"))?;
    Ok(())
}
