//! E9 (paper §5.4): quantizing a larger deep net on CIFAR10 with K=2.
//! The paper's 14M-parameter VGG-style conv net (18h/run on a Titan X) is
//! scaled to this CPU testbed: a deep MLP of the same depth class on the
//! synthetic CIFAR-like set (substitution table in DESIGN.md §3). The
//! headline to reproduce: **K=2 LC quantization matches or beats the
//! reference test error** while compressing ~×31.
//!
//! When AOT artifacts are present, the conv VGG-small graph
//! (`python/compile/model.py::vgg_small`) exercises the same protocol via
//! the PJRT backend (`examples/quantized_serving.rs` loads it).

use super::common::{train_reference_on, Protocol};
use super::Scale;
use crate::coordinator::lc_quantize;
use crate::data::cifar_like;
use crate::metrics::History;
use crate::nn::{Activation, MlpSpec};
use crate::quant::ratio::compression_ratio;
use crate::quant::Scheme;
use crate::report::{f, Table};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let mut p = Protocol::for_scale(scale);
    let n = match scale {
        Scale::Quick => 1_500,
        Scale::Full => 6_000,
    };
    p.lr0 = 0.05;
    let mut data = cifar_like::generate(n, seed);
    data.subtract_mean(None);
    let mut rng = Rng::new(seed ^ 0xC1FA);
    let (train, test) = data.split(0.1, &mut rng);

    // deep net: 3072-512-256-128-10 ReLU (≈1.75M params)
    let spec = MlpSpec {
        sizes: vec![3072, 512, 256, 128, 10],
        hidden_activation: Activation::Relu,
        dropout_keep: vec![],
    };
    let (p1, p0) = spec.param_counts();
    let mut tr = train_reference_on(&spec, train, Some(test), &p, seed);
    let rho = compression_ratio(p1, p0, 2, spec.n_layers());

    tr.reset();
    let lc = lc_quantize(&mut tr.backend, &p.lc_config(Scheme::AdaptiveCodebook { k: 2 }, seed));

    let mut t = Table::new(&["net", "train loss", "E_test %"]);
    t.row(vec![
        "reference (float32)".into(),
        format!("{:.3e}", tr.ref_train_loss),
        f(tr.ref_test_err.unwrap_or(f32::NAN) as f64, 2),
    ]);
    t.row(vec![
        format!("LC K=2 (rho ~ x{rho:.1})"),
        format!("{:.3e}", lc.train_loss),
        f(lc.test_err.unwrap_or(f32::NAN) as f64, 2),
    ]);
    println!(
        "\nSec. 5.4 — deep net on CIFAR-like data, K=2 ({} weights):\n{}",
        p1,
        t.render()
    );

    let mut hist = History::new(&["which", "train_loss", "test_err", "rho"]);
    hist.push(vec![0.0, tr.ref_train_loss as f64, tr.ref_test_err.unwrap_or(f32::NAN) as f64, 1.0]);
    hist.push(vec![1.0, lc.train_loss as f64, lc.test_err.unwrap_or(f32::NAN) as f64, rho]);
    hist.save_csv(&Path::new(out_dir).join("sec54_cifar.csv"))?;
    Ok(())
}
