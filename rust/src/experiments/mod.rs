//! Experiment drivers: one module per paper table/figure (see DESIGN.md §5).

pub mod common;
pub mod fig6_tradeoff;
pub mod fig7_linreg;
pub mod fig8_curves;
pub mod fig9_table;
pub mod fig10_kmeans;
pub mod fig11_evolution;
pub mod fig13_centroids;
pub mod sec54_cifar;
pub mod table2_binary;

use anyhow::{bail, Result};

/// Scale knob for experiment runs: `quick` for CI/tests, `paper` for the
/// full (hours-long) protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn from_str(s: &str) -> Scale {
        if s == "full" {
            Scale::Full
        } else {
            Scale::Quick
        }
    }
}

/// Run an experiment by id, writing outputs under `out_dir`.
pub fn run(id: &str, out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    match id {
        "fig6" => fig6_tradeoff::run(out_dir, scale, seed),
        "fig7" => fig7_linreg::run(out_dir, scale, seed),
        "fig8" => fig8_curves::run(out_dir, scale, seed),
        "fig9" => fig9_table::run(out_dir, scale, seed),
        "fig10" => fig10_kmeans::run(out_dir, scale, seed),
        "fig11" => fig11_evolution::run(out_dir, scale, seed),
        "fig13" => fig13_centroids::run(out_dir, scale, seed),
        "table2" => table2_binary::run(out_dir, scale, seed),
        "sec54" => sec54_cifar::run(out_dir, scale, seed),
        "all" => {
            for e in ALL {
                crate::info!("=== experiment {e} ===");
                run(e, out_dir, scale, seed)?;
            }
            Ok(())
        }
        _ => bail!("unknown experiment '{id}'; known: {ALL:?} or 'all'"),
    }
}

/// All experiment ids.
pub const ALL: &[&str] = &[
    "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig13", "table2", "sec54",
];
