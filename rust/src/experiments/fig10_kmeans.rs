//! E5 (paper Fig. 10): number of k-means iterations inside each C step over
//! the LC run (K=4). The first C step (k-means++ from scratch) takes tens of
//! iterations; warm-started later C steps take ~1.

use super::common::{train_reference, Protocol};
use super::Scale;
use crate::coordinator::{lc_quantize, Backend as _};
use crate::metrics::History;
use crate::nn::MlpSpec;
use crate::quant::{LayerQuantizer, Scheme};
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let p = Protocol::for_scale(scale);
    let spec = MlpSpec::lenet300();
    let mut tr = train_reference(&spec, &p, seed);

    // the first (DC) compression is outside the LC history; measure it here
    let w = tr.backend.weights();
    let mut first_iters = Vec::new();
    for (l, wl) in w.iter().enumerate() {
        let mut q = LayerQuantizer::new(Scheme::AdaptiveCodebook { k: 4 }, seed + l as u64);
        first_iters.push(q.compress(wl).iterations);
    }

    tr.reset();
    let mut cfg = p.lc_config(Scheme::AdaptiveCodebook { k: 4 }, seed);
    cfg.tol = 0.0;
    cfg.eval_every = 0;
    let lc = lc_quantize(&mut tr.backend, &cfg);

    let n_layers = spec.n_layers();
    let mut cols: Vec<String> = vec!["iter".into()];
    for l in 0..n_layers {
        cols.push(format!("layer{}", l + 1));
    }
    let colrefs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut hist = History::new(&colrefs);
    let mut row0: Vec<f64> = vec![0.0];
    row0.extend(first_iters.iter().map(|&i| i as f64));
    hist.push(row0);
    for rec in &lc.history {
        let mut row: Vec<f64> = vec![(rec.iter + 1) as f64];
        row.extend(rec.kmeans_iters.iter().map(|&i| i as f64));
        hist.push(row);
    }
    hist.save_csv(&Path::new(out_dir).join("fig10_kmeans_iters.csv"))?;

    let late_max = lc
        .history
        .iter()
        .skip(2)
        .flat_map(|r| r.kmeans_iters.iter())
        .max()
        .copied()
        .unwrap_or(0);
    println!(
        "Fig. 10 — k-means iterations per C step: first compression {:?}, max after warm start {}",
        first_iters, late_max
    );
    Ok(())
}
