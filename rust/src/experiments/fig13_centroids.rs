//! E7 (paper Fig. 13): final centroid distributions learnt by LC and iDC
//! per layer, for K = 2..64, plus mean/std of each centroid set.

use super::common::{train_reference, Protocol};
use super::Scale;
use crate::coordinator::baselines;
use crate::coordinator::lc_quantize;
use crate::metrics::History;
use crate::nn::sgd::ClippedLrSchedule;
use crate::nn::MlpSpec;
use crate::quant::Scheme;
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let p = Protocol::for_scale(scale);
    let ks: Vec<usize> = match scale {
        Scale::Quick => vec![2, 4, 16],
        Scale::Full => vec![2, 4, 8, 16, 32, 64],
    };
    let spec = MlpSpec::lenet300();
    let mut tr = train_reference(&spec, &p, seed);

    let mut cent = History::new(&["algo", "k", "layer", "centroid_idx", "value"]);
    let mut stats = History::new(&["algo", "k", "layer", "mean", "std"]);

    for &k in &ks {
        let scheme = Scheme::AdaptiveCodebook { k };
        tr.reset();
        let lc = lc_quantize(&mut tr.backend, &p.lc_config(scheme.clone(), seed));
        tr.reset();
        let idc = baselines::iterated_direct_compression(
            &mut tr.backend,
            &scheme,
            p.lc_iterations,
            p.l_steps,
            ClippedLrSchedule { eta0: p.lr0, decay: p.lr_decay },
            p.momentum,
            seed,
            0,
        );
        for (algo, cbs) in [(0.0, &lc.codebooks), (1.0, &idc.codebooks)] {
            for (l, cb) in cbs.iter().enumerate() {
                for (ci, &c) in cb.iter().enumerate() {
                    cent.push(vec![algo, k as f64, l as f64, ci as f64, c as f64]);
                }
                let s = crate::metrics::summary(cb);
                stats.push(vec![algo, k as f64, l as f64, s["mean"], s["std"]]);
            }
        }
        println!(
            "K={k}: LC layer-3 centroids {:?}",
            lc.codebooks
                .last()
                .unwrap()
                .iter()
                .map(|c| format!("{c:.3}"))
                .collect::<Vec<_>>()
        );
    }
    // reference-net per-layer mean/std (the "∞" column of Fig. 13 bottom)
    for (l, wl) in tr.ref_weights.iter().enumerate() {
        let s = crate::metrics::summary(wl);
        stats.push(vec![2.0, f64::INFINITY, l as f64, s["mean"], s["std"]]);
    }
    cent.save_csv(&Path::new(out_dir).join("fig13_centroids.csv"))?;
    stats.save_csv(&Path::new(out_dir).join("fig13_stats.csv"))?;
    Ok(())
}
