//! E2 (paper §5.2, Fig. 7): quantizing linear regression on a simulated
//! super-resolution task, with a clustered non-Gaussian weight
//! distribution.
//!
//! The loss is L(W, b) = (1/N)Σ‖yₙ − W xₙ − b‖². Both the reference model
//! and the penalized L step have **exact closed-form solutions** via the
//! normal equations (solved by Cholesky on the Gram matrix), so this
//! experiment isolates the algorithmic comparison: with exact L and C
//! steps, DC and iDC are *identical* and stuck, while LC keeps improving.

use super::Scale;
use crate::data::superres::SuperResData;
use crate::linalg::gemm::matmul_at_b;
use crate::linalg::solve::Cholesky;
use crate::linalg::Mat;
use crate::metrics::{kde, History};
use crate::quant::{LayerQuantizer, Scheme};
use crate::report::{f, Table};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Closed-form penalized linear regression on precomputed Gram matrices.
///
/// Weights are augmented with a bias column: W̃ = [W | b], X̃ = [X; 1ᵀ].
/// The penalty applies to the weight columns only (biases unquantized).
pub struct LinRegLc {
    /// G = X̃X̃ᵀ/N, (d+1, d+1).
    g: Mat,
    /// H = YX̃ᵀ/N, (out, d+1).
    h: Mat,
    /// (1/N)Σ‖yₙ‖² — constant term of the loss.
    y2: f64,
    pub d_in: usize,
    pub d_out: usize,
    /// Current solution [W | b], (out, d+1).
    pub w: Mat,
}

impl LinRegLc {
    pub fn new(data: &SuperResData) -> LinRegLc {
        let n = data.x.rows;
        let d_in = data.x.cols;
        let d_out = data.y.cols;
        // augmented design matrix rows: [x; 1]
        let mut xa = Mat::zeros(n, d_in + 1);
        for r in 0..n {
            xa.row_mut(r)[..d_in].copy_from_slice(data.x.row(r));
            xa.row_mut(r)[d_in] = 1.0;
        }
        let mut g = matmul_at_b(&xa, &xa);
        // Yᵀ is (d_out, n) as columns of data.y; matmul_at_b(Y, X̃) = YᵀX̃ has
        // shape (d_out, d_in+1) — exactly H's layout, just scale by 1/N.
        let mut h = matmul_at_b(&data.y, &xa);
        for v in h.data.iter_mut() {
            *v /= n as f32;
        }
        for v in g.data.iter_mut() {
            *v /= n as f32;
        }
        let y2 = data.y.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n as f64;
        let w = Mat::zeros(d_out, d_in + 1);
        LinRegLc { g, h, y2, d_in, d_out, w }
    }

    /// Exact unpenalized solve (the reference model): W̃ G = H.
    pub fn solve_reference(&mut self) -> Result<()> {
        // tiny ridge for numerical safety
        let mut a = self.g.clone();
        for i in 0..a.rows {
            a[(i, i)] += 1e-6;
        }
        let ch = Cholesky::factor(&a).ok_or_else(|| anyhow!("gram not SPD"))?;
        for r in 0..self.d_out {
            let x = ch.solve_vec(self.h.row(r));
            self.w.row_mut(r).copy_from_slice(&x);
        }
        Ok(())
    }

    /// Exact penalized L step: minimize L(W̃) + μ/2‖(W − T)‖² where T is
    /// the (out, d_in) matrix of targets (w_C + λ/μ), bias unpenalized.
    pub fn solve_penalized(&mut self, target: &Mat, mu: f32) -> Result<()> {
        assert_eq!(target.rows, self.d_out);
        assert_eq!(target.cols, self.d_in);
        // per-row system: w̃ᵣ (2G + μ diag(m)) = 2hᵣ + μ tᵣ (m masks bias)
        let mut a = self.g.clone();
        for v in a.data.iter_mut() {
            *v *= 2.0;
        }
        // tiny constant ridge (as in solve_reference) keeps the factorization
        // SPD even when n < d and mu -> 0
        for i in 0..self.d_in {
            a[(i, i)] += mu + 1e-6;
        }
        a[(self.d_in, self.d_in)] += 1e-6;
        let ch = Cholesky::factor(&a).ok_or_else(|| anyhow!("penalized gram not SPD"))?;
        let mut rhs = vec![0.0f32; self.d_in + 1];
        for r in 0..self.d_out {
            for j in 0..=self.d_in {
                rhs[j] = 2.0 * self.h[(r, j)];
            }
            for j in 0..self.d_in {
                rhs[j] += mu * target[(r, j)];
            }
            let x = ch.solve_vec(&rhs);
            self.w.row_mut(r).copy_from_slice(&x);
        }
        Ok(())
    }

    /// Loss of an arbitrary [W | b] matrix via the Gram identity:
    /// L = y² + Σᵣ (w̃ᵣ G w̃ᵣᵀ − 2 w̃ᵣ·hᵣ).
    pub fn loss_of(&self, w: &Mat) -> f64 {
        // f64 accumulation throughout: the three terms cancel to ~1e-7 of
        // their magnitude at the optimum, far below f32 resolution.
        let d = self.d_in + 1;
        let mut total = self.y2;
        let mut gw = vec![0.0f64; d];
        for r in 0..self.d_out {
            let wr = w.row(r);
            for (i, gwi) in gw.iter_mut().enumerate() {
                let grow = self.g.row(i);
                let mut s = 0.0f64;
                for j in 0..d {
                    s += grow[j] as f64 * wr[j] as f64;
                }
                *gwi = s;
            }
            let mut quad = 0.0f64;
            let mut lin = 0.0f64;
            let hrow = self.h.row(r);
            for j in 0..d {
                quad += wr[j] as f64 * gw[j];
                lin += wr[j] as f64 * hrow[j] as f64;
            }
            total += quad - 2.0 * lin;
        }
        total.max(0.0)
    }

    /// Gram matrix accessor (X̃X̃ᵀ/N) — used by the PJRT integration test
    /// to feed the `linreg_lstep` artifact the same inputs.
    pub fn gram(&self) -> &Mat {
        &self.g
    }

    /// H = YX̃ᵀ/N accessor.
    pub fn h_mat(&self) -> &Mat {
        &self.h
    }

    /// Assemble the penalized normal-equation system exactly as
    /// `solve_penalized` does: A = 2G + diag(μ·mask + ridge),
    /// rhs = 2H + μ·[T | 0]. This is the input contract of the
    /// `linreg_lstep` AOT artifact.
    pub fn assemble_system(&self, target: &Mat, mu: f32) -> (Mat, Mat) {
        let d = self.d_in + 1;
        let mut a = self.g.clone();
        for v in a.data.iter_mut() {
            *v *= 2.0;
        }
        for i in 0..self.d_in {
            a[(i, i)] += mu + 1e-6;
        }
        a[(self.d_in, self.d_in)] += 1e-6;
        let mut rhs = Mat::zeros(self.d_out, d);
        for r in 0..self.d_out {
            for j in 0..d {
                rhs[(r, j)] = 2.0 * self.h[(r, j)];
            }
            for j in 0..self.d_in {
                rhs[(r, j)] += mu * target[(r, j)];
            }
        }
        (a, rhs)
    }

    /// Extract the weight block (out × d_in) as a flat vector.
    pub fn weights_flat(&self, w: &Mat) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.d_out * self.d_in);
        for r in 0..self.d_out {
            out.extend_from_slice(&w.row(r)[..self.d_in]);
        }
        out
    }

    /// Write a flat weight vector back into the weight block of `w`.
    pub fn set_weights_flat(&self, w: &mut Mat, flat: &[f32]) {
        assert_eq!(flat.len(), self.d_out * self.d_in);
        for r in 0..self.d_out {
            w.row_mut(r)[..self.d_in]
                .copy_from_slice(&flat[r * self.d_in..(r + 1) * self.d_in]);
        }
    }
}

/// Outcome of one algorithm run on the linreg problem.
pub struct LinRegOutcome {
    pub loss_per_iter: Vec<f64>,
    pub kmeans_iters: Vec<usize>,
    pub final_codebook: Vec<f32>,
    pub final_wc_flat: Vec<f32>,
}

/// Run LC with exact L steps. μ_j = μ₀·aʲ (paper: μ₀=10, a=1.1, 30 iters).
pub fn run_lc(
    lr: &mut LinRegLc,
    k: usize,
    mu0: f32,
    mult: f32,
    iterations: usize,
    seed: u64,
) -> Result<LinRegOutcome> {
    lr.solve_reference()?;
    let mut quantizer = LayerQuantizer::new(Scheme::AdaptiveCodebook { k }, seed);
    let p = lr.d_out * lr.d_in;
    let mut lambda = vec![0.0f32; p];
    // initial C step on the reference weights (direct compression)
    let w_flat = lr.weights_flat(&lr.w);
    let out = quantizer.compress(&w_flat);
    let mut wc = out.wc;
    let mut codebook = out.codebook;
    let mut loss_per_iter = Vec::new();
    let mut kmeans_iters = vec![out.iterations];
    // loss of the DC point
    let mut wq = lr.w.clone();
    lr.set_weights_flat(&mut wq, &wc);
    loss_per_iter.push(lr.loss_of(&wq));

    let mut shifted = vec![0.0f32; p];
    let mut target = Mat::zeros(lr.d_out, lr.d_in);
    for j in 0..iterations {
        let mu = mu0 * mult.powi(j as i32);
        // L step: target T = w_C + λ/μ
        for (t, (c, l)) in target.data.iter_mut().zip(wc.iter().zip(&lambda)) {
            *t = c + l / mu;
        }
        lr.solve_penalized(&target, mu)?;
        // C step on w − λ/μ
        let w_flat = lr.weights_flat(&lr.w);
        crate::linalg::vecops::shift_by_multipliers(&w_flat, &lambda, mu, &mut shifted);
        let out = quantizer.compress(&shifted);
        wc = out.wc;
        codebook = out.codebook;
        kmeans_iters.push(out.iterations);
        // λ ← λ − μ(w − w_C)
        crate::linalg::vecops::update_multipliers(&mut lambda, &w_flat, &wc, mu);
        let mut wq = lr.w.clone();
        lr.set_weights_flat(&mut wq, &wc);
        loss_per_iter.push(lr.loss_of(&wq));
    }
    Ok(LinRegOutcome {
        loss_per_iter,
        kmeans_iters,
        final_codebook: codebook,
        final_wc_flat: wc,
    })
}

/// Run DC/iDC with the exact L step. With a unique global optimum, iDC
/// cycles between the reference and its quantization — its loss history is
/// flat after iteration 1 (the paper's point).
pub fn run_idc(lr: &mut LinRegLc, k: usize, iterations: usize, seed: u64) -> Result<LinRegOutcome> {
    lr.solve_reference()?;
    let mut quantizer = LayerQuantizer::new(Scheme::AdaptiveCodebook { k }, seed);
    let mut loss_per_iter = Vec::new();
    let mut kmeans_iters = Vec::new();
    let mut codebook = Vec::new();
    let mut wc = Vec::new();
    for _ in 0..=iterations {
        // L step: exact, unpenalized — returns to the reference solution
        lr.solve_reference()?;
        // C step
        let w_flat = lr.weights_flat(&lr.w);
        let out = quantizer.compress(&w_flat);
        wc = out.wc;
        codebook = out.codebook;
        kmeans_iters.push(out.iterations);
        let mut wq = lr.w.clone();
        lr.set_weights_flat(&mut wq, &wc);
        loss_per_iter.push(lr.loss_of(&wq));
        // iDC restarts training *from* the quantized weights; with an exact
        // convex solve the restart point is irrelevant.
        lr.set_weights_flat(&mut lr.w.clone(), &wc);
    }
    Ok(LinRegOutcome { loss_per_iter, kmeans_iters, final_codebook: codebook, final_wc_flat: wc })
}

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let (n, iterations) = match scale {
        Scale::Quick => (300usize, 30usize),
        Scale::Full => (1000, 30),
    };
    let data = SuperResData::generate(n, 0.05, seed);
    let mut lr = LinRegLc::new(&data);
    lr.solve_reference()?;
    let ref_loss = lr.loss_of(&lr.w);
    let w_ref_flat = lr.weights_flat(&lr.w);
    println!("reference linreg loss: {ref_loss:.6}");

    let mut curves = History::new(&["k", "iter", "lc_loss", "idc_loss", "lc_kmeans_iters"]);
    let mut table = Table::new(&["K", "reference", "DC", "iDC", "LC"]);
    let mut kdes = History::new(&["k", "stage", "x", "density"]);
    let grid: Vec<f32> = (0..361).map(|i| -0.4 + i as f32 * 0.004).collect();

    for &k in &[4usize, 2] {
        let lc = run_lc(&mut lr, k, 10.0, 1.1, iterations, seed)?;
        let idc = run_idc(&mut lr, k, iterations, seed)?;
        let dc_loss = idc.loss_per_iter[0];
        for j in 0..lc.loss_per_iter.len() {
            curves.push(vec![
                k as f64,
                j as f64,
                lc.loss_per_iter[j],
                idc.loss_per_iter.get(j).copied().unwrap_or(f64::NAN),
                lc.kmeans_iters.get(j).copied().unwrap_or(0) as f64,
            ]);
        }
        table.row(vec![
            k.to_string(),
            f(ref_loss, 6),
            f(dc_loss, 6),
            f(*idc.loss_per_iter.last().unwrap(), 6),
            f(*lc.loss_per_iter.last().unwrap(), 6),
        ]);
        // weight-distribution KDEs: reference (0), DC (1), LC final (2);
        // plus centroid locations as stage 3 (LC) / 4 (DC fit to reference)
        let mut dc_q = LayerQuantizer::new(Scheme::AdaptiveCodebook { k }, seed);
        let dc_out = dc_q.compress(&w_ref_flat);
        for (stage, dat) in [
            (0.0, &w_ref_flat),
            (1.0, &dc_out.wc),
            (2.0, &lc.final_wc_flat),
        ] {
            let d = kde(dat, &grid, 0.006);
            for (x, v) in grid.iter().zip(&d) {
                kdes.push(vec![k as f64, stage, *x as f64, *v as f64]);
            }
        }
        for &c in &lc.final_codebook {
            kdes.push(vec![k as f64, 3.0, c as f64, 0.0]);
        }
        for &c in &dc_out.codebook {
            kdes.push(vec![k as f64, 4.0, c as f64, 0.0]);
        }
        println!(
            "K={k}: DC={dc_loss:.6} iDC(final)={:.6} LC(final)={:.6}  LC codebook {:?}",
            idc.loss_per_iter.last().unwrap(),
            lc.loss_per_iter.last().unwrap(),
            lc.final_codebook
        );
    }
    println!("\nFig. 7 — linreg super-resolution training loss:\n{}", table.render());
    curves.save_csv(&Path::new(out_dir).join("fig7_curves.csv"))?;
    kdes.save_csv(&Path::new(out_dir).join("fig7_weight_kde.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_problem(seed: u64) -> (SuperResData, LinRegLc) {
        let data = SuperResData::generate(80, 0.05, seed);
        let lr = LinRegLc::new(&data);
        (data, lr)
    }

    #[test]
    fn reference_solution_fits_training_data() {
        let (data, mut lr) = small_problem(1);
        lr.solve_reference().unwrap();
        let loss = lr.loss_of(&lr.w);
        // direct check against the definition of the loss
        let mut direct = 0.0f64;
        for nidx in 0..data.x.rows {
            let x = data.x.row(nidx);
            for r in 0..lr.d_out {
                let wr = lr.w.row(r);
                let pred = crate::linalg::vecops::dot(&wr[..lr.d_in], x) + wr[lr.d_in];
                direct += ((data.y[(nidx, r)] - pred) as f64).powi(2);
            }
        }
        direct /= data.x.rows as f64;
        assert!(
            (loss - direct).abs() < 1e-2 * direct.max(1e-3),
            "gram loss {loss} vs direct {direct}"
        );
    }

    #[test]
    fn penalized_solve_interpolates_to_target_as_mu_grows() {
        let (_, mut lr) = small_problem(2);
        lr.solve_reference().unwrap();
        let target = Mat::zeros(lr.d_out, lr.d_in); // pull weights to 0
        lr.solve_penalized(&target, 1e6).unwrap();
        let flat = lr.weights_flat(&lr.w);
        let maxw = flat.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(maxw < 1e-2, "weights should be ~0 under huge mu, max {maxw}");
    }

    #[test]
    fn penalized_solve_with_mu_zero_like_matches_reference_loss() {
        // With n < d the Gram is singular and weights are not identifiable;
        // compare achieved losses instead of raw weights.
        let (_, mut lr) = small_problem(3);
        lr.solve_reference().unwrap();
        let ref_loss = lr.loss_of(&lr.w);
        let target = Mat::zeros(lr.d_out, lr.d_in);
        lr.solve_penalized(&target, 1e-9).unwrap();
        let pen_loss = lr.loss_of(&lr.w);
        assert!(
            (pen_loss - ref_loss).abs() < 1e-4 + 0.05 * ref_loss.abs(),
            "mu->0 loss {pen_loss} vs reference {ref_loss}"
        );
    }

    #[test]
    fn lc_beats_dc_and_idc_is_flat() {
        let (_, mut lr) = small_problem(4);
        let lc = run_lc(&mut lr, 2, 10.0, 1.2, 15, 7).unwrap();
        let idc = run_idc(&mut lr, 2, 15, 7).unwrap();
        let dc = idc.loss_per_iter[0];
        // iDC identical to DC forever (exact L step)
        for &l in &idc.loss_per_iter {
            assert!((l - dc).abs() < 1e-6 * dc.max(1e-9), "iDC moved: {l} vs {dc}");
        }
        // LC strictly better at the end
        let lc_final = *lc.loss_per_iter.last().unwrap();
        assert!(
            lc_final < dc * 0.9,
            "LC {lc_final} should clearly beat DC {dc}"
        );
    }

    #[test]
    fn lc_final_weights_are_quantized() {
        let (_, mut lr) = small_problem(5);
        let lc = run_lc(&mut lr, 4, 10.0, 1.3, 12, 9).unwrap();
        for v in &lc.final_wc_flat {
            assert!(lc.final_codebook.iter().any(|c| (c - v).abs() < 1e-6));
        }
        assert!(lc.final_codebook.len() <= 4);
    }
}
