//! Shared experiment harness: dataset construction, reference-net training,
//! and the LC/DC/iDC protocol used across the paper's figures.

use crate::coordinator::baselines::{self, BaselineResult};
use crate::coordinator::sgd_driver::{run_sgd, FlatNesterov};
use crate::coordinator::{lc_quantize, Backend, LcConfig, LcResult, MuSchedule, NativeBackend};
use crate::data::synth_mnist::SynthMnist;
use crate::data::Dataset;
use crate::nn::sgd::ClippedLrSchedule;
use crate::nn::{Mlp, MlpSpec};
use crate::quant::Scheme;
use crate::util::rng::Rng;

/// Experiment-scale knobs shared by the drivers.
pub struct Protocol {
    pub n_data: usize,
    pub ref_steps: usize,
    pub batch: usize,
    pub lc_iterations: usize,
    pub l_steps: usize,
    pub lr0: f32,
    pub lr_decay: f32,
    pub momentum: f32,
    pub mu0: f32,
    pub mu_mult: f32,
}

impl Protocol {
    /// Scaled-down protocol (minutes, preserves the paper's shape).
    pub fn quick() -> Protocol {
        Protocol {
            n_data: 2_000,
            ref_steps: 500,
            batch: 128,
            lc_iterations: 20,
            l_steps: 80,
            lr0: 0.1,
            lr_decay: 0.99,
            momentum: 0.95,
            mu0: 1e-3,
            mu_mult: 1.4,
        }
    }

    /// Closer to the paper's §5.3 protocol (much slower).
    pub fn full() -> Protocol {
        Protocol {
            n_data: 10_000,
            ref_steps: 4_000,
            batch: 256,
            lc_iterations: 30,
            l_steps: 400,
            lr0: 0.1,
            lr_decay: 0.99,
            momentum: 0.95,
            mu0: 9.76e-5,
            mu_mult: 1.3,
        }
    }

    pub fn for_scale(scale: super::Scale) -> Protocol {
        match scale {
            super::Scale::Quick => Protocol::quick(),
            super::Scale::Full => Protocol::full(),
        }
    }

    pub fn lc_config(&self, scheme: Scheme, seed: u64) -> LcConfig {
        LcConfig {
            scheme,
            mu: MuSchedule::new(self.mu0, self.mu_mult),
            iterations: self.lc_iterations,
            l_steps: self.l_steps,
            lr: ClippedLrSchedule { eta0: self.lr0, decay: self.lr_decay },
            momentum: self.momentum,
            mode: crate::coordinator::PenaltyMode::AugmentedLagrangian,
            tol: 1e-4,
            seed,
            eval_every: 1,
            n_weight_samples: 0,
        }
    }
}

/// A trained reference net + its data, ready for quantization runs.
pub struct TrainedRef {
    pub backend: NativeBackend,
    pub ref_weights: Vec<Vec<f32>>,
    pub ref_biases: Vec<Vec<f32>>,
    pub ref_train_loss: f32,
    pub ref_train_err: f32,
    pub ref_test_err: Option<f32>,
}

impl TrainedRef {
    /// Restore the backend to the reference parameters.
    pub fn reset(&mut self) {
        self.backend.set_weights(&self.ref_weights);
        self.backend.set_biases(&self.ref_biases);
    }
}

/// Build a synth-MNIST classification backend and train the reference net.
pub fn train_reference(spec: &MlpSpec, p: &Protocol, seed: u64) -> TrainedRef {
    let mut data = SynthMnist::generate(p.n_data, seed);
    data.subtract_mean(None);
    let mut rng = Rng::new(seed ^ 0x5EED);
    let (train, test) = data.split(0.1, &mut rng);
    train_reference_on(spec, train, Some(test), p, seed)
}

/// Same, over a caller-supplied dataset.
pub fn train_reference_on(
    spec: &MlpSpec,
    train: Dataset,
    test: Option<Dataset>,
    p: &Protocol,
    seed: u64,
) -> TrainedRef {
    let net = Mlp::new(spec, seed);
    let mut backend = NativeBackend::new(net, train, test, p.batch, seed);
    let mut opt = FlatNesterov::new(backend.layout(), p.momentum);
    // Nesterov with decaying lr, matching the paper's reference training.
    let chunk = 100.max(p.ref_steps / 20);
    let mut step = 0;
    while step < p.ref_steps {
        let n = chunk.min(p.ref_steps - step);
        let lr = p.lr0 * p.lr_decay.powi((step / chunk) as i32);
        run_sgd(&mut backend, &mut opt, n, lr, None);
        step += n;
    }
    let (l, e) = backend.eval_train();
    let te = backend.eval_test().map(|(_, e)| e);
    crate::info!("reference trained: loss={l:.5} train_err={e:.2}% test_err={te:?}");
    TrainedRef {
        ref_weights: backend.weights(),
        ref_biases: backend.biases(),
        backend,
        ref_train_loss: l,
        ref_train_err: e,
        ref_test_err: te,
    }
}

/// Run the three algorithms (LC / DC / iDC) from the same reference under a
/// matched budget. Returns (lc, dc, idc).
pub fn run_all_algorithms(
    tr: &mut TrainedRef,
    scheme: &Scheme,
    p: &Protocol,
    seed: u64,
) -> (LcResult, BaselineResult, BaselineResult) {
    tr.reset();
    let dc = baselines::direct_compression(&mut tr.backend, scheme, seed);

    tr.reset();
    let idc = baselines::iterated_direct_compression(
        &mut tr.backend,
        scheme,
        p.lc_iterations,
        p.l_steps,
        ClippedLrSchedule { eta0: p.lr0, decay: p.lr_decay },
        p.momentum,
        seed,
        1,
    );

    tr.reset();
    let lc = lc_quantize(&mut tr.backend, &p.lc_config(scheme.clone(), seed));
    (lc, dc, idc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_training_learns() {
        let mut p = Protocol::quick();
        p.n_data = 300;
        p.ref_steps = 120;
        let spec = MlpSpec::single_hidden(784, 12, 10);
        let tr = train_reference(&spec, &p, 5);
        // far better than chance (90% error)
        assert!(tr.ref_train_err < 45.0, "train err {}", tr.ref_train_err);
        assert!(tr.ref_train_loss < 2.0);
    }

    #[test]
    fn reset_restores_reference() {
        let mut p = Protocol::quick();
        p.n_data = 200;
        p.ref_steps = 50;
        let spec = MlpSpec::single_hidden(784, 8, 10);
        let mut tr = train_reference(&spec, &p, 6);
        let w0 = tr.ref_weights.clone();
        // clobber
        let zeros: Vec<Vec<f32>> = w0.iter().map(|l| vec![0.0; l.len()]).collect();
        tr.backend.set_weights(&zeros);
        tr.reset();
        assert_eq!(tr.backend.weights(), w0);
    }
}
