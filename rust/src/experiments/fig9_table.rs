//! E4 (paper Fig. 9): compression results for the LeNet nets — for each
//! codebook size K ∈ {2,4,8,16,32,64}, run LC / DC / iDC from the same
//! reference and report log₁₀ L, E_train (%), E_test (%) and ρ(K).

use super::common::{run_all_algorithms, train_reference, Protocol};
use super::Scale;
use crate::metrics::History;
use crate::nn::MlpSpec;
use crate::quant::ratio::compression_ratio;
use crate::quant::Scheme;
use crate::report::{f, Table};
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let p = Protocol::for_scale(scale);
    let ks: Vec<usize> = match scale {
        Scale::Quick => vec![2, 4, 16, 64],
        Scale::Full => vec![2, 4, 8, 16, 32, 64],
    };
    let nets: Vec<(&str, MlpSpec)> = match scale {
        Scale::Quick => vec![("lenet300", MlpSpec::lenet300())],
        Scale::Full => vec![
            ("lenet300", MlpSpec::lenet300()),
            ("lenet5_mlp", MlpSpec::lenet5_mlp()),
        ],
    };

    let mut hist = History::new(&[
        "net", "k", "rho", "lc_logL", "lc_etrain", "lc_etest", "dc_logL", "dc_etrain",
        "dc_etest", "idc_logL", "idc_etrain", "idc_etest",
    ]);
    let mut table = Table::new(&[
        "net", "K", "rho", "LC logL", "LC Etr", "LC Ete", "DC logL", "DC Etr", "DC Ete",
        "iDC logL", "iDC Etr", "iDC Ete",
    ]);

    for (net_id, (name, spec)) in nets.iter().enumerate() {
        let mut tr = train_reference(spec, &p, seed);
        let (p1, p0) = spec.param_counts();
        crate::info!(
            "{name}: reference logL={:.3} E_train={:.2}% E_test={:?}",
            tr.ref_train_loss.max(1e-12).log10(),
            tr.ref_train_err,
            tr.ref_test_err
        );
        for &k in &ks {
            let scheme = Scheme::AdaptiveCodebook { k };
            let (lc, dc, idc) = run_all_algorithms(&mut tr, &scheme, &p, seed + k as u64);
            let rho = compression_ratio(p1, p0, k, spec.n_layers());
            let log = |l: f32| (l.max(1e-12) as f64).log10();
            hist.push(vec![
                net_id as f64,
                k as f64,
                rho,
                log(lc.train_loss),
                lc.train_err as f64,
                lc.test_err.unwrap_or(f32::NAN) as f64,
                log(dc.train_loss),
                dc.train_err as f64,
                dc.test_err.unwrap_or(f32::NAN) as f64,
                log(idc.train_loss),
                idc.train_err as f64,
                idc.test_err.unwrap_or(f32::NAN) as f64,
            ]);
            table.row(vec![
                name.to_string(),
                k.to_string(),
                format!("x{:.1}", rho),
                f(log(lc.train_loss), 2),
                f(lc.train_err as f64, 2),
                f(lc.test_err.unwrap_or(f32::NAN) as f64, 2),
                f(log(dc.train_loss), 2),
                f(dc.train_err as f64, 2),
                f(dc.test_err.unwrap_or(f32::NAN) as f64, 2),
                f(log(idc.train_loss), 2),
                f(idc.train_err as f64, 2),
                f(idc.test_err.unwrap_or(f32::NAN) as f64, 2),
            ]);
            crate::info!(
                "{name} K={k}: LC logL={:.2} | DC logL={:.2} | iDC logL={:.2}",
                log(lc.train_loss),
                log(dc.train_loss),
                log(idc.train_loss)
            );
        }
    }
    println!("\nFig. 9 — compression results (LC vs DC vs iDC):\n{}", table.render());
    hist.save_csv(&Path::new(out_dir).join("fig9_table.csv"))?;
    Ok(())
}
