//! E6 (paper Figs. 11–12): evolution of the weight distribution and the
//! codebook centroids over LC iterations (and iDC for contrast), per layer,
//! K=4. Emits centroid trajectories, sampled weight trajectories, and KDEs
//! of the weight distribution at iterations 0 / 1 / final.

use super::common::{train_reference, Protocol};
use super::Scale;
use crate::coordinator::baselines;
use crate::coordinator::lc_quantize;
use crate::metrics::{kde, History};
use crate::nn::sgd::ClippedLrSchedule;
use crate::nn::MlpSpec;
use crate::quant::Scheme;
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let p = Protocol::for_scale(scale);
    let k = 4usize;
    let spec = MlpSpec::lenet300();
    let mut tr = train_reference(&spec, &p, seed);
    let w_ref = tr.ref_weights.clone();

    tr.reset();
    let mut cfg = p.lc_config(Scheme::AdaptiveCodebook { k }, seed);
    cfg.tol = 0.0;
    cfg.eval_every = 0;
    cfg.n_weight_samples = 40; // paper: "40 randomly chosen weights"
    let lc = lc_quantize(&mut tr.backend, &cfg);

    tr.reset();
    let idc = baselines::iterated_direct_compression(
        &mut tr.backend,
        &Scheme::AdaptiveCodebook { k },
        p.lc_iterations,
        p.l_steps,
        ClippedLrSchedule { eta0: p.lr0, decay: p.lr_decay },
        p.momentum,
        seed,
        0,
    );

    // --- centroid trajectories (LC vs iDC) ---
    let mut cent = History::new(&["algo", "iter", "layer", "centroid_idx", "value"]);
    for (algo, snapshots) in [
        (0.0, lc.history.iter().map(|r| &r.codebooks).collect::<Vec<_>>()),
        (1.0, idc.codebook_history.iter().collect::<Vec<_>>()),
    ] {
        for (j, cbs) in snapshots.iter().enumerate() {
            for (l, cb) in cbs.iter().enumerate() {
                for (ci, &c) in cb.iter().enumerate() {
                    cent.push(vec![algo, j as f64, l as f64, ci as f64, c as f64]);
                }
            }
        }
    }
    cent.save_csv(&Path::new(out_dir).join("fig11_centroids.csv"))?;

    // --- sampled weight trajectories (LC) ---
    let mut traj = History::new(&["iter", "layer", "weight_idx", "value"]);
    for rec in &lc.history {
        for (l, samples) in rec.weight_samples.iter().enumerate() {
            for (wi, &v) in samples.iter().enumerate() {
                traj.push(vec![rec.iter as f64, l as f64, wi as f64, v as f64]);
            }
        }
    }
    traj.save_csv(&Path::new(out_dir).join("fig11_weight_trajectories.csv"))?;

    // --- weight-distribution KDEs at iteration 0 (reference), 1 (DC-ish)
    //     and final, per layer ---
    let grid: Vec<f32> = (0..241).map(|i| -0.6 + i as f32 * 0.005).collect();
    let mut dens = History::new(&["layer", "stage", "x", "density"]);
    for l in 0..spec.n_layers() {
        // stage 1 = direct compression of the reference layer
        let mut dc_q = crate::quant::LayerQuantizer::new(Scheme::AdaptiveCodebook { k }, seed);
        let dc_wc = dc_q.compress(&w_ref[l]).wc;
        let stages: Vec<(f64, &[f32])> =
            vec![(0.0, &w_ref[l][..]), (1.0, &dc_wc[..]), (2.0, &lc.wc[l][..])];
        for (stage, data) in stages {
            let d = kde(data, &grid, 0.01);
            for (x, v) in grid.iter().zip(&d) {
                dens.push(vec![l as f64, stage, *x as f64, *v as f64]);
            }
        }
    }
    dens.save_csv(&Path::new(out_dir).join("fig11_weight_kde.csv"))?;

    // console summary: did LC converge to deltas at the centroids?
    for (l, (wl, cb)) in lc.wc.iter().zip(&lc.codebooks).enumerate() {
        let distinct: std::collections::BTreeSet<i64> =
            wl.iter().map(|v| (v * 1e6).round() as i64).collect();
        println!(
            "layer {}: final LC weights take {} distinct values; centroids {:?}",
            l + 1,
            distinct.len(),
            cb.iter().map(|c| format!("{c:.3}")).collect::<Vec<_>>()
        );
    }
    Ok(())
}
