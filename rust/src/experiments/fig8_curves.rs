//! E3 (paper Fig. 8): learning curves — quantized-net training loss over LC
//! iterations for several codebook sizes, LC vs iDC, on the LeNet nets.

use super::common::{train_reference, Protocol};
use super::Scale;
use crate::coordinator::baselines;
use crate::coordinator::lc_quantize;
use crate::metrics::History;
use crate::nn::sgd::ClippedLrSchedule;
use crate::nn::MlpSpec;
use crate::quant::Scheme;
use anyhow::Result;
use std::path::Path;

pub fn run(out_dir: &str, scale: Scale, seed: u64) -> Result<()> {
    let p = Protocol::for_scale(scale);
    let ks: Vec<usize> = match scale {
        Scale::Quick => vec![2, 4, 32],
        Scale::Full => vec![2, 4, 8, 32],
    };
    let spec = MlpSpec::lenet300();
    let mut tr = train_reference(&spec, &p, seed);

    let mut hist = History::new(&["k", "iter", "lc_loss", "idc_loss", "lc_feas"]);
    for &k in &ks {
        let scheme = Scheme::AdaptiveCodebook { k };
        tr.reset();
        let mut cfg = p.lc_config(scheme.clone(), seed);
        cfg.eval_every = 1;
        cfg.tol = 0.0; // trace the full curve
        let lc = lc_quantize(&mut tr.backend, &cfg);

        tr.reset();
        let idc = baselines::iterated_direct_compression(
            &mut tr.backend,
            &scheme,
            p.lc_iterations,
            p.l_steps,
            ClippedLrSchedule { eta0: p.lr0, decay: p.lr_decay },
            p.momentum,
            seed,
            1,
        );

        for (j, rec) in lc.history.iter().enumerate() {
            let lc_loss = rec.train_loss_wc.unwrap_or(f32::NAN);
            let idc_loss = idc.loss_history.get(j).copied().unwrap_or(f32::NAN);
            hist.push(vec![
                k as f64,
                j as f64,
                lc_loss as f64,
                idc_loss as f64,
                rec.feasibility as f64,
            ]);
        }
        let last = lc.history.last().unwrap();
        crate::info!(
            "fig8 K={k}: final LC loss={:.4} iDC loss={:.4} feas={:.3e}",
            last.train_loss_wc.unwrap_or(f32::NAN),
            idc.train_loss,
            last.feasibility
        );
        println!(
            "K={k}: LC final quantized-net loss {:.4}, iDC {:.4} (reference {:.4})",
            lc.train_loss, idc.train_loss, tr.ref_train_loss
        );
    }
    hist.save_csv(&Path::new(out_dir).join("fig8_curves.csv"))?;
    Ok(())
}
