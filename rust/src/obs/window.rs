//! Windowed rates: turning monotonic counters into rolling req/s.
//!
//! Every counter and histogram in the plane is a monotonic total — cheap
//! to record, trivially mergeable, but useless for "how fast right now".
//! A [`RateWindow`] is a small ring of periodic samples (counter values +
//! a histogram snapshot + a timestamp); subtracting the oldest retained
//! sample from the newest yields the activity *inside the window*:
//! rolling requests/s, shed rate, and a p99 over just the last N seconds
//! via [`HistogramSnapshot::delta_since`] (bucket-wise, exact — log₂
//! buckets make interval percentiles as honest as lifetime ones).
//!
//! The window is a poller-side structure (`lcquant top`, the periodic
//! snapshot dump) — nothing on the serving hot path touches it.

use super::hist::HistogramSnapshot;
use std::collections::VecDeque;

/// One periodic observation of a peer's monotonic books.
#[derive(Clone, Debug)]
struct Sample {
    /// Caller-supplied timestamp, seconds from any fixed origin.
    t_s: f64,
    /// Requests answered OK, lifetime total.
    requests: u64,
    /// Requests shed, lifetime total.
    shed: u64,
    /// Latency histogram snapshot at the same instant.
    hist: HistogramSnapshot,
}

/// Rolling rates derived from the oldest and newest retained samples.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowRates {
    /// Window span actually covered, seconds.
    pub span_s: f64,
    /// Requests answered per second over the window.
    pub qps: f64,
    /// Sheds per second over the window.
    pub shed_per_s: f64,
    /// Shed fraction over the window: `shed / (ok + shed)`, 0 when idle.
    pub shed_rate: f64,
    /// p99 latency of requests recorded *inside* the window, ms.
    pub p99_ms: f32,
    /// Requests recorded inside the window (the delta's sample count).
    pub delta_count: u64,
}

/// Bounded ring of periodic counter/histogram samples (see module docs).
pub struct RateWindow {
    slots: usize,
    samples: VecDeque<Sample>,
}

impl RateWindow {
    /// A window retaining the most recent `slots` samples (minimum 2 —
    /// rates need two points).
    pub fn new(slots: usize) -> RateWindow {
        let slots = slots.max(2);
        RateWindow { slots, samples: VecDeque::with_capacity(slots) }
    }

    /// Number of samples retained at most.
    pub fn capacity(&self) -> usize {
        self.slots
    }

    /// Samples currently held.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True until the first sample arrives.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Record one observation. `t_s` is a caller-supplied monotonic
    /// timestamp in seconds (e.g. `Instant::elapsed` of the poller's
    /// start); samples arriving with a timestamp older than the newest
    /// retained one are dropped (a restarted poller starts a new window).
    pub fn push(&mut self, t_s: f64, requests: u64, shed: u64, hist: HistogramSnapshot) {
        if let Some(last) = self.samples.back() {
            if t_s < last.t_s {
                self.samples.clear();
            }
        }
        if self.samples.len() == self.slots {
            self.samples.pop_front();
        }
        self.samples.push_back(Sample { t_s, requests, shed, hist });
    }

    /// Rates over the retained window: `None` until two samples exist or
    /// while the span is not positive. Counter deltas saturate at zero, so
    /// a peer restart (totals reset) reads as an idle window, not a spike.
    pub fn rates(&self) -> Option<WindowRates> {
        let oldest = self.samples.front()?;
        let newest = self.samples.back()?;
        let span_s = newest.t_s - oldest.t_s;
        if span_s <= 0.0 {
            return None;
        }
        let d_req = newest.requests.saturating_sub(oldest.requests);
        let d_shed = newest.shed.saturating_sub(oldest.shed);
        let delta = newest.hist.delta_since(&oldest.hist);
        let offered = d_req + d_shed;
        Some(WindowRates {
            span_s,
            qps: d_req as f64 / span_s,
            shed_per_s: d_shed as f64 / span_s,
            shed_rate: if offered == 0 { 0.0 } else { d_shed as f64 / offered as f64 },
            p99_ms: delta.percentile_ms(99.0),
            delta_count: delta.count(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::{bucket_index, bucket_max_ns, Histogram};

    #[test]
    fn rates_come_from_the_window_not_the_lifetime() {
        let h = Histogram::new();
        let mut w = RateWindow::new(4);
        // lifetime history: 1000 fast requests before the window opened
        for _ in 0..1000 {
            h.record_ns(1_000); // ~1 µs
        }
        w.push(0.0, 1000, 0, h.snapshot());
        // inside the window: 20 slow requests over 2 seconds
        for _ in 0..20 {
            h.record_ns(50_000_000); // 50 ms
        }
        w.push(2.0, 1020, 5, h.snapshot());
        let r = w.rates().unwrap();
        assert_eq!(r.span_s, 2.0);
        assert_eq!(r.qps, 10.0);
        assert_eq!(r.shed_per_s, 2.5);
        assert!((r.shed_rate - 5.0 / 25.0).abs() < 1e-12);
        assert_eq!(r.delta_count, 20);
        // the window p99 sees only the slow bucket — the 1000 fast
        // lifetime samples would have dragged a lifetime p99 to ~1 µs
        let expect_ms = (bucket_max_ns(bucket_index(50_000_000)) as f64 / 1e6) as f32;
        assert_eq!(r.p99_ms, expect_ms);
    }

    #[test]
    fn window_is_bounded_and_slides() {
        let mut w = RateWindow::new(3);
        let h = Histogram::new();
        for i in 0..10u64 {
            w.push(i as f64, i * 100, 0, h.snapshot());
        }
        assert_eq!(w.len(), 3);
        let r = w.rates().unwrap();
        // oldest retained is t=7 (700), newest t=9 (900)
        assert_eq!(r.span_s, 2.0);
        assert_eq!(r.qps, 100.0);
    }

    #[test]
    fn degenerate_windows_are_none_and_resets_are_absorbed() {
        let h = Histogram::new();
        let mut w = RateWindow::new(4);
        assert!(w.rates().is_none());
        w.push(1.0, 50, 0, h.snapshot());
        assert!(w.rates().is_none(), "one sample has no span");
        // same-timestamp second sample: still no positive span
        w.push(1.0, 60, 0, h.snapshot());
        assert!(w.rates().is_none());
        // a peer restart: totals drop — saturating delta reads as idle
        w.push(2.0, 5, 0, h.snapshot());
        let r = w.rates().unwrap();
        assert_eq!(r.qps, 0.0);
        assert_eq!(r.shed_rate, 0.0);
        // time going backwards starts a fresh window
        w.push(0.5, 1000, 0, h.snapshot());
        assert_eq!(w.len(), 1);
        assert!(w.rates().is_none());
    }
}
