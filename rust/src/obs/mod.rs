//! Process-wide observability plane: metrics registry, trace ring, and
//! snapshot exposition.
//!
//! The plane has three pieces, threaded through every serving layer:
//!
//! * **Registry** — statically-registered [`Counter`]s, [`Gauge`]s and
//!   log₂-bucketed latency [`Histogram`]s (see [`hist`]), addressed by
//!   the [`CounterId`]/[`GaugeId`]/[`HistId`] enums. The whole registry
//!   is one `static` of fixed-size atomic arrays: recording is a relaxed
//!   `fetch_add` — zero-alloc, lock-free — so hot paths record every
//!   event instead of sampling into a `Vec<f32>` and sorting on read.
//! * **Trace spans** — per-request [`trace::Trace`] records carrying the
//!   wire request id through accept → decode → queue wait → assembly →
//!   compute → frame → write, retained in a bounded overwrite-oldest
//!   [`trace::TraceRing`].
//! * **Exposition** — [`Registry::snapshot_json`] renders the registry
//!   for the LCQ-RPC `Stats` frame (`net::proto`), the `stats` CLI
//!   command, and periodic dumps driven by the config `obs` section.
//!
//! Subsystems that need *exact* per-instance counts (the net server's
//! shed accounting, the batch server's request totals) keep their own
//! per-instance atomics and additionally mirror into this global
//! registry; the registry is the process-wide aggregate view. Global
//! mirroring and tracing can be switched off wholesale with
//! [`set_enabled`] — `benches/bench_obs.rs` uses this for the
//! instrumented-vs-uninstrumented A/B.

#![warn(missing_docs)]

pub mod hist;
pub mod trace;
pub mod window;

pub use hist::{Histogram, HistogramSnapshot, HIST_BUCKETS};
pub use trace::{RouterStage, Stage, Trace, TraceRing, ROUTER_STAGES, STAGES};
pub use window::{RateWindow, WindowRates};

use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Monotonic event counters, one per enum variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum CounterId {
    /// TCP connections accepted by the net server.
    NetConnections = 0,
    /// Connections shed at accept (connection limit).
    NetConnectionsShed = 1,
    /// Wire requests answered successfully.
    NetRequestsOk = 2,
    /// Wire requests shed (inflight budget exhausted).
    NetRequestsShed = 3,
    /// Wire requests answered with an error frame.
    NetRequestsFailed = 4,
    /// Stats frames served.
    NetStatsRequests = 5,
    /// Requests entering the micro-batch server.
    ServeRequests = 6,
    /// Batches executed by the micro-batch server.
    ServeBatches = 7,
    /// Requests that rode in a batch (sum of batch sizes).
    ServeBatchedRequests = 8,
    /// Requests answered with an error by the micro-batch server.
    ServeErrors = 9,
    /// Traces published into the global ring.
    TracesRecorded = 10,
    /// Traces dropped by the ring (slot contention).
    TracesDropped = 11,
    /// LC outer iterations completed.
    LcIterations = 12,
    /// `.lcq` models loaded zero-copy via a page-cache mapping (the heap
    /// fallback and eager loads don't count).
    LcqMmapLoads = 13,
    /// `.lcq` plane sections whose FNV checksum was actually computed
    /// (lazy first touch, or eager load).
    LcqSectionVerifies = 14,
    /// Plane verification calls answered from an already-verified section
    /// — the work the lazy checksum scheme avoided.
    LcqLazyVerifyHits = 15,
    /// `NetClient` retry attempts after a failed round trip (the first
    /// attempt of a request is not a retry).
    NetClientRetries = 16,
    /// Connections shed by the net server's per-frame progress deadline
    /// (slow-loris defense): a request frame held partial bytes without
    /// completing for longer than `frame_deadline`.
    NetFrameTimeouts = 17,
    /// Routed requests answered with a backend response.
    FabricRequestsOk = 18,
    /// Routed requests answered with a typed error relayed from a backend.
    FabricRequestsFailed = 19,
    /// Routed requests shed by the router itself (all replicas down,
    /// retry budget or deadline exhausted).
    FabricRequestsShed = 20,
    /// Router re-attempts of a request after a failed forward (any
    /// backend, including the same one).
    FabricRetries = 21,
    /// Router re-attempts that switched to a *different* backend.
    FabricFailovers = 22,
    /// Backend health state transitions observed by the router.
    FabricHealthTransitions = 23,
    /// Active hello probes completed (success or failure) by the router.
    FabricProbes = 24,
    /// Epoll loop iterations of the event-driven connection plane that
    /// delivered work (readiness events or a cross-thread wake).
    NetEpollWakeups = 25,
    /// Requests shed with `Overloaded` because the per-connection
    /// pipeline bound (`max_inflight`: queued replies + in-flight rows)
    /// was already full.
    NetWriteqSheds = 26,
    /// Fleet-stats frames answered by a fabric router (one per
    /// `FleetStatsRequest`, regardless of how many backends it fanned to).
    NetFleetStatsRequests = 27,
}

/// Number of [`CounterId`] variants.
pub const COUNTERS: usize = 28;

impl CounterId {
    /// All counters, declaration order.
    pub const ALL: [CounterId; COUNTERS] = [
        CounterId::NetConnections,
        CounterId::NetConnectionsShed,
        CounterId::NetRequestsOk,
        CounterId::NetRequestsShed,
        CounterId::NetRequestsFailed,
        CounterId::NetStatsRequests,
        CounterId::ServeRequests,
        CounterId::ServeBatches,
        CounterId::ServeBatchedRequests,
        CounterId::ServeErrors,
        CounterId::TracesRecorded,
        CounterId::TracesDropped,
        CounterId::LcIterations,
        CounterId::LcqMmapLoads,
        CounterId::LcqSectionVerifies,
        CounterId::LcqLazyVerifyHits,
        CounterId::NetClientRetries,
        CounterId::NetFrameTimeouts,
        CounterId::FabricRequestsOk,
        CounterId::FabricRequestsFailed,
        CounterId::FabricRequestsShed,
        CounterId::FabricRetries,
        CounterId::FabricFailovers,
        CounterId::FabricHealthTransitions,
        CounterId::FabricProbes,
        CounterId::NetEpollWakeups,
        CounterId::NetWriteqSheds,
        CounterId::NetFleetStatsRequests,
    ];

    /// Stable snake_case name (the JSON key in snapshots).
    pub fn name(self) -> &'static str {
        match self {
            CounterId::NetConnections => "net_connections",
            CounterId::NetConnectionsShed => "net_connections_shed",
            CounterId::NetRequestsOk => "net_requests_ok",
            CounterId::NetRequestsShed => "net_requests_shed",
            CounterId::NetRequestsFailed => "net_requests_failed",
            CounterId::NetStatsRequests => "net_stats_requests",
            CounterId::ServeRequests => "serve_requests",
            CounterId::ServeBatches => "serve_batches",
            CounterId::ServeBatchedRequests => "serve_batched_requests",
            CounterId::ServeErrors => "serve_errors",
            CounterId::TracesRecorded => "traces_recorded",
            CounterId::TracesDropped => "traces_dropped",
            CounterId::LcIterations => "lc_iterations",
            CounterId::LcqMmapLoads => "lcq_mmap_loads",
            CounterId::LcqSectionVerifies => "lcq_section_verifies",
            CounterId::LcqLazyVerifyHits => "lcq_lazy_verify_hits",
            CounterId::NetClientRetries => "net_client_retries",
            CounterId::NetFrameTimeouts => "net_frame_timeouts",
            CounterId::FabricRequestsOk => "fabric_requests_ok",
            CounterId::FabricRequestsFailed => "fabric_requests_failed",
            CounterId::FabricRequestsShed => "fabric_requests_shed",
            CounterId::FabricRetries => "fabric_retries",
            CounterId::FabricFailovers => "fabric_failovers",
            CounterId::FabricHealthTransitions => "fabric_health_transitions",
            CounterId::FabricProbes => "fabric_probes",
            CounterId::NetEpollWakeups => "net_epoll_wakeups",
            CounterId::NetWriteqSheds => "net_writeq_sheds",
            CounterId::NetFleetStatsRequests => "net_fleet_stats_requests",
        }
    }
}

/// Last-value gauges (stored as `f64` bits), one per enum variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum GaugeId {
    /// Current LC outer iteration (1-based).
    LcIter = 0,
    /// Current LC penalty parameter μ.
    LcMu = 1,
    /// L-step loss of the latest LC iteration.
    LcLoss = 2,
    /// Feasibility norm ‖w − Δ(θ)‖ of the latest LC iteration.
    LcFeasibility = 3,
    /// Wall time of the latest L step, milliseconds.
    LcLstepMs = 4,
    /// Wall time of the latest C step, milliseconds.
    LcCstepMs = 5,
    /// Router: backends currently in the `Healthy` state.
    FabricBackendsHealthy = 6,
    /// Router: backends currently in the `Down` state.
    FabricBackendsDown = 7,
    /// Net server: rows currently inside the in-flight budget (admitted
    /// to the batcher, response not yet assembled).
    NetInflight = 8,
    /// Net server: replies queued in connection write queues, summed
    /// across net threads at the last poll tick.
    NetWriteqDepth = 9,
}

/// Number of [`GaugeId`] variants.
pub const GAUGES: usize = 10;

impl GaugeId {
    /// All gauges, declaration order.
    pub const ALL: [GaugeId; GAUGES] = [
        GaugeId::LcIter,
        GaugeId::LcMu,
        GaugeId::LcLoss,
        GaugeId::LcFeasibility,
        GaugeId::LcLstepMs,
        GaugeId::LcCstepMs,
        GaugeId::FabricBackendsHealthy,
        GaugeId::FabricBackendsDown,
        GaugeId::NetInflight,
        GaugeId::NetWriteqDepth,
    ];

    /// Stable snake_case name (the JSON key in snapshots).
    pub fn name(self) -> &'static str {
        match self {
            GaugeId::LcIter => "lc_iter",
            GaugeId::LcMu => "lc_mu",
            GaugeId::LcLoss => "lc_loss",
            GaugeId::LcFeasibility => "lc_feasibility",
            GaugeId::LcLstepMs => "lc_lstep_ms",
            GaugeId::LcCstepMs => "lc_cstep_ms",
            GaugeId::FabricBackendsHealthy => "fabric_backends_healthy",
            GaugeId::FabricBackendsDown => "fabric_backends_down",
            GaugeId::NetInflight => "net_inflight",
            GaugeId::NetWriteqDepth => "net_writeq_depth",
        }
    }
}

/// Latency histograms, one per enum variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum HistId {
    /// Micro-batch server: request enqueue → reply, end to end.
    ServeLatency = 0,
    /// Micro-batch server: time waiting in the batcher queue.
    ServeQueueWait = 1,
    /// Micro-batch server: batch cut → executor pickup.
    ServeAssembly = 2,
    /// Micro-batch server: forward pass wall time.
    ServeCompute = 3,
    /// Net server: request decode → response written.
    NetRequest = 4,
    /// Net server: connection handshake duration.
    NetHandshake = 5,
    /// LC loop: L-step wall time.
    LcLstep = 6,
    /// LC loop: C-step wall time.
    LcCstep = 7,
    /// Registry: `.lcq` cold load, file open → engine ready.
    ModelLoad = 8,
    /// Router: request decode → response written (includes retries).
    FabricRequest = 9,
    /// Router: one backend round trip (forward → backend reply).
    FabricBackendRtt = 10,
    /// Router: full fleet-stats fan-out wall time (all backends queried,
    /// merged document built).
    FabricFleetFanout = 11,
}

/// Number of [`HistId`] variants.
pub const HISTS: usize = 12;

impl HistId {
    /// All histograms, declaration order.
    pub const ALL: [HistId; HISTS] = [
        HistId::ServeLatency,
        HistId::ServeQueueWait,
        HistId::ServeAssembly,
        HistId::ServeCompute,
        HistId::NetRequest,
        HistId::NetHandshake,
        HistId::LcLstep,
        HistId::LcCstep,
        HistId::ModelLoad,
        HistId::FabricRequest,
        HistId::FabricBackendRtt,
        HistId::FabricFleetFanout,
    ];

    /// Stable snake_case name (the JSON key in snapshots).
    pub fn name(self) -> &'static str {
        match self {
            HistId::ServeLatency => "serve_latency",
            HistId::ServeQueueWait => "serve_queue_wait",
            HistId::ServeAssembly => "serve_assembly",
            HistId::ServeCompute => "serve_compute",
            HistId::NetRequest => "net_request",
            HistId::NetHandshake => "net_handshake",
            HistId::LcLstep => "lc_lstep",
            HistId::LcCstep => "lc_cstep",
            HistId::ModelLoad => "model_load",
            HistId::FabricRequest => "fabric_request",
            HistId::FabricBackendRtt => "fabric_backend_rtt",
            HistId::FabricFleetFanout => "fabric_fleet_fanout",
        }
    }
}

/// One monotonic counter (relaxed atomic).
pub struct Counter(AtomicU64);

impl Counter {
    /// A zeroed counter.
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }
    /// Add 1.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// One last-value gauge: an `f64` stored as bits in a relaxed atomic.
pub struct Gauge(AtomicU64);

impl Gauge {
    /// A gauge reading 0.0.
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }
    /// Store a value (exact — the f64 bits are kept verbatim, so reads
    /// are bit-identical to what was written; the LC parity test in
    /// `rust/tests/obs.rs` depends on this).
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }
    /// Current value.
    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// The metrics registry: fixed arrays of counters, gauges and histograms
/// indexed by the id enums. Fully `const`-constructible.
pub struct Registry {
    counters: [Counter; COUNTERS],
    gauges: [Gauge; GAUGES],
    hists: [Histogram; HISTS],
}

impl Registry {
    /// An all-zero registry.
    pub const fn new() -> Registry {
        #[allow(clippy::declare_interior_mutable_const)]
        const C: Counter = Counter::new();
        #[allow(clippy::declare_interior_mutable_const)]
        const G: Gauge = Gauge::new();
        #[allow(clippy::declare_interior_mutable_const)]
        const H: Histogram = Histogram::new();
        Registry { counters: [C; COUNTERS], gauges: [G; GAUGES], hists: [H; HISTS] }
    }

    /// The counter for `id`.
    #[inline]
    pub fn counter(&self, id: CounterId) -> &Counter {
        &self.counters[id as usize]
    }

    /// The gauge for `id`.
    #[inline]
    pub fn gauge(&self, id: GaugeId) -> &Gauge {
        &self.gauges[id as usize]
    }

    /// The histogram for `id`.
    #[inline]
    pub fn hist(&self, id: HistId) -> &Histogram {
        &self.hists[id as usize]
    }

    /// Render the registry as a JSON object:
    /// `{"counters": {name: n, ...}, "gauges": {...}, "histograms":
    /// {name: {count, mean_ms, p50_ms, p90_ms, p99_ms, max_ms}, ...}}`.
    pub fn snapshot_json(&self) -> Json {
        let counters = CounterId::ALL
            .iter()
            .map(|&id| (id.name(), Json::from(self.counter(id).get() as usize)))
            .collect();
        let gauges =
            GaugeId::ALL.iter().map(|&id| (id.name(), Json::from(self.gauge(id).get()))).collect();
        let hists = HistId::ALL
            .iter()
            .map(|&id| (id.name(), self.hist(id).snapshot().to_json()))
            .collect();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
        ])
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

/// The process-wide registry.
static GLOBAL: Registry = Registry::new();

/// Whether global mirroring + tracing is on (default: on). Per-instance
/// stats in `serve`/`net` always record regardless.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// The process-wide [`Registry`].
#[inline]
pub fn global() -> &'static Registry {
    &GLOBAL
}

/// Shorthand for `global().counter(id)`.
#[inline]
pub fn counter(id: CounterId) -> &'static Counter {
    GLOBAL.counter(id)
}

/// Shorthand for `global().gauge(id)`.
#[inline]
pub fn gauge(id: GaugeId) -> &'static Gauge {
    GLOBAL.gauge(id)
}

/// Shorthand for `global().hist(id)`.
#[inline]
pub fn hist(id: HistId) -> &'static Histogram {
    GLOBAL.hist(id)
}

/// Is global mirroring + tracing enabled?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Switch global mirroring + tracing on/off process-wide (the
/// instrumented-vs-uninstrumented bench toggle).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Record one LC outer iteration into the registry: counters, gauges and
/// the L/C step histograms. The gauge values are stored bit-exact so they
/// match `metrics::History` records produced from the same `f64` casts.
pub fn lc_iteration(iter: usize, mu: f64, loss: f64, feasibility: f64, lstep_ns: u64, cstep_ns: u64) {
    if !enabled() {
        return;
    }
    counter(CounterId::LcIterations).inc();
    gauge(GaugeId::LcIter).set(iter as f64);
    gauge(GaugeId::LcMu).set(mu);
    gauge(GaugeId::LcLoss).set(loss);
    gauge(GaugeId::LcFeasibility).set(feasibility);
    gauge(GaugeId::LcLstepMs).set(lstep_ns as f64 / 1e6);
    gauge(GaugeId::LcCstepMs).set(cstep_ns as f64 / 1e6);
    hist(HistId::LcLstep).record_ns(lstep_ns);
    hist(HistId::LcCstep).record_ns(cstep_ns);
}

/// Render a slice of traces for the stats snapshot: each trace becomes
/// `{"id": n, "trace_id": n, "total_ms": x, "stages": {accept: ms, ...}}`.
pub fn traces_json(traces: &[Trace]) -> Json {
    let items: Vec<Json> = traces
        .iter()
        .map(|t| {
            let stages = Stage::ALL
                .iter()
                .map(|&s| (s.name(), Json::from(t.stage_ns[s as usize] as f64 / 1e6)))
                .collect();
            Json::obj(vec![
                ("id", Json::from(t.id as usize)),
                ("trace_id", Json::from(t.trace_id as usize)),
                ("total_ms", Json::from(t.total_ns() as f64 / 1e6)),
                ("stages", Json::obj(stages)),
            ])
        })
        .collect();
    Json::Arr(items)
}

/// Render router-side spans: same shape as [`traces_json`] but the stage
/// keys are the [`RouterStage`] hop names (`pick`/`forward`/`backend_wait`
/// /`relay`) read from the first [`ROUTER_STAGES`] stage words.
pub fn router_traces_json(traces: &[Trace]) -> Json {
    let items: Vec<Json> = traces
        .iter()
        .map(|t| {
            let stages = RouterStage::ALL
                .iter()
                .map(|&s| (s.name(), Json::from(t.stage_ns[s as usize] as f64 / 1e6)))
                .collect();
            Json::obj(vec![
                ("id", Json::from(t.id as usize)),
                ("trace_id", Json::from(t.trace_id as usize)),
                ("total_ms", Json::from(t.total_ns() as f64 / 1e6)),
                ("stages", Json::obj(stages)),
            ])
        })
        .collect();
    Json::Arr(items)
}

/// Render the trace ids currently resident in a ring (the loadgen trace-
/// coverage probe reads this): an array of the non-zero fleet trace ids,
/// unordered.
pub fn trace_ids_json(traces: &[Trace]) -> Json {
    Json::Arr(
        traces
            .iter()
            .filter(|t| t.trace_id != 0)
            .map(|t| Json::from(t.trace_id as usize))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_enums_are_dense_and_named() {
        for (i, id) in CounterId::ALL.iter().enumerate() {
            assert_eq!(*id as usize, i);
            assert!(!id.name().is_empty());
        }
        for (i, id) in GaugeId::ALL.iter().enumerate() {
            assert_eq!(*id as usize, i);
            assert!(!id.name().is_empty());
        }
        for (i, id) in HistId::ALL.iter().enumerate() {
            assert_eq!(*id as usize, i);
            assert!(!id.name().is_empty());
        }
        // names are unique (they are JSON keys)
        let mut names: Vec<&str> = CounterId::ALL.iter().map(|i| i.name()).collect();
        names.extend(GaugeId::ALL.iter().map(|i| i.name()));
        names.extend(HistId::ALL.iter().map(|i| i.name()));
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate metric name");
    }

    #[test]
    fn gauge_round_trips_bits() {
        let g = Gauge::new();
        for v in [0.0, -0.0, 1.5, f64::MIN_POSITIVE, 1e300, f64::INFINITY] {
            g.set(v);
            assert_eq!(g.get().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn registry_snapshot_contains_every_metric() {
        let r = Registry::new();
        r.counter(CounterId::ServeRequests).add(3);
        r.gauge(GaugeId::LcMu).set(0.25);
        r.hist(HistId::ServeLatency).record_ns(1000);
        let j = r.snapshot_json();
        let counters = j.get("counters").unwrap();
        for id in CounterId::ALL {
            assert!(counters.get(id.name()).is_some(), "missing counter {}", id.name());
        }
        assert_eq!(counters.get("serve_requests").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get("gauges").unwrap().get("lc_mu").unwrap().as_f64().unwrap(), 0.25);
        let h = j.get("histograms").unwrap().get("serve_latency").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64().unwrap(), 1.0);
    }
}
