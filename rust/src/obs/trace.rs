//! Per-request trace spans and the bounded ring that retains them.
//!
//! A [`Trace`] follows one wire request through the serving pipeline,
//! timing each [`Stage`]: accept (connection handshake), decode, queue
//! wait, batch assembly, pool compute, frame, write. Finished traces are
//! recorded into a [`TraceRing`] — a fixed-size ring of slots claimed by
//! a single atomic cursor `fetch_add`, the same cell-claim idiom as
//! `util::mpmc` — that **overwrites oldest and never blocks**: a writer
//! that loses the race for a slot (the previous writer is still mid-
//! publish) drops the trace and bumps the `TracesDropped` counter rather
//! than spinning.
//!
//! Each slot is a sequence counter plus a fixed array of plain atomic
//! words (request id, total, per-stage nanoseconds). The sequence is a
//! publication guard in the seqlock style — even = stable, odd = being
//! written — but the payload words are themselves relaxed atomics, so a
//! torn read is impossible at the language level; the sequence check only
//! rejects *mixed* (partly-old, partly-new) snapshots.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Pipeline stages a request passes through, in order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Stage {
    /// Connection handshake (shared by every request on the connection).
    Accept = 0,
    /// Frame checksum verification + payload decode.
    Decode = 1,
    /// Waiting in the batcher queue before a batch was cut.
    QueueWait = 2,
    /// Batch assembly: batch cut until the executor picked it up.
    Assembly = 3,
    /// Forward pass in the compute pool.
    Compute = 4,
    /// Response frame encode.
    Frame = 5,
    /// Response write to the socket.
    Write = 6,
}

/// Number of [`Stage`] variants.
pub const STAGES: usize = 7;

impl Stage {
    /// All stages, pipeline order.
    pub const ALL: [Stage; STAGES] = [
        Stage::Accept,
        Stage::Decode,
        Stage::QueueWait,
        Stage::Assembly,
        Stage::Compute,
        Stage::Frame,
        Stage::Write,
    ];

    /// Stable display name (used as the JSON key in snapshots).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Accept => "accept",
            Stage::Decode => "decode",
            Stage::QueueWait => "queue_wait",
            Stage::Assembly => "assembly",
            Stage::Compute => "compute",
            Stage::Frame => "frame",
            Stage::Write => "write",
        }
    }
}

/// Router-side hop stages for a forwarded request, in order. A router
/// span reuses the same [`TraceRing`] machinery as the backend's 7-stage
/// pipeline but times the fabric hop instead; the two link up through the
/// shared fleet-wide `trace_id`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum RouterStage {
    /// Candidate filtering + health-aware replica pick.
    Pick = 0,
    /// Writing the forwarded request to the backend socket.
    Forward = 1,
    /// Waiting for the backend's reply (includes all retry attempts).
    BackendWait = 2,
    /// Relaying the backend's answer back toward the client.
    Relay = 3,
}

/// Number of [`RouterStage`] variants.
pub const ROUTER_STAGES: usize = 4;

impl RouterStage {
    /// All router stages, hop order.
    pub const ALL: [RouterStage; ROUTER_STAGES] = [
        RouterStage::Pick,
        RouterStage::Forward,
        RouterStage::BackendWait,
        RouterStage::Relay,
    ];

    /// Stable display name (used as the JSON key in snapshots).
    pub fn name(self) -> &'static str {
        match self {
            RouterStage::Pick => "pick",
            RouterStage::Forward => "forward",
            RouterStage::BackendWait => "backend_wait",
            RouterStage::Relay => "relay",
        }
    }
}

/// One request's span record, built up stage by stage on the connection
/// thread and published to a [`TraceRing`] when the response is written.
/// Plain value type — building and finishing a trace allocates nothing.
#[derive(Clone, Copy, Debug)]
pub struct Trace {
    /// Wire request id (`RequestFrame::id`).
    pub id: u64,
    /// Fleet-wide trace id stitching this span to the other tiers' spans
    /// for the same request (0 = untraced / pre-v3 record).
    pub trace_id: u64,
    /// Per-stage wall time, nanoseconds, indexed by [`Stage`].
    pub stage_ns: [u64; STAGES],
    start: Option<Instant>,
}

impl Trace {
    /// Start a trace for wire request `id` (untraced fleet identity; set
    /// [`Trace::trace_id`] to link it across tiers).
    pub fn begin(id: u64) -> Trace {
        Trace { id, trace_id: 0, stage_ns: [0; STAGES], start: Some(Instant::now()) }
    }

    /// A trace with no timing clock (for decoded/stored records).
    pub fn from_parts(id: u64, trace_id: u64, stage_ns: [u64; STAGES]) -> Trace {
        Trace { id, trace_id, stage_ns, start: None }
    }

    /// Set one stage's duration directly.
    #[inline]
    pub fn set(&mut self, stage: Stage, ns: u64) {
        self.stage_ns[stage as usize] = ns;
    }

    /// Total across all stages, nanoseconds.
    pub fn total_ns(&self) -> u64 {
        self.stage_ns.iter().fold(0u64, |a, &b| a.saturating_add(b))
    }

    /// Wall time since [`Trace::begin`], nanoseconds (0 without a clock).
    pub fn elapsed_ns(&self) -> u64 {
        match self.start {
            Some(t) => u64::try_from(t.elapsed().as_nanos()).unwrap_or(u64::MAX),
            None => 0,
        }
    }
}

/// Words per ring slot: request id, fleet trace id, total, then one word
/// per stage.
const SLOT_WORDS: usize = 3 + STAGES;

struct TraceSlot {
    /// Even = stable, odd = mid-write, 0 = never written.
    seq: AtomicU64,
    words: [AtomicU64; SLOT_WORDS],
}

impl TraceSlot {
    const fn new() -> TraceSlot {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        TraceSlot { seq: AtomicU64::new(0), words: [ZERO; SLOT_WORDS] }
    }
}

/// Bounded lock-free ring of recent traces (see module docs). Capacity is
/// rounded up to a power of two so the claim cursor can mask instead of
/// divide.
pub struct TraceRing {
    slots: Vec<TraceSlot>,
    mask: u64,
    cursor: AtomicU64,
    dropped: AtomicU64,
}

impl TraceRing {
    /// A ring retaining the most recent `capacity.next_power_of_two()`
    /// traces (minimum 2).
    pub fn new(capacity: usize) -> TraceRing {
        let cap = capacity.max(2).next_power_of_two();
        let mut slots = Vec::with_capacity(cap);
        for _ in 0..cap {
            slots.push(TraceSlot::new());
        }
        TraceRing { slots, mask: (cap as u64) - 1, cursor: AtomicU64::new(0), dropped: AtomicU64::new(0) }
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Traces dropped because a slot was still being published.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Publish a finished trace. Never blocks: claims a slot by a single
    /// `fetch_add`, and if that slot is mid-publish by a lapped writer the
    /// trace is counted as dropped instead. Zero-alloc (asserted in
    /// `rust/tests/obs.rs`).
    pub fn record(&self, trace: &Trace) -> bool {
        let idx = (self.cursor.fetch_add(1, Ordering::Relaxed) & self.mask) as usize;
        let slot = &self.slots[idx];
        let seq = slot.seq.load(Ordering::Relaxed);
        if seq & 1 == 1
            || slot
                .seq
                .compare_exchange(seq, seq + 1, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        slot.words[0].store(trace.id, Ordering::Relaxed);
        slot.words[1].store(trace.trace_id, Ordering::Relaxed);
        slot.words[2].store(trace.total_ns(), Ordering::Relaxed);
        for (w, &ns) in slot.words[3..].iter().zip(trace.stage_ns.iter()) {
            w.store(ns, Ordering::Relaxed);
        }
        slot.seq.store(seq + 2, Ordering::Release);
        true
    }

    /// Stable snapshot of every published trace, unordered. Slots caught
    /// mid-write are skipped (they will appear in a later snapshot).
    pub fn snapshot(&self) -> Vec<Trace> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 & 1 == 1 {
                continue;
            }
            let id = slot.words[0].load(Ordering::Relaxed);
            let trace_id = slot.words[1].load(Ordering::Relaxed);
            let mut stage_ns = [0u64; STAGES];
            for (ns, w) in stage_ns.iter_mut().zip(&slot.words[3..]) {
                *ns = w.load(Ordering::Relaxed);
            }
            std::sync::atomic::fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != s1 {
                continue;
            }
            out.push(Trace::from_parts(id, trace_id, stage_ns));
        }
        out
    }

    /// The `n` slowest published traces by total time, slowest first.
    pub fn slowest(&self, n: usize) -> Vec<Trace> {
        let mut all = self.snapshot();
        all.sort_by(|a, b| b.total_ns().cmp(&a.total_ns()));
        all.truncate(n);
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(id: u64, base: u64) -> Trace {
        let mut t = Trace::begin(id);
        for (i, st) in Stage::ALL.iter().enumerate() {
            t.set(*st, base + i as u64);
        }
        t
    }

    #[test]
    fn record_and_snapshot_round_trip() {
        let ring = TraceRing::new(8);
        assert!(ring.record(&mk(7, 100)));
        let got = ring.snapshot();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 7);
        assert_eq!(got[0].stage_ns[Stage::Compute as usize], 104);
        assert_eq!(got[0].total_ns(), (100..107).sum::<u64>());
    }

    #[test]
    fn trace_id_round_trips_through_the_ring() {
        let ring = TraceRing::new(4);
        let mut t = mk(11, 50);
        t.trace_id = 0xABCD;
        assert!(ring.record(&t));
        let got = ring.snapshot();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 11);
        assert_eq!(got[0].trace_id, 0xABCD);
        // untraced records report the 0 sentinel
        assert_eq!(mk(12, 0).trace_id, 0);
        for st in RouterStage::ALL {
            assert!(!st.name().is_empty());
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let ring = TraceRing::new(4);
        for id in 0..10u64 {
            ring.record(&mk(id, 0));
        }
        let ids: Vec<u64> = ring.snapshot().iter().map(|t| t.id).collect();
        assert_eq!(ids.len(), 4);
        // last 4 records survive
        for id in 6..10u64 {
            assert!(ids.contains(&id), "missing id {id} in {ids:?}");
        }
    }

    #[test]
    fn slowest_sorts_by_total() {
        let ring = TraceRing::new(8);
        ring.record(&mk(1, 10));
        ring.record(&mk(2, 1000));
        ring.record(&mk(3, 100));
        let top = ring.slowest(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].id, 2);
        assert_eq!(top[1].id, 3);
    }

    #[test]
    fn concurrent_writers_never_block_and_snapshots_stay_consistent() {
        let ring = std::sync::Arc::new(TraceRing::new(16));
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let r = std::sync::Arc::clone(&ring);
            handles.push(std::thread::spawn(move || {
                for i in 0..2000u64 {
                    // stage values derived from id so a mixed snapshot is detectable
                    let id = w * 1_000_000 + i;
                    let mut t = Trace::begin(id);
                    for st in Stage::ALL {
                        t.set(st, id);
                    }
                    r.record(&t);
                }
            }));
        }
        let reader = {
            let r = std::sync::Arc::clone(&ring);
            std::thread::spawn(move || {
                for _ in 0..200 {
                    for t in r.snapshot() {
                        for st in Stage::ALL {
                            assert_eq!(t.stage_ns[st as usize], t.id, "torn trace");
                        }
                    }
                }
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        reader.join().unwrap();
    }
}
