//! Log₂-bucketed latency histograms over relaxed atomics.
//!
//! A [`Histogram`] is a fixed array of [`HIST_BUCKETS`] counters: bucket
//! `i ≥ 1` counts recorded values in `[2^(i-1), 2^i)` nanoseconds (bucket
//! 0 counts exact zeros; the last bucket absorbs everything above its
//! floor). Recording is **one relaxed `fetch_add` per value plus one for
//! the running sum — zero allocation, no lock, no CAS loop** — which is
//! what lets the serve and net hot paths record every request instead of
//! retaining a bounded `Vec<f32>` sample window and sorting it on read.
//!
//! Percentiles come out of a [`HistogramSnapshot`] by the same
//! nearest-rank discipline as [`crate::metrics::percentile_sorted`]
//! (`rank = round(q/100 · (n−1))`, walk the cumulative counts to the
//! bucket holding that rank), quantized to the bucket's inclusive upper
//! edge — so a histogram percentile is within one bucket width of the
//! exact sample percentile, pinned by the parity tests in
//! `rust/tests/obs.rs`.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log₂ buckets. 64 covers `[1 ns, 2^62 ns ≈ 146 years)` —
/// every latency this process can observe lands in exactly one bucket.
pub const HIST_BUCKETS: usize = 64;

/// Bucket index of a nanosecond value: 0 for 0, else `64 − lz(ns)`
/// clamped into the table (bucket `i ≥ 1` covers `[2^(i-1), 2^i)` ns).
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    ((u64::BITS - ns.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Inclusive upper edge of bucket `idx`, in nanoseconds (0 for bucket 0).
/// This is the representative a percentile query returns, and it lies in
/// the same bucket as every value the bucket counted.
#[inline]
pub fn bucket_max_ns(idx: usize) -> u64 {
    if idx == 0 {
        0
    } else {
        (1u64 << idx.min(63)) - 1
    }
}

/// A lock-free log₂ latency histogram (see module docs). `const`-
/// constructible, so registries of histograms are plain `static`s.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_ns: AtomicU64,
}

impl Histogram {
    /// An empty histogram.
    pub const fn new() -> Histogram {
        // interior-mutable const: the idiomatic pre-inline-const way to
        // build an array of atomics; each element is a fresh atomic
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram { buckets: [ZERO; HIST_BUCKETS], sum_ns: AtomicU64::new(0) }
    }

    /// Record one value, in nanoseconds. Hot path: two relaxed
    /// `fetch_add`s, zero allocation (asserted in `rust/tests/obs.rs`).
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one [`Duration`] (saturating at `u64::MAX` ns).
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Fold a frozen snapshot into this histogram, bucket by bucket.
    /// Because every histogram in the stack shares the same log₂ bucket
    /// edges, merging is **lossless**: percentiles over the merged
    /// counts equal percentiles over the pooled raw samples (pinned by a
    /// property test in `rust/tests/obs.rs`). This is the aggregation
    /// primitive behind the router's fleet-stats view; the serialized
    /// twin is [`HistogramSnapshot::merge`].
    pub fn merge(&self, other: &HistogramSnapshot) {
        for (b, &c) in self.buckets.iter().zip(&other.counts) {
            if c > 0 {
                b.fetch_add(c, Ordering::Relaxed);
            }
        }
        if other.sum_ns > 0 {
            self.sum_ns.fetch_add(other.sum_ns, Ordering::Relaxed);
        }
    }

    /// Point-in-time copy of the bucket counts (relaxed reads; counts
    /// recorded concurrently may or may not be included).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = [0u64; HIST_BUCKETS];
        for (c, b) in counts.iter_mut().zip(&self.buckets) {
            *c = b.load(Ordering::Relaxed);
        }
        HistogramSnapshot { counts, sum_ns: self.sum_ns.load(Ordering::Relaxed) }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Frozen bucket counts; all derived statistics read from here so one
/// snapshot yields a consistent set of percentiles.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Count per log₂ bucket (see [`bucket_index`]).
    pub counts: [u64; HIST_BUCKETS],
    /// Sum of every recorded value, nanoseconds.
    pub sum_ns: u64,
}

impl HistogramSnapshot {
    /// Total values recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Nearest-rank percentile (`q ∈ [0, 100]`), quantized to the holding
    /// bucket's inclusive upper edge, in nanoseconds. Same rank formula as
    /// [`crate::metrics::percentile_sorted`]; 0 on an empty histogram.
    pub fn percentile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q / 100.0) * (total - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_max_ns(i);
            }
        }
        bucket_max_ns(HIST_BUCKETS - 1)
    }

    /// [`HistogramSnapshot::percentile_ns`] in milliseconds.
    pub fn percentile_ms(&self, q: f64) -> f32 {
        (self.percentile_ns(q) as f64 / 1e6) as f32
    }

    /// Upper edge of the highest non-empty bucket, nanoseconds (an upper
    /// bound on the worst recorded value, within one bucket width).
    pub fn max_ns(&self) -> u64 {
        match self.counts.iter().rposition(|&c| c > 0) {
            Some(i) => bucket_max_ns(i),
            None => 0,
        }
    }

    /// [`HistogramSnapshot::max_ns`] in milliseconds.
    pub fn max_ms(&self) -> f32 {
        (self.max_ns() as f64 / 1e6) as f32
    }

    /// Mean recorded value, nanoseconds (exact — from the running sum, not
    /// the buckets); 0.0 when empty.
    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns as f64 / n as f64
        }
    }

    /// An empty snapshot (the identity element of [`merge`]).
    ///
    /// [`merge`]: HistogramSnapshot::merge
    pub fn empty() -> HistogramSnapshot {
        HistogramSnapshot { counts: [0; HIST_BUCKETS], sum_ns: 0 }
    }

    /// Exact bucket-wise merge: after `a.merge(&b)`, every percentile of
    /// `a` answers as if the two underlying sample streams had been
    /// recorded into one histogram — log₂ buckets align across processes,
    /// so merging is lossless (no re-bucketing, no interpolation).
    /// Saturating adds keep hostile/huge inputs from wrapping.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a = a.saturating_add(*b);
        }
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
    }

    /// Bucket-wise difference against an earlier snapshot of the *same*
    /// histogram: what was recorded in between. Saturating, so a restarted
    /// peer (counters reset) degrades to the current totals instead of
    /// wrapping. Feeds the [`crate::obs::window`] rolling rates.
    pub fn delta_since(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let mut out = HistogramSnapshot::empty();
        for (o, (now, then)) in
            out.counts.iter_mut().zip(self.counts.iter().zip(&earlier.counts))
        {
            *o = now.saturating_sub(*then);
        }
        out.sum_ns = self.sum_ns.saturating_sub(earlier.sum_ns);
        out
    }

    /// Summary object for the stats snapshot: count, mean and tail
    /// percentiles in milliseconds, plus the canonical mergeable form —
    /// `sum_ns` and a sparse `buckets` array of `[index, count]` pairs
    /// (non-empty buckets only, ascending index) that
    /// [`HistogramSnapshot::from_json`] round-trips exactly.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::from(i), Json::from(c as usize)]))
            .collect();
        Json::obj(vec![
            ("count", Json::from(self.count() as usize)),
            ("mean_ms", Json::from(self.mean_ns() / 1e6)),
            ("p50_ms", Json::from(self.percentile_ms(50.0) as f64)),
            ("p90_ms", Json::from(self.percentile_ms(90.0) as f64)),
            ("p99_ms", Json::from(self.percentile_ms(99.0) as f64)),
            ("max_ms", Json::from(self.max_ms() as f64)),
            ("sum_ns", Json::from(self.sum_ns as usize)),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    /// Rebuild a snapshot from the canonical form emitted by
    /// [`HistogramSnapshot::to_json`]. Hostile documents degrade to `None`
    /// (bad shapes, bucket index ≥ [`HIST_BUCKETS`]) — never a panic.
    pub fn from_json(doc: &Json) -> Option<HistogramSnapshot> {
        let mut out = HistogramSnapshot::empty();
        out.sum_ns = doc.get("sum_ns")?.as_f64()? as u64;
        let Json::Arr(pairs) = doc.get("buckets")? else {
            return None;
        };
        for pair in pairs {
            let Json::Arr(kv) = pair else {
                return None;
            };
            if kv.len() != 2 {
                return None;
            }
            let idx = kv[0].as_f64()? as usize;
            let count = kv[1].as_f64()? as u64;
            if idx >= HIST_BUCKETS {
                return None;
            }
            out.counts[idx] = out.counts[idx].saturating_add(count);
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // every power of two starts a fresh bucket, and the inclusive
        // upper edge lies in the bucket it represents
        for i in 1..63usize {
            assert_eq!(bucket_index(1u64 << (i - 1)), i, "floor of bucket {i}");
            assert_eq!(bucket_index(bucket_max_ns(i)), i, "edge of bucket {i}");
        }
    }

    #[test]
    fn percentiles_walk_the_cumulative_counts() {
        let h = Histogram::new();
        // 10 values in bucket 4 ([8, 16)), 10 in bucket 8 ([128, 256))
        for _ in 0..10 {
            h.record_ns(10);
            h.record_ns(200);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 20);
        assert_eq!(s.percentile_ns(0.0), bucket_max_ns(bucket_index(10)));
        assert_eq!(s.percentile_ns(100.0), bucket_max_ns(bucket_index(200)));
        assert_eq!(s.max_ns(), bucket_max_ns(bucket_index(200)));
        assert_eq!(s.sum_ns, 10 * 10 + 10 * 200);
        assert!((s.mean_ns() - 105.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.percentile_ns(50.0), 0);
        assert_eq!(s.max_ns(), 0);
        assert_eq!(s.mean_ns(), 0.0);
    }

    #[test]
    fn merge_is_exact_and_commutative() {
        let a = Histogram::new();
        let b = Histogram::new();
        let pooled = Histogram::new();
        for v in [3u64, 10, 200, 5000, 0] {
            a.record_ns(v);
            pooled.record_ns(v);
        }
        for v in [7u64, 180, 9000, 1 << 40] {
            b.record_ns(v);
            pooled.record_ns(v);
        }
        let mut ab = a.snapshot();
        ab.merge(&b.snapshot());
        let mut ba = b.snapshot();
        ba.merge(&a.snapshot());
        let p = pooled.snapshot();
        assert_eq!(ab.counts, p.counts);
        assert_eq!(ba.counts, p.counts);
        assert_eq!(ab.sum_ns, p.sum_ns);
        for q in [0.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(ab.percentile_ns(q), p.percentile_ns(q), "q={q}");
        }
        // empty is the identity
        let mut e = HistogramSnapshot::empty();
        e.merge(&p);
        assert_eq!(e.counts, p.counts);
    }

    #[test]
    fn delta_since_recovers_the_interval() {
        let h = Histogram::new();
        h.record_ns(10);
        h.record_ns(200);
        let t0 = h.snapshot();
        h.record_ns(10);
        h.record_ns(3000);
        let d = h.snapshot().delta_since(&t0);
        assert_eq!(d.count(), 2);
        assert_eq!(d.counts[bucket_index(10)], 1);
        assert_eq!(d.counts[bucket_index(3000)], 1);
        assert_eq!(d.sum_ns, 3010);
        // a reset peer (snapshot smaller than baseline) saturates to zero
        let z = t0.delta_since(&h.snapshot());
        assert_eq!(z.count(), 0);
    }

    #[test]
    fn canonical_json_round_trips() {
        let h = Histogram::new();
        for v in [0u64, 1, 10, 10, 200, 1 << 40] {
            h.record_ns(v);
        }
        let s = h.snapshot();
        let back = HistogramSnapshot::from_json(&s.to_json()).unwrap();
        assert_eq!(back.counts, s.counts);
        assert_eq!(back.sum_ns, s.sum_ns);
        // hostile documents: bucket index out of range, bad shapes
        let bad = crate::util::json::Json::parse(
            r#"{"sum_ns":1,"buckets":[[99,1]]}"#,
        )
        .unwrap();
        assert!(HistogramSnapshot::from_json(&bad).is_none());
        let bad = crate::util::json::Json::parse(r#"{"sum_ns":1}"#).unwrap();
        assert!(HistogramSnapshot::from_json(&bad).is_none());
        let bad = crate::util::json::Json::parse(r#"{"sum_ns":1,"buckets":[[1]]}"#).unwrap();
        assert!(HistogramSnapshot::from_json(&bad).is_none());
    }
}
