//! Decorrelated-jitter retry backoff.
//!
//! Implements the "decorrelated jitter" policy (Brooker, AWS architecture
//! blog): each delay is drawn uniformly from `[base, prev * 3]`, clamped to
//! `[base, cap]`. Compared to plain exponential backoff this spreads
//! retries of many independent clients apart in time, which matters when a
//! backend restart makes an entire fleet retry at once (thundering herd).
//!
//! All randomness comes from [`crate::util::rng::Rng`], so a pinned seed
//! gives a fully reproducible delay sequence — fabric tests rely on this.
//!
//! A zero configuration (`base == cap == 0`) always yields zero delays,
//! which is how callers encode "retry immediately, no backoff" (the
//! [`crate::net::NetClient`] default preserving its historical single
//! instant reconnect).

use std::time::Duration;

use crate::util::rng::Rng;

/// Backoff policy parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BackoffCfg {
    /// Minimum (and first) delay. `0` disables backoff entirely.
    pub base: Duration,
    /// Upper clamp for every delay.
    pub cap: Duration,
}

impl BackoffCfg {
    /// No waiting between retries (every delay is zero).
    pub const ZERO: BackoffCfg = BackoffCfg {
        base: Duration::ZERO,
        cap: Duration::ZERO,
    };

    /// True if this config always yields zero delays.
    pub fn is_zero(&self) -> bool {
        self.base.is_zero() || self.cap.is_zero()
    }
}

impl Default for BackoffCfg {
    /// Default tuned for LAN-scale fabrics: 5 ms base, 200 ms cap.
    fn default() -> Self {
        BackoffCfg {
            base: Duration::from_millis(5),
            cap: Duration::from_millis(200),
        }
    }
}

/// Stateful delay generator. One instance per retry loop; call
/// [`Backoff::next_delay`] before each re-attempt and [`Backoff::reset`]
/// after a success so the next failure starts from `base` again.
#[derive(Clone, Debug)]
pub struct Backoff {
    cfg: BackoffCfg,
    prev: Duration,
    rng: Rng,
}

impl Backoff {
    /// New generator with the given policy and seed.
    pub fn new(cfg: BackoffCfg, seed: u64) -> Self {
        Backoff {
            cfg,
            prev: cfg.base,
            rng: Rng::new(seed),
        }
    }

    /// Draw the next delay: uniform in `[base, prev * 3]`, clamped to `cap`.
    pub fn next_delay(&mut self) -> Duration {
        if self.cfg.is_zero() {
            return Duration::ZERO;
        }
        let lo = self.cfg.base.as_secs_f64();
        let hi = (self.prev.as_secs_f64() * 3.0).min(self.cfg.cap.as_secs_f64());
        let hi = hi.max(lo);
        let d = Duration::from_secs_f64(lo + self.rng.uniform() * (hi - lo));
        let d = d.clamp(self.cfg.base, self.cfg.cap);
        self.prev = d;
        d
    }

    /// Forget accumulated growth: the next delay is drawn near `base` again.
    pub fn reset(&mut self) {
        self.prev = self.cfg.base;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cfg_yields_zero_delays() {
        let mut b = Backoff::new(BackoffCfg::ZERO, 1);
        for _ in 0..8 {
            assert_eq!(b.next_delay(), Duration::ZERO);
        }
    }

    #[test]
    fn delays_stay_within_bounds() {
        let cfg = BackoffCfg {
            base: Duration::from_millis(2),
            cap: Duration::from_millis(50),
        };
        let mut b = Backoff::new(cfg, 42);
        for _ in 0..200 {
            let d = b.next_delay();
            assert!(d >= cfg.base, "delay {d:?} below base");
            assert!(d <= cfg.cap, "delay {d:?} above cap");
        }
    }

    #[test]
    fn same_seed_same_sequence() {
        let cfg = BackoffCfg::default();
        let mut a = Backoff::new(cfg, 7);
        let mut b = Backoff::new(cfg, 7);
        for _ in 0..32 {
            assert_eq!(a.next_delay(), b.next_delay());
        }
    }

    #[test]
    fn reset_returns_to_base_scale() {
        let cfg = BackoffCfg {
            base: Duration::from_millis(1),
            cap: Duration::from_secs(10),
        };
        let mut b = Backoff::new(cfg, 3);
        // Grow the window.
        for _ in 0..20 {
            b.next_delay();
        }
        b.reset();
        // After reset the window is [base, base*3].
        let d = b.next_delay();
        assert!(d <= cfg.base * 3, "post-reset delay {d:?}");
    }

    #[test]
    fn grows_toward_cap() {
        let cfg = BackoffCfg {
            base: Duration::from_millis(1),
            cap: Duration::from_millis(64),
        };
        let mut b = Backoff::new(cfg, 11);
        let mut hit_upper_half = false;
        for _ in 0..64 {
            if b.next_delay() > cfg.cap / 2 {
                hit_upper_half = true;
            }
        }
        assert!(hit_upper_half, "backoff never grew past cap/2");
    }
}
