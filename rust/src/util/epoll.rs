//! A thin readiness-polling wrapper over the kernel's `epoll` facility —
//! the substrate of the event-driven connection plane (`net::plane`).
//!
//! No async runtime and no `libc` crate: on Linux the four syscalls
//! (`epoll_create1`, `epoll_ctl`, `epoll_wait`, `eventfd`) are declared
//! directly against the C library the standard library already links,
//! exactly like `util::mmap` does for `mmap`/`munmap`. On other unix
//! targets the same API is served by `poll(2)` (slower at thousands of
//! fds, semantically identical at test scale); on non-unix targets
//! [`Poller::new`] fails at runtime with `Unsupported` and the network
//! plane reports a clean startup error instead of compiling the platform
//! out.
//!
//! The API is deliberately tiny and level-triggered:
//!
//! * [`Poller::add`] / [`Poller::modify`] / [`Poller::delete`] manage the
//!   interest set, addressed by raw fd and tagged with a caller-chosen
//!   `u64` token (the connection plane packs a slab slot + generation
//!   into it);
//! * [`Poller::wait`] blocks for readiness and fills a reusable event
//!   buffer with portable [`Event`]s;
//! * [`Poller::waker`] hands out a cheap cloneable [`Waker`] that any
//!   thread can use to interrupt a `wait` (an `eventfd` on Linux, a
//!   loopback socket pair on the fallback). Waker traffic is drained
//!   inside `wait` and never surfaces as an event — the `bool` in
//!   `wait`'s return says whether a wake was consumed.
//!
//! Level-triggered means a socket with unread bytes (or writable space)
//! reports ready on every `wait` until drained, so a connection handler
//! that processes only part of the available input is never stranded —
//! the simplest model that is correct, and plenty at the fan-in scale the
//! C10K suite pins.

use std::io;
use std::time::Duration;

/// Raw file-descriptor type used by the poller API.
#[cfg(unix)]
pub type RawFd = std::os::fd::RawFd;
/// Raw file-descriptor placeholder on targets without descriptors; the
/// poller itself fails at runtime there, so this is never a live fd.
#[cfg(not(unix))]
pub type RawFd = i32;

/// Extract the raw fd of a TCP stream for registration with a [`Poller`].
#[cfg(unix)]
pub fn raw_fd(stream: &std::net::TcpStream) -> RawFd {
    std::os::fd::AsRawFd::as_raw_fd(stream)
}

/// Non-unix placeholder; unreachable in practice because [`Poller::new`]
/// fails before anything could be registered.
#[cfg(not(unix))]
pub fn raw_fd(_stream: &std::net::TcpStream) -> RawFd {
    -1
}

/// Readiness interest for a registered fd. Level-triggered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    /// Wake when the fd has bytes to read (or a peer hangup to observe).
    pub readable: bool,
    /// Wake when the fd can accept more outgoing bytes.
    pub writable: bool,
}

impl Interest {
    /// Read-only interest — the steady state of an idle connection.
    pub const READ: Interest = Interest { readable: true, writable: false };
    /// Read + write interest — while a connection's write queue is
    /// non-empty.
    pub const READ_WRITE: Interest = Interest { readable: true, writable: true };
}

/// One readiness event delivered by [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the fd was registered under.
    pub token: u64,
    /// Bytes are available to read (or the peer closed — a read will
    /// observe it).
    pub readable: bool,
    /// The socket can accept more outgoing bytes.
    pub writable: bool,
    /// Peer hangup / error condition; the connection should be driven to
    /// a read (which will surface the close) or torn down.
    pub hangup: bool,
}

/// Token reserved for the poller's internal waker; user registrations
/// must not use it.
const WAKE_TOKEN: u64 = u64::MAX;

// ---------------------------------------------------------------------------
// Linux backend: epoll + eventfd.
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod imp {
    use super::{Event, Interest, RawFd, WAKE_TOKEN};
    use std::fs::File;
    use std::io::{self, Read, Write};
    use std::os::fd::{AsRawFd, FromRawFd, OwnedFd};
    use std::sync::Arc;
    use std::time::Duration;

    mod sys {
        use std::os::raw::{c_int, c_uint};

        pub const EPOLLIN: u32 = 0x001;
        pub const EPOLLOUT: u32 = 0x004;
        pub const EPOLLERR: u32 = 0x008;
        pub const EPOLLHUP: u32 = 0x010;
        pub const EPOLLRDHUP: u32 = 0x2000;

        pub const EPOLL_CTL_ADD: c_int = 1;
        pub const EPOLL_CTL_DEL: c_int = 2;
        pub const EPOLL_CTL_MOD: c_int = 3;

        pub const EPOLL_CLOEXEC: c_int = 0o2000000;
        pub const EFD_CLOEXEC: c_int = 0o2000000;
        pub const EFD_NONBLOCK: c_int = 0o4000;

        /// The kernel's `struct epoll_event`. On x86-64 the kernel ABI
        /// packs it (no padding between `events` and `data`); everywhere
        /// else it has natural alignment — mirror both, like libc does.
        #[repr(C)]
        #[cfg_attr(target_arch = "x86_64", repr(packed))]
        #[derive(Clone, Copy)]
        pub struct EpollEvent {
            pub events: u32,
            pub data: u64,
        }

        extern "C" {
            pub fn epoll_create1(flags: c_int) -> c_int;
            pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
            pub fn epoll_wait(
                epfd: c_int,
                events: *mut EpollEvent,
                maxevents: c_int,
                timeout: c_int,
            ) -> c_int;
            pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        }
    }

    fn interest_mask(interest: Interest) -> u32 {
        let mut m = sys::EPOLLRDHUP;
        if interest.readable {
            m |= sys::EPOLLIN;
        }
        if interest.writable {
            m |= sys::EPOLLOUT;
        }
        m
    }

    /// Readiness poller backed by an epoll instance plus an internal
    /// eventfd waker.
    pub struct Poller {
        epfd: OwnedFd,
        wake: Arc<File>,
        buf: Vec<sys::EpollEvent>,
    }

    /// Cheap cloneable handle that interrupts this poller's `wait`.
    #[derive(Clone)]
    pub struct Waker {
        wake: Arc<File>,
    }

    impl Waker {
        pub fn wake(&self) {
            // An eventfd write only fails if the counter would overflow
            // (the wait side drains it) or the poller is gone — both
            // benign for a level-triggered wake: drop the error.
            let _ = (&*self.wake).write(&1u64.to_le_bytes());
        }
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // SAFETY: plain syscall, no pointers; a negative return is
            // converted to the thread errno below.
            let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            // SAFETY: epfd is a freshly created, owned descriptor.
            let epfd = unsafe { OwnedFd::from_raw_fd(epfd) };

            // SAFETY: plain syscall; error checked below.
            let efd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
            if efd < 0 {
                return Err(io::Error::last_os_error());
            }
            // SAFETY: efd is a freshly created, owned descriptor; File
            // takes ownership and closes it on drop.
            let wake = Arc::new(unsafe { File::from_raw_fd(efd) });

            let poller = Poller { epfd, wake, buf: vec![sys::EpollEvent { events: 0, data: 0 }; 256] };
            poller.ctl(sys::EPOLL_CTL_ADD, poller.wake.as_raw_fd(), sys::EPOLLIN, WAKE_TOKEN)?;
            Ok(poller)
        }

        pub fn waker(&self) -> Waker {
            Waker { wake: Arc::clone(&self.wake) }
        }

        fn ctl(&self, op: std::os::raw::c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let mut ev = sys::EpollEvent { events, data: token };
            // SAFETY: `ev` is a live stack value for the duration of the
            // call; the kernel copies it and keeps no reference.
            let rc = unsafe { sys::epoll_ctl(self.epfd.as_raw_fd(), op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            debug_assert_ne!(token, WAKE_TOKEN, "token u64::MAX is reserved for the waker");
            self.ctl(sys::EPOLL_CTL_ADD, fd, interest_mask(interest), token)
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(sys::EPOLL_CTL_MOD, fd, interest_mask(interest), token)
        }

        pub fn delete(&self, fd: RawFd) -> io::Result<()> {
            // Pre-2.6.9 kernels demanded a non-null event for DEL; every
            // target we run on accepts it, and passing one costs nothing.
            self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
        }

        pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<bool> {
            events.clear();
            let timeout_ms: std::os::raw::c_int = match timeout {
                None => -1,
                Some(d) => d.as_millis().min(i32::MAX as u128) as i32,
            };
            // SAFETY: `buf` is a live Vec of `buf.len()` properly
            // initialized events; the kernel writes at most that many.
            let n = unsafe {
                sys::epoll_wait(
                    self.epfd.as_raw_fd(),
                    self.buf.as_mut_ptr(),
                    self.buf.len() as std::os::raw::c_int,
                    timeout_ms,
                )
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(false);
                }
                return Err(err);
            }
            let mut woken = false;
            for i in 0..n as usize {
                // Copy out of the (possibly packed) kernel struct before
                // touching fields — never take references into it.
                let ev = self.buf[i];
                let bits = ev.events;
                let token = ev.data;
                if token == WAKE_TOKEN {
                    woken = true;
                    let mut drain = [0u8; 8];
                    // Nonblocking eventfd: one read resets the counter;
                    // WouldBlock just means another wait already drained.
                    let _ = (&*self.wake).read(&mut drain);
                    continue;
                }
                events.push(Event {
                    token,
                    readable: bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0,
                    writable: bits & sys::EPOLLOUT != 0,
                    hangup: bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0,
                });
            }
            Ok(woken)
        }
    }
}

// ---------------------------------------------------------------------------
// Portable unix fallback: poll(2) over a registration table, woken by a
// loopback socket pair. O(n) per wait — fine at test scale, and only
// compiled where epoll does not exist.
// ---------------------------------------------------------------------------

#[cfg(all(unix, not(target_os = "linux")))]
mod imp {
    use super::{Event, Interest, RawFd, WAKE_TOKEN};
    use std::io::{self, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    mod sys {
        use std::os::raw::{c_int, c_uint};

        pub const POLLIN: i16 = 0x001;
        pub const POLLOUT: i16 = 0x004;
        pub const POLLERR: i16 = 0x008;
        pub const POLLHUP: i16 = 0x010;
        pub const POLLNVAL: i16 = 0x020;

        #[repr(C)]
        #[derive(Clone, Copy)]
        pub struct PollFd {
            pub fd: i32,
            pub events: i16,
            pub revents: i16,
        }

        extern "C" {
            pub fn poll(fds: *mut PollFd, nfds: c_uint, timeout: c_int) -> c_int;
        }
    }

    /// Readiness poller backed by `poll(2)`; see the Linux backend for
    /// the contract.
    pub struct Poller {
        registry: Arc<Mutex<Vec<(RawFd, u64, Interest)>>>,
        wake_rx: TcpStream,
        wake_tx: Arc<TcpStream>,
        buf: Vec<sys::PollFd>,
    }

    /// Cheap cloneable handle that interrupts this poller's `wait`.
    #[derive(Clone)]
    pub struct Waker {
        wake_tx: Arc<TcpStream>,
    }

    impl Waker {
        pub fn wake(&self) {
            let _ = (&*self.wake_tx).write(&[1u8]);
        }
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // A connected loopback pair stands in for eventfd: writing a
            // byte to one end makes the other end poll readable.
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let wake_tx = TcpStream::connect(listener.local_addr()?)?;
            let (wake_rx, _) = listener.accept()?;
            wake_rx.set_nonblocking(true)?;
            wake_tx.set_nonblocking(true)?;
            Ok(Poller {
                registry: Arc::new(Mutex::new(Vec::new())),
                wake_rx,
                wake_tx: Arc::new(wake_tx),
                buf: Vec::new(),
            })
        }

        pub fn waker(&self) -> Waker {
            Waker { wake_tx: Arc::clone(&self.wake_tx) }
        }

        pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            debug_assert_ne!(token, WAKE_TOKEN, "token u64::MAX is reserved for the waker");
            let mut reg = self.registry.lock().unwrap();
            if reg.iter().any(|&(f, _, _)| f == fd) {
                return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd already registered"));
            }
            reg.push((fd, token, interest));
            Ok(())
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let mut reg = self.registry.lock().unwrap();
            for slot in reg.iter_mut() {
                if slot.0 == fd {
                    *slot = (fd, token, interest);
                    return Ok(());
                }
            }
            Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
        }

        pub fn delete(&self, fd: RawFd) -> io::Result<()> {
            let mut reg = self.registry.lock().unwrap();
            let before = reg.len();
            reg.retain(|&(f, _, _)| f != fd);
            if reg.len() == before {
                return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
            }
            Ok(())
        }

        pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<bool> {
            events.clear();
            self.buf.clear();
            self.buf.push(sys::PollFd { fd: self.wake_rx.as_raw_fd(), events: sys::POLLIN, revents: 0 });
            let tokens: Vec<u64> = {
                let reg = self.registry.lock().unwrap();
                for &(fd, _, interest) in reg.iter() {
                    let mut mask = 0i16;
                    if interest.readable {
                        mask |= sys::POLLIN;
                    }
                    if interest.writable {
                        mask |= sys::POLLOUT;
                    }
                    self.buf.push(sys::PollFd { fd, events: mask, revents: 0 });
                }
                reg.iter().map(|&(_, t, _)| t).collect()
            };
            let timeout_ms: std::os::raw::c_int = match timeout {
                None => -1,
                Some(d) => d.as_millis().min(i32::MAX as u128) as i32,
            };
            // SAFETY: `buf` is a live Vec of `buf.len()` initialized
            // pollfd records; the kernel writes only their `revents`.
            let n = unsafe {
                sys::poll(self.buf.as_mut_ptr(), self.buf.len() as std::os::raw::c_uint, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(false);
                }
                return Err(err);
            }
            let mut woken = false;
            if self.buf[0].revents & sys::POLLIN != 0 {
                woken = true;
                let mut drain = [0u8; 64];
                while matches!((&self.wake_rx).read(&mut drain), Ok(n) if n > 0) {}
            }
            for (i, pfd) in self.buf.iter().enumerate().skip(1) {
                let bits = pfd.revents;
                if bits == 0 {
                    continue;
                }
                events.push(Event {
                    token: tokens[i - 1],
                    readable: bits & sys::POLLIN != 0,
                    writable: bits & sys::POLLOUT != 0,
                    hangup: bits & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0,
                });
            }
            Ok(woken)
        }
    }
}

// ---------------------------------------------------------------------------
// Non-unix stub: construction fails at runtime with a clean error, so the
// network plane reports "unsupported platform" instead of hanging or
// compiling out.
// ---------------------------------------------------------------------------

#[cfg(not(unix))]
mod imp {
    use super::{Event, Interest, RawFd};
    use std::io;
    use std::time::Duration;

    /// Stub poller for targets without readiness syscalls; `new` fails.
    pub struct Poller {
        _never: std::convert::Infallible,
    }

    /// Stub waker; never constructed because the stub poller cannot be.
    #[derive(Clone)]
    pub struct Waker {}

    impl Waker {
        pub fn wake(&self) {}
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "readiness polling is not available on this platform",
            ))
        }

        pub fn waker(&self) -> Waker {
            Waker {}
        }

        pub fn add(&self, _fd: RawFd, _token: u64, _interest: Interest) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn modify(&self, _fd: RawFd, _token: u64, _interest: Interest) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn delete(&self, _fd: RawFd) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn wait(&mut self, _events: &mut Vec<Event>, _timeout: Option<Duration>) -> io::Result<bool> {
            unreachable!("stub poller cannot be constructed")
        }
    }
}

/// Readiness poller: a registered-interest set plus a blocking wait.
///
/// Backed by epoll on Linux, `poll(2)` on other unixes, and a
/// runtime-`Unsupported` stub elsewhere. See the module docs for the
/// contract; all backends are level-triggered.
pub struct Poller {
    imp: imp::Poller,
}

/// Cheap cloneable handle that interrupts a [`Poller::wait`] from any
/// thread. Wakes are consumed inside `wait` (its `bool` return) and never
/// surface as [`Event`]s.
#[derive(Clone)]
pub struct Waker {
    imp: imp::Waker,
}

impl Waker {
    /// Interrupt the poller's current (or next) `wait`. Never blocks,
    /// never fails; redundant wakes coalesce.
    pub fn wake(&self) {
        self.imp.wake()
    }
}

impl Poller {
    /// Create a poller (and its internal waker fd). Fails with
    /// `Unsupported` on platforms without readiness syscalls.
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { imp: imp::Poller::new()? })
    }

    /// A cloneable waker bound to this poller.
    pub fn waker(&self) -> Waker {
        Waker { imp: self.imp.waker() }
    }

    /// Register `fd` under `token` with the given interest. `token` must
    /// not be `u64::MAX` (reserved for the internal waker), and `fd` must
    /// stay open until [`Poller::delete`].
    pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.imp.add(fd, token, interest)
    }

    /// Replace the registration of `fd` (token and interest) in place.
    pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.imp.modify(fd, token, interest)
    }

    /// Remove `fd` from the interest set. Call before closing the fd —
    /// a closed fd auto-deregisters from epoll, but the fallback backend
    /// keeps a table.
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.imp.delete(fd)
    }

    /// Block until readiness, a wake, or `timeout` (None = forever).
    /// Fills `events` (cleared first) with ready registrations and
    /// returns whether a [`Waker::wake`] was consumed. `EINTR` returns
    /// `Ok(false)` with no events rather than an error.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<bool> {
        self.imp.wait(events, timeout)
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    fn loopback_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn readable_event_fires_for_pending_bytes() {
        let mut poller = Poller::new().unwrap();
        let (mut a, b) = loopback_pair();
        b.set_nonblocking(true).unwrap();
        poller.add(raw_fd(&b), 7, Interest::READ).unwrap();

        let mut events = Vec::new();
        // Nothing pending: times out with no events.
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty());

        a.write_all(b"ping").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);

        // Level-triggered: still readable until drained.
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert_eq!(events.len(), 1, "level-triggered readiness must persist");
        let mut buf = [0u8; 8];
        assert_eq!((&b).read(&mut buf).unwrap(), 4);

        poller.delete(raw_fd(&b)).unwrap();
        drop(a);
    }

    #[test]
    fn write_interest_toggles_via_modify() {
        let mut poller = Poller::new().unwrap();
        let (a, b) = loopback_pair();
        b.set_nonblocking(true).unwrap();
        poller.add(raw_fd(&b), 3, Interest::READ).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "read-only interest on an idle socket is quiet");

        poller.modify(raw_fd(&b), 3, Interest::READ_WRITE).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == 3 && e.writable));

        poller.delete(raw_fd(&b)).unwrap();
        drop(a);
    }

    #[test]
    fn waker_interrupts_wait_from_another_thread() {
        let mut poller = Poller::new().unwrap();
        let waker = poller.waker();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
        });
        let mut events = Vec::new();
        let woken = poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(woken, "wake must interrupt the wait");
        assert!(events.is_empty(), "the waker never surfaces as an event");
        handle.join().unwrap();
    }

    #[test]
    fn hangup_or_readable_reported_on_peer_close() {
        let mut poller = Poller::new().unwrap();
        let (a, b) = loopback_pair();
        b.set_nonblocking(true).unwrap();
        poller.add(raw_fd(&b), 11, Interest::READ).unwrap();
        drop(a);
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert_eq!(events.len(), 1);
        // A close may surface as readable-EOF, hangup, or both; either
        // way a read observes it.
        assert!(events[0].readable || events[0].hangup);
        poller.delete(raw_fd(&b)).unwrap();
    }
}
