//! Deterministic pseudo-random number generation.
//!
//! We use `splitmix64` for seeding and `xoshiro256++` as the main generator
//! (Blackman & Vigna). All experiment randomness flows through [`Rng`] so
//! every table/figure in EXPERIMENTS.md is exactly reproducible from a seed.

/// splitmix64 step — used to expand a single `u64` seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographic; fast, 2^256-1 period, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box-Muller pair.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 exactly.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal deviate as `f32` with mean/std.
    #[inline]
    pub fn normal(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Fill a slice with N(mean, std) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal(mean, std);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an index proportionally to the (non-negative) weights.
    /// Used by k-means++ seeding.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut r = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.uniform();
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..90_000 {
            counts[r.below(3)] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 30_000).abs() < 1_500, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_sampling_prefers_heavy() {
        let mut r = Rng::new(17);
        let w = [0.0, 0.0, 1.0, 3.0];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[0] + counts[1], 0);
        let ratio = counts[3] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng::new(21);
        let mut b = a.split();
        let matches = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(matches < 2);
    }
}
