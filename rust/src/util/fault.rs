//! Seeded, deterministic fault injection for robustness tests.
//!
//! The fabric's failover claims ("survives a dropped backend", "sheds
//! typed errors under overload") are only trustworthy if the failures can
//! be manufactured on demand and *counted*. This module is a process-wide
//! fault registry that the net plane consults at well-known injection
//! points (see `docs/FABRIC.md`): connection drops, read/write stalls
//! (slow-loris), delayed responses, corrupt frames, and forced
//! `Overloaded` responses.
//!
//! Design constraints, in order:
//!
//! 1. **Off means free.** When no plan is installed, every injection point
//!    costs a single relaxed atomic load. Release hot paths never pay for
//!    a test-only facility.
//! 2. **Deterministic totals.** Faults trigger on a count-based schedule,
//!    not a random draw per call: for kind `k` with rate `r` and phase
//!    `p`, call number `n` (a process-wide atomic counter) fires iff
//!    `floor((n + 1) * r + p) > floor(n * r + p)`. Every call observes a
//!    distinct `n`, so the *total* number of injected faults after `N`
//!    calls is exactly `floor(N * r + p) - floor(p)` regardless of thread
//!    interleaving — tests can assert exact counts under a pinned seed.
//! 3. **Seeded placement.** The seed only shifts the phase `p`, i.e.
//!    *which* calls fire, never how many. Re-running with the same seed
//!    reproduces the same placement bit-for-bit.
//!
//! Injected faults are tallied per kind ([`injected`]); fabric tests match
//! those tallies against the router's failover/retry counters exactly.

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use crate::util::rng::splitmix64;

/// Kinds of injectable faults. Each names one injection point in the net
/// plane; `docs/FABRIC.md` documents where each is consulted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum FaultKind {
    /// Abruptly sever a connection (reset instead of a clean write).
    ConnDrop = 0,
    /// Stall before a read makes progress (slow-loris on the inbound side).
    ReadStall = 1,
    /// Stall before a write makes progress (slow-loris on the outbound side).
    WriteStall = 2,
    /// Delay a response by the plan's `delay` before sending it.
    Delay = 3,
    /// Flip a byte in an outgoing frame so the peer sees a checksum error.
    Corrupt = 4,
    /// Answer with a forced `Overloaded` error instead of serving.
    Overload = 5,
}

/// Number of fault kinds (size of the per-kind counter arrays).
pub const KINDS: usize = 6;

impl FaultKind {
    /// Every kind, for iteration in tests and reports.
    pub const ALL: [FaultKind; KINDS] = [
        FaultKind::ConnDrop,
        FaultKind::ReadStall,
        FaultKind::WriteStall,
        FaultKind::Delay,
        FaultKind::Corrupt,
        FaultKind::Overload,
    ];

    /// Stable snake_case name (used in loadgen cluster reports).
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::ConnDrop => "conn_drop",
            FaultKind::ReadStall => "read_stall",
            FaultKind::WriteStall => "write_stall",
            FaultKind::Delay => "delay",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Overload => "overload",
        }
    }
}

/// A fault plan: per-kind rates plus the durations the stall/delay kinds
/// use. Build with the chainable setters, then [`install`] it.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    rates: [f64; KINDS],
    delay: Duration,
    stall: Duration,
}

impl FaultPlan {
    /// Empty plan (no faults) with the given placement seed.
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            rates: [0.0; KINDS],
            delay: Duration::from_millis(20),
            stall: Duration::from_millis(50),
        }
    }

    /// Set the rate for one kind (clamped to `[0, 1]`; `0.5` = every
    /// second consultation of that injection point fires).
    pub fn with(mut self, kind: FaultKind, rate: f64) -> Self {
        self.rates[kind as usize] = rate.clamp(0.0, 1.0);
        self
    }

    /// Duration used by [`FaultKind::Delay`] injections.
    pub fn delay(mut self, d: Duration) -> Self {
        self.delay = d;
        self
    }

    /// Duration used by the stall kinds.
    pub fn stall(mut self, d: Duration) -> Self {
        self.stall = d;
        self
    }
}

struct State {
    rates: [AtomicU64; KINDS],  // f64 bits
    phases: [AtomicU64; KINDS], // f64 bits, in [0, 1)
    calls: [AtomicU64; KINDS],
    injected: [AtomicU64; KINDS],
    delay_ns: AtomicU64,
    stall_ns: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: State = State {
    rates: [ZERO; KINDS],
    phases: [ZERO; KINDS],
    calls: [ZERO; KINDS],
    injected: [ZERO; KINDS],
    delay_ns: AtomicU64::new(0),
    stall_ns: AtomicU64::new(0),
};

/// Install a plan and enable injection. Resets all call/injected counters.
/// Process-wide: tests using this must hold a serialization lock or run in
/// their own process (the fabric suite serializes via a static mutex).
pub fn install(plan: &FaultPlan) {
    ENABLED.store(false, Ordering::SeqCst);
    let mut s = plan.seed;
    for k in 0..KINDS {
        STATE.rates[k].store(plan.rates[k].to_bits(), Ordering::SeqCst);
        // Per-kind phase in [0, 1): decides *which* calls fire, not how many.
        let p = (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        STATE.phases[k].store(p.to_bits(), Ordering::SeqCst);
        STATE.calls[k].store(0, Ordering::SeqCst);
        STATE.injected[k].store(0, Ordering::SeqCst);
    }
    STATE
        .delay_ns
        .store(plan.delay.as_nanos() as u64, Ordering::SeqCst);
    STATE
        .stall_ns
        .store(plan.stall.as_nanos() as u64, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disable injection and zero every rate and counter.
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    for k in 0..KINDS {
        STATE.rates[k].store(0, Ordering::SeqCst);
        STATE.phases[k].store(0, Ordering::SeqCst);
        STATE.calls[k].store(0, Ordering::SeqCst);
        STATE.injected[k].store(0, Ordering::SeqCst);
    }
}

/// Is any plan installed? (The one relaxed load on the disabled path.)
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Consult an injection point: returns `true` iff this call should fault.
/// The schedule is count-based (see module docs), so totals are exact and
/// deterministic under any thread interleaving.
#[inline]
pub fn should_inject(kind: FaultKind) -> bool {
    if !ENABLED.load(Ordering::Relaxed) {
        return false;
    }
    should_inject_slow(kind)
}

#[cold]
fn should_inject_slow(kind: FaultKind) -> bool {
    let k = kind as usize;
    let rate = f64::from_bits(STATE.rates[k].load(Ordering::Relaxed));
    if rate <= 0.0 {
        return false;
    }
    let phase = f64::from_bits(STATE.phases[k].load(Ordering::Relaxed));
    let n = STATE.calls[k].fetch_add(1, Ordering::Relaxed) as f64;
    let fire = ((n + 1.0) * rate + phase).floor() > (n * rate + phase).floor();
    if fire {
        STATE.injected[k].fetch_add(1, Ordering::Relaxed);
    }
    fire
}

/// How many faults of this kind have been injected since [`install`].
pub fn injected(kind: FaultKind) -> u64 {
    STATE.injected[kind as usize].load(Ordering::Relaxed)
}

/// Total injected faults across all kinds.
pub fn injected_total() -> u64 {
    FaultKind::ALL.iter().map(|&k| injected(k)).sum()
}

/// The installed plan's delay duration (for [`FaultKind::Delay`]).
pub fn delay_duration() -> Duration {
    Duration::from_nanos(STATE.delay_ns.load(Ordering::Relaxed))
}

/// The installed plan's stall duration (for the stall kinds).
pub fn stall_duration() -> Duration {
    Duration::from_nanos(STATE.stall_ns.load(Ordering::Relaxed))
}

/// Stream adapter that consults the registry around every read/write.
/// Used by tests and `net::loadgen` to abuse a socket from the client
/// side: reads may stall ([`FaultKind::ReadStall`]); writes may stall
/// ([`FaultKind::WriteStall`]), get a byte flipped ([`FaultKind::Corrupt`],
/// so the peer sees a checksum failure), or fail outright with
/// `ConnectionReset` ([`FaultKind::ConnDrop`]).
pub struct FaultStream<S> {
    inner: S,
}

impl<S> FaultStream<S> {
    /// Wrap a stream. With injection disabled this is a zero-cost
    /// pass-through (one relaxed load per call).
    pub fn new(inner: S) -> Self {
        FaultStream { inner }
    }

    /// The wrapped stream.
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    /// Unwrap.
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Read> Read for FaultStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if should_inject(FaultKind::ReadStall) {
            std::thread::sleep(stall_duration());
        }
        self.inner.read(buf)
    }
}

impl<S: Write> Write for FaultStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if should_inject(FaultKind::ConnDrop) {
            return Err(io::Error::new(
                io::ErrorKind::ConnectionReset,
                "injected connection drop",
            ));
        }
        if should_inject(FaultKind::WriteStall) {
            std::thread::sleep(stall_duration());
        }
        if !buf.is_empty() && should_inject(FaultKind::Corrupt) {
            let mut copy = buf.to_vec();
            let last = copy.len() - 1;
            copy[last] ^= 0xFF;
            return self.inner.write(&copy);
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // The registry is process-wide state; serialize the tests that touch it.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_is_inert() {
        let _g = LOCK.lock().unwrap();
        clear();
        assert!(!enabled());
        for _ in 0..100 {
            assert!(!should_inject(FaultKind::ConnDrop));
        }
        assert_eq!(injected_total(), 0);
    }

    #[test]
    fn count_based_totals_are_exact() {
        let _g = LOCK.lock().unwrap();
        install(&FaultPlan::new(99).with(FaultKind::Overload, 0.25));
        let mut fired = 0u64;
        for _ in 0..1000 {
            if should_inject(FaultKind::Overload) {
                fired += 1;
            }
        }
        // floor(1000*r + p) - floor(p) with r=0.25: exactly 250.
        assert_eq!(fired, 250);
        assert_eq!(injected(FaultKind::Overload), 250);
        // Other kinds untouched.
        assert_eq!(injected(FaultKind::ConnDrop), 0);
        clear();
    }

    #[test]
    fn seed_pins_placement() {
        let _g = LOCK.lock().unwrap();
        let run = |seed: u64| -> Vec<bool> {
            install(&FaultPlan::new(seed).with(FaultKind::Corrupt, 0.3));
            let v: Vec<bool> = (0..64).map(|_| should_inject(FaultKind::Corrupt)).collect();
            clear();
            v
        };
        assert_eq!(run(5), run(5));
        // A different seed shifts the phase; totals stay within 1 of each
        // other but placement (almost surely) moves.
        let a = run(1);
        let b = run(2);
        let ca = a.iter().filter(|&&x| x).count() as i64;
        let cb = b.iter().filter(|&&x| x).count() as i64;
        assert!((ca - cb).abs() <= 1, "totals drifted: {ca} vs {cb}");
    }

    #[test]
    fn rate_one_fires_always() {
        let _g = LOCK.lock().unwrap();
        install(&FaultPlan::new(0).with(FaultKind::Delay, 1.0));
        for _ in 0..32 {
            assert!(should_inject(FaultKind::Delay));
        }
        assert_eq!(injected(FaultKind::Delay), 32);
        clear();
    }

    #[test]
    fn fault_stream_passthrough_when_disabled() {
        let _g = LOCK.lock().unwrap();
        clear();
        let mut s = FaultStream::new(std::io::Cursor::new(vec![1u8, 2, 3]));
        let mut buf = [0u8; 3];
        s.read_exact(&mut buf).unwrap();
        assert_eq!(buf, [1, 2, 3]);
    }

    #[test]
    fn fault_stream_corrupts_last_byte() {
        let _g = LOCK.lock().unwrap();
        install(&FaultPlan::new(7).with(FaultKind::Corrupt, 1.0));
        let mut s = FaultStream::new(std::io::Cursor::new(Vec::new()));
        s.write_all(&[0xAA, 0xBB]).unwrap();
        clear();
        let out = s.into_inner().into_inner();
        assert_eq!(out, vec![0xAA, 0x44]); // 0xBB ^ 0xFF
    }
}
