//! Tiny leveled logger with wall-clock timestamps relative to process start.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1); // Info

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Initialize the start timestamp and set the level.
pub fn set_level(level: Level) {
    let _ = start();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
