//! Foundation utilities built from scratch (the vendored crate set contains
//! only `xla` + `anyhow`, so PRNG, JSON, CLI parsing, logging and the
//! property-test harness are all implemented here).

pub mod backoff;
pub mod cli;
pub mod epoll;
pub mod fault;
pub mod json;
pub mod log;
pub mod mmap;
pub mod mpmc;
pub mod prop;
pub mod rng;
pub mod timer;
