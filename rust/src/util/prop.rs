//! A miniature property-testing harness (proptest is not in the vendored
//! crate set). Deterministic: every case derives from a fixed seed, and a
//! failing case reports its index + seed so it can be replayed.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath link flags)
//! use lcquant::util::prop::{check, Gen};
//! check("abs is non-negative", 256, |g: &mut Gen| {
//!     let x = g.f32_in(-100.0, 100.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use crate::util::rng::Rng;

/// Input generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.uniform()
    }
    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 0
    }
    /// Vector of f32 from N(0, std), occasionally spiked with outliers —
    /// good stress input for quantizers.
    pub fn weights(&mut self, max_len: usize, std: f32) -> Vec<f32> {
        let n = self.usize_in(1, max_len);
        (0..n)
            .map(|_| {
                let v = self.rng.normal(0.0, std);
                if self.rng.below(50) == 0 {
                    v * 20.0 // outlier
                } else {
                    v
                }
            })
            .collect()
    }
    /// Strictly increasing codebook of size k within [lo, hi].
    pub fn sorted_codebook(&mut self, k: usize, lo: f32, hi: f32) -> Vec<f32> {
        let mut c: Vec<f32> = (0..k).map(|_| self.f32_in(lo, hi)).collect();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        c.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
        while c.len() < k {
            let last = *c.last().unwrap();
            c.push(last + 0.1 + 0.1 * c.len() as f32);
        }
        c
    }
}

/// Run `cases` random cases of the property `f`. Panics (with replay info)
/// on the first failing case.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut f: F) {
    const SEED: u64 = 0x5eed_1c_0ffee;
    for case in 0..cases {
        let mut g = Gen {
            rng: Rng::new(SEED.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15))),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed base {SEED:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 200, |g| {
            let n = g.usize_in(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f32_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&x));
            let cb = g.sorted_codebook(4, -1.0, 1.0);
            assert_eq!(cb.len(), 4);
            assert!(cb.windows(2).all(|w| w[0] < w[1]));
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check("always fails eventually", 50, |g| {
            assert!(g.case < 10);
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<f32> = vec![];
        check("collect", 5, |g| {
            first.push(g.f32_in(0.0, 1.0));
        });
        let mut second: Vec<f32> = vec![];
        check("collect", 5, |g| {
            second.push(g.f32_in(0.0, 1.0));
        });
        assert_eq!(first, second);
    }
}
