//! Bounded lock-free MPMC ring queue — the serve plane's batch hand-off.
//!
//! The micro-batch server used to hand coalesced batch groups to its
//! executor threads through an `mpsc` channel wrapped in a `Mutex`, which
//! serialized every executor behind one lock held across `recv`. Fine at
//! `pipeline_depth ≤ 8`; with the network plane multiplying producers and
//! consumers, the hand-off itself should not be a lock. This queue is the
//! classic Vyukov bounded MPMC ring: each cell carries a sequence number,
//! producers and consumers claim cells with a single CAS on their own
//! cursor, and the element move happens without any lock. A `Mutex` +
//! `Condvar` pair exists **only for parking**: blocked
//! [`push`](RingQueue::push)/[`pop`](RingQueue::pop) callers sleep on it
//! (futex on Linux). The fast path never touches that lock at all — a
//! waiter count (SeqCst, fence-paired with the wakers) tells an
//! uncontended push/pop that nobody is parked, and waiters raise the
//! count and re-check the ring *before* sleeping, so notify-after-publish
//! can never be missed (see the race argument on `wake`).
//!
//! Shutdown is explicit: [`close`](RingQueue::close) wakes everyone;
//! `pop` keeps draining queued items after close and returns `None` only
//! once the ring is empty, so "answer everything already coalesced, then
//! stop" falls out of the queue semantics.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// One ring cell: `seq` encodes whose turn the cell is (Vyukov protocol —
/// `seq == pos` ⇒ free for the producer of ticket `pos`; `seq == pos + 1`
/// ⇒ holds the value for the consumer of ticket `pos`).
struct Cell<T> {
    seq: AtomicUsize,
    value: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded multi-producer multi-consumer ring queue (see module docs).
pub struct RingQueue<T> {
    cells: Box<[Cell<T>]>,
    mask: usize,
    /// Next pop ticket.
    head: AtomicUsize,
    /// Next push ticket.
    tail: AtomicUsize,
    closed: AtomicBool,
    /// Parking lot only — never guards the cells themselves.
    park: Mutex<()>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Poppers currently parked (or committing to park) on `not_empty`.
    /// Lets the push fast path skip the park lock entirely when nobody is
    /// waiting — the common case — so an uncontended hand-off touches no
    /// lock at all. See `wake` for the fencing argument.
    waiting_poppers: AtomicUsize,
    /// Pushers currently parked (or committing to park) on `not_full`.
    waiting_pushers: AtomicUsize,
}

// SAFETY: cells are handed off between threads through the seq protocol
// (Acquire/Release pairs on `seq` order the value writes); `T: Send` is
// all that moving values between threads requires.
unsafe impl<T: Send> Send for RingQueue<T> {}
unsafe impl<T: Send> Sync for RingQueue<T> {}

impl<T> RingQueue<T> {
    /// A queue holding at most `capacity` items (rounded up to a power of
    /// two, minimum 2).
    pub fn new(capacity: usize) -> RingQueue<T> {
        let cap = capacity.max(2).next_power_of_two();
        let cells = (0..cap)
            .map(|i| Cell {
                seq: AtomicUsize::new(i),
                value: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        RingQueue {
            cells,
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            park: Mutex::new(()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            waiting_poppers: AtomicUsize::new(0),
            waiting_pushers: AtomicUsize::new(0),
        }
    }

    /// Number of cells (the rounded-up capacity).
    pub fn capacity(&self) -> usize {
        self.cells.len()
    }

    /// Whether [`close`](RingQueue::close) has been called. Items already
    /// queued are still delivered by `pop`.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Non-blocking push; returns the value back when the ring is full.
    pub fn try_push(&self, value: T) -> Result<(), T> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let cell = &self.cells[pos & self.mask];
            let seq = cell.seq.load(Ordering::Acquire);
            match seq.wrapping_sub(pos) as isize {
                // our turn: claim the cell by advancing the tail cursor
                0 => match self.tail.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS gave this thread exclusive
                        // ownership of the cell for ticket `pos`; the
                        // Release store below publishes the write.
                        unsafe { (*cell.value.get()).write(value) };
                        cell.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(p) => pos = p,
                },
                // consumer of ticket `pos − cap` has not emptied the cell
                d if d < 0 => return Err(value),
                // another producer claimed this ticket: reload and retry
                _ => pos = self.tail.load(Ordering::Relaxed),
            }
        }
    }

    /// Non-blocking pop; `None` when the ring is empty.
    pub fn try_pop(&self) -> Option<T> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let cell = &self.cells[pos & self.mask];
            let seq = cell.seq.load(Ordering::Acquire);
            match seq.wrapping_sub(pos.wrapping_add(1)) as isize {
                // a value is ready: claim it by advancing the head cursor
                0 => match self.head.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS gave this thread exclusive
                        // ownership of the filled cell; the producer's
                        // Release/our Acquire on `seq` ordered the write.
                        let value = unsafe { (*cell.value.get()).assume_init_read() };
                        cell.seq
                            .store(pos.wrapping_add(self.mask).wrapping_add(1), Ordering::Release);
                        return Some(value);
                    }
                    Err(p) => pos = p,
                },
                // producer of ticket `pos` has not filled the cell yet
                d if d < 0 => return None,
                // another consumer claimed this ticket: reload and retry
                _ => pos = self.head.load(Ordering::Relaxed),
            }
        }
    }

    /// Blocking push: parks until a cell frees up. Returns the value back
    /// (like a failed send) once the queue is closed.
    pub fn push(&self, value: T) -> Result<(), T> {
        if self.is_closed() {
            return Err(value);
        }
        let mut value = value;
        // fast path: lock-free claim; the lock is touched only if a
        // popper is (about to be) parked
        match self.try_push(value) {
            Ok(()) => {
                self.wake(&self.waiting_poppers, &self.not_empty);
                return Ok(());
            }
            Err(back) => value = back,
        }
        let mut guard = self.park.lock().unwrap();
        loop {
            if self.closed.load(Ordering::Acquire) {
                return Err(value);
            }
            match self.try_push(value) {
                Ok(()) => {
                    self.not_empty.notify_one();
                    return Ok(());
                }
                // still full: commit to parking. The waiter count is
                // raised (SeqCst) *before* the final recheck, so a pop
                // that frees a cell either sees the count and takes the
                // lock to notify (delivered once we wait — we hold the
                // lock until then) or completed early enough that our
                // recheck sees the free cell. Either way, no lost wakeup.
                Err(back) => {
                    value = back;
                    self.waiting_pushers.fetch_add(1, Ordering::SeqCst);
                    fence(Ordering::SeqCst); // pairs with the fence in `wake`
                    match self.try_push(value) {
                        Ok(()) => {
                            self.waiting_pushers.fetch_sub(1, Ordering::SeqCst);
                            self.not_empty.notify_one();
                            return Ok(());
                        }
                        Err(back) => value = back,
                    }
                    guard = self.not_full.wait(guard).unwrap();
                    self.waiting_pushers.fetch_sub(1, Ordering::SeqCst);
                }
            }
        }
    }

    /// Blocking pop: parks until an item arrives. Returns `None` only
    /// when the queue is closed **and** drained.
    pub fn pop(&self) -> Option<T> {
        // fast path: lock-free claim; the lock is touched only if a
        // pusher is (about to be) parked
        if let Some(v) = self.try_pop() {
            self.wake(&self.waiting_pushers, &self.not_full);
            return Some(v);
        }
        let mut guard = self.park.lock().unwrap();
        loop {
            if let Some(v) = self.try_pop() {
                self.not_full.notify_one();
                return Some(v);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            // still empty: commit to parking (same fencing argument as
            // the push slow path, with the roles swapped)
            self.waiting_poppers.fetch_add(1, Ordering::SeqCst);
            fence(Ordering::SeqCst); // pairs with the fence in `wake`
            if let Some(v) = self.try_pop() {
                self.waiting_poppers.fetch_sub(1, Ordering::SeqCst);
                self.not_full.notify_one();
                return Some(v);
            }
            guard = self.not_empty.wait(guard).unwrap();
            self.waiting_poppers.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Close the queue: pending and future `push` calls fail, `pop`
    /// drains what is queued and then returns `None`.
    pub fn close(&self) {
        let _guard = self.park.lock().unwrap();
        self.closed.store(true, Ordering::Release);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Wake one waiter of `cv`, but only when `waiting` says someone is
    /// (or is about to be) parked — the common no-waiter case touches no
    /// lock at all.
    ///
    /// Race argument (Dekker-style): the waiter raises its count, issues
    /// a SeqCst fence, then rechecks the ring — all while holding the
    /// park lock; this waker completed its ring operation, issues a
    /// SeqCst fence, then loads the count. In the fence total order one
    /// of the two fences comes first: if the waker's does, the waiter's
    /// post-fence recheck sees the ring operation and never parks; if the
    /// waiter's does, the waker's post-fence load sees the raised count,
    /// takes the lock (serializing behind the waiter's hold, which the
    /// waiter only releases by entering `wait`) and the notify is
    /// delivered. Either way, no lost wakeup.
    fn wake(&self, waiting: &AtomicUsize, cv: &Condvar) {
        fence(Ordering::SeqCst); // pairs with the fence before parking
        if waiting.load(Ordering::SeqCst) > 0 {
            let _guard = self.park.lock().unwrap();
            cv.notify_one();
        }
    }
}

impl<T> Drop for RingQueue<T> {
    fn drop(&mut self) {
        // run the destructors of anything still queued
        while self.try_pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = RingQueue::new(4);
        assert_eq!(q.capacity(), 4);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        assert!(q.try_push(99).is_err(), "ring must report full");
        for i in 0..4 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
        // wrap around several times: sequence numbers must recycle cleanly
        for round in 0..10 {
            for i in 0..3 {
                q.try_push(round * 10 + i).unwrap();
            }
            for i in 0..3 {
                assert_eq!(q.try_pop(), Some(round * 10 + i));
            }
        }
    }

    #[test]
    fn close_drains_then_ends() {
        let q = RingQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.push(3).is_err(), "push after close must fail");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q = Arc::new(RingQueue::new(2));
        for i in 0..q.capacity() {
            q.push(i).unwrap();
        }
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || qp.push(777));
        // give the producer time to park on the full ring, then drain
        std::thread::sleep(std::time::Duration::from_millis(20));
        let first = q.pop().unwrap();
        assert_eq!(first, 0);
        producer.join().unwrap().unwrap();
        // remaining items: 1 then the late 777
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(777));
    }

    #[test]
    fn mpmc_stress_every_item_exactly_once() {
        const PRODUCERS: u64 = 4;
        const CONSUMERS: usize = 4;
        const PER: u64 = 2_000;
        let q = Arc::new(RingQueue::new(8));
        let sum = Arc::new(AtomicU64::new(0));
        let count = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for c in 0..CONSUMERS {
            let q = Arc::clone(&q);
            let sum = Arc::clone(&sum);
            let count = Arc::clone(&count);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mpmc-pop-{c}"))
                    .spawn(move || {
                        while let Some(v) = q.pop() {
                            sum.fetch_add(v, Ordering::Relaxed);
                            count.fetch_add(1, Ordering::Relaxed);
                        }
                    })
                    .unwrap(),
            );
        }
        let mut producers = Vec::new();
        for p in 0..PRODUCERS {
            let q = Arc::clone(&q);
            producers.push(
                std::thread::Builder::new()
                    .name(format!("mpmc-push-{p}"))
                    .spawn(move || {
                        for i in 0..PER {
                            q.push(p * PER + i).unwrap();
                        }
                    })
                    .unwrap(),
            );
        }
        for h in producers {
            h.join().unwrap();
        }
        q.close();
        for h in handles {
            h.join().unwrap();
        }
        let n = PRODUCERS * PER;
        assert_eq!(count.load(Ordering::Relaxed), n);
        assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2);
    }

    #[test]
    fn drop_releases_queued_items() {
        struct Token(Arc<AtomicU64>);
        impl Drop for Token {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::Relaxed);
            }
        }
        let drops = Arc::new(AtomicU64::new(0));
        {
            let q = RingQueue::new(4);
            for _ in 0..3 {
                q.try_push(Token(Arc::clone(&drops))).unwrap();
            }
            let popped = q.try_pop().unwrap();
            drop(popped);
            assert_eq!(drops.load(Ordering::Relaxed), 1);
        }
        // the two still-queued tokens are dropped with the queue
        assert_eq!(drops.load(Ordering::Relaxed), 3);
    }
}
