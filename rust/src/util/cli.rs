//! Hand-rolled CLI argument parsing (`clap` is not in the vendored crate
//! set). Supports `subcommand --flag value --switch positional` style.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, bare
/// `--switch` flags and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut args = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                // --key value form (value must not look like a flag)
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        args.options.insert(name.to_string(), v);
                    }
                    _ => args.switches.push(name.to_string()),
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Parse a comma-separated list of usizes, e.g. `--ks 2,4,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("experiment fig9 --k 4 --out results");
        assert_eq!(a.command, "experiment");
        assert_eq!(a.positional, vec!["fig9"]);
        assert_eq!(a.get("k"), Some("4"));
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn equals_form_and_switches() {
        let a = parse("lc --mu0=0.001 --verbose --seed 9");
        assert_eq!(a.get_f64("mu0", 0.0), 0.001);
        assert!(a.has("verbose"));
        assert_eq!(a.get_u64("seed", 0), 9);
    }

    #[test]
    fn switch_before_option() {
        let a = parse("run --fast --n 10");
        assert!(a.has("fast"));
        assert_eq!(a.get_usize("n", 0), 10);
    }

    #[test]
    fn list_parsing() {
        let a = parse("x --ks 2,4,8,64");
        assert_eq!(a.get_usize_list("ks", &[]), vec![2, 4, 8, 64]);
        assert_eq!(a.get_usize_list("hs", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert!(!a.has("quiet"));
    }

    #[test]
    fn negative_number_value() {
        let a = parse("cmd --lr -0.5");
        assert_eq!(a.get_f64("lr", 0.0), -0.5);
    }
}
