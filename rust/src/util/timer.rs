//! Timing helpers for the bench harness (criterion is not vendored; the
//! `benches/` binaries use [`bench`] with warmup + trimmed statistics).

use std::time::Instant;

/// A simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Statistics over a set of timed runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    /// Median seconds per iteration.
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Tail percentiles (nearest-rank via `metrics::percentile_sorted`,
    /// the same discipline as the obs histogram plane).
    pub p90_s: f64,
    pub p99_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10}  median {:>12}  mean {:>12}  min {:>12}  p90 {:>12}  p99 {:>12}  max {:>12}",
            self.name,
            format!("n={}", self.iters),
            human_time(self.median_s),
            human_time(self.mean_s),
            human_time(self.min_s),
            human_time(self.p90_s),
            human_time(self.p99_s),
            human_time(self.max_s),
        )
    }

    /// Throughput helper: items per second at the median time.
    pub fn per_sec(&self, items: usize) -> f64 {
        items as f64 / self.median_s
    }
}

/// Format seconds in a human unit.
pub fn human_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark `f` : warm up, then time `iters` runs and report statistics.
/// The closure returns a value that is passed to `std::hint::black_box` to
/// keep the optimizer honest.
pub fn bench<T, F: FnMut() -> T>(name: &str, iters: usize, mut f: F) -> BenchStats {
    // Warmup: at least one run, up to ~100ms.
    let warm = Timer::start();
    loop {
        std::hint::black_box(f());
        if warm.elapsed_s() > 0.1 {
            break;
        }
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        std::hint::black_box(f());
        times.push(t.elapsed_s());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_s = times[times.len() / 2];
    let mean_s = times.iter().sum::<f64>() / times.len() as f64;
    let sorted_f32: Vec<f32> = times.iter().map(|&t| t as f32).collect();
    BenchStats {
        name: name.to_string(),
        iters,
        median_s,
        mean_s,
        min_s: times[0],
        max_s: *times.last().unwrap(),
        p90_s: crate::metrics::percentile_sorted(&sorted_f32, 90.0) as f64,
        p99_s: crate::metrics::percentile_sorted(&sorted_f32, 99.0) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop-spin", 10, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
        assert!(s.median_s > 0.0);
        assert!(s.median_s <= s.p90_s + 1e-12 && s.p90_s <= s.p99_s + 1e-12);
        assert!(s.p99_s <= s.max_s + 1e-12);
        assert_eq!(s.iters, 10);
        let line = s.report();
        assert!(line.contains("p90") && line.contains("p99") && line.contains("max"));
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(5e-9).ends_with("ns"));
        assert!(human_time(5e-6).ends_with("µs"));
        assert!(human_time(5e-3).ends_with("ms"));
        assert!(human_time(5.0).ends_with('s'));
    }
}
