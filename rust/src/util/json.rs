//! Minimal JSON parser / emitter.
//!
//! Used for the config system, the artifact manifest and machine-readable
//! experiment results. (The vendored crate set has no `serde`, so this is a
//! from-scratch RFC 8259 subset: no `\u` surrogate pairs beyond the BMP are
//! validated, numbers are parsed as `f64`.)

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so emission is
/// deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Self {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}
impl From<Vec<f32>> for Json {
    fn from(v: Vec<f32>) -> Self {
        Json::Arr(v.into_iter().map(|x| Json::Num(x as f64)).collect())
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.src.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.src[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.src[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact emission with deterministic key order.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_whitespace_tolerant() {
        let j = Json::parse(" { \"k\" :\n[ 1 ,\t2 ] } ").unwrap();
        assert_eq!(j.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn emit_roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"num":-7,"obj":{"x":1}}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn emit_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn property_roundtrip_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        // random nested structures
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 0),
                2 => Json::Num((rng.gaussian() * 100.0 * 8.0).round() / 8.0),
                3 => Json::Str(format!("s{}", rng.below(1000))),
                4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        for _ in 0..200 {
            let j = gen(&mut rng, 3);
            let text = j.to_string();
            assert_eq!(Json::parse(&text).unwrap(), j, "text={text}");
        }
    }
}
