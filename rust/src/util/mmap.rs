//! Read-only memory-mapped file regions for zero-copy model loading.
//!
//! [`MmapRegion::map_file`] maps a whole file with `mmap(PROT_READ,
//! MAP_PRIVATE)` so its bytes are served straight from the page cache: no
//! read-time copy, no resident heap until a page is actually touched, and
//! identical mappings across processes share physical pages. The crate
//! vendors no `libc`, so the two syscalls are declared directly against
//! the C library the standard library already links.
//!
//! On targets where the mapping path is not compiled in (non-unix, or a
//! 32-bit address space where a large model may not fit), or when `mmap`
//! itself fails at runtime, the region transparently falls back to a
//! heap buffer read with ordinary file I/O. The fallback buffer is backed
//! by a `Vec<u64>`, which guarantees the 8-byte base alignment the `.lcq`
//! reader needs to view plane sections as `&[u64]` — a plain `Vec<u8>`
//! would not. Callers can distinguish the two with
//! [`MmapRegion::is_mapped`] (the observability counters do), but the
//! byte contract is identical.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub fn map_failed(p: *mut c_void) -> bool {
        p as isize == -1
    }
}

enum Inner {
    /// A live `mmap` mapping; unmapped on drop.
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped { ptr: *const u8, len: usize },
    /// Heap fallback. The `Vec<u64>` backing guarantees 8-byte alignment;
    /// `len` is the real byte length (the last word may be partial).
    Heap { words: Vec<u64>, len: usize },
}

/// A read-only byte region backed by a file mapping (or a heap buffer
/// when mapping is unavailable). See the module docs for the contract.
pub struct MmapRegion {
    inner: Inner,
}

// SAFETY: the region is immutable after construction — `bytes()` hands out
// only shared references and nothing ever writes through the mapping — so
// shared access from multiple threads is sound.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

impl MmapRegion {
    /// Map `path` read-only, falling back to a heap read if mapping is
    /// unavailable on this target or refused by the kernel. Empty files
    /// are an error (an `.lcq` file is never empty, and `mmap` rejects
    /// zero-length mappings).
    pub fn map_file(path: &Path) -> Result<MmapRegion> {
        let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
        let len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
        if len == 0 {
            return Err(anyhow!("{path:?} is empty"));
        }
        let len = usize::try_from(len).map_err(|_| anyhow!("{path:?} exceeds address space"))?;
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            use std::os::fd::AsRawFd;
            // SAFETY: fd is a valid open file descriptor for `len` bytes;
            // a PROT_READ MAP_PRIVATE mapping of it aliases nothing
            // writable. Failure is reported via MAP_FAILED, checked below.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if !sys::map_failed(ptr) {
                return Ok(MmapRegion { inner: Inner::Mapped { ptr: ptr as *const u8, len } });
            }
            // fall through to the heap read — e.g. a filesystem that
            // refuses mappings; the byte contract is unchanged
        }
        Self::read_heap(&file, len).with_context(|| format!("reading {path:?}"))
    }

    /// Heap fallback: read the whole file into a `Vec<u64>`-backed buffer
    /// (8-byte aligned so `.lcq` plane sections can be viewed as words).
    fn read_heap(file: &std::fs::File, len: usize) -> Result<MmapRegion> {
        use std::io::Read;
        let mut words = vec![0u64; len.div_ceil(8)];
        // SAFETY: the Vec owns `len.div_ceil(8) * 8 >= len` initialized
        // bytes; viewing them as &mut [u8] for the read is sound.
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u8, len)
        };
        let mut reader = file;
        reader.read_exact(bytes)?;
        Ok(MmapRegion { inner: Inner::Heap { words, len } })
    }

    /// The mapped (or buffered) bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            // SAFETY: ptr/len describe the live mapping created in
            // `map_file`, valid until Drop unmaps it.
            Inner::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Inner::Heap { words, len } => {
                // SAFETY: the Vec owns at least `len` initialized bytes.
                unsafe { std::slice::from_raw_parts(words.as_ptr() as *const u8, *len) }
            }
        }
    }

    /// Region length in bytes.
    pub fn len(&self) -> usize {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Inner::Mapped { len, .. } => *len,
            Inner::Heap { len, .. } => *len,
        }
    }

    /// Whether the region has any bytes (always true for regions built by
    /// [`MmapRegion::map_file`], which rejects empty files).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `true` when the region is a real page-cache mapping, `false` on
    /// the heap fallback — the distinction the `lcq_mmap_loads` counter
    /// records.
    pub fn is_mapped(&self) -> bool {
        match &self.inner {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Inner::Mapped { .. } => true,
            Inner::Heap { .. } => false,
        }
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let Inner::Mapped { ptr, len } = self.inner {
            // SAFETY: exactly the mapping created in `map_file`; after
            // Drop no reference into it can exist (`bytes` borrows self).
            unsafe {
                sys::munmap(ptr as *mut std::os::raw::c_void, len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, data: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("lcquant_mmap_{name}"));
        std::fs::write(&p, data).unwrap();
        p
    }

    #[test]
    fn maps_bytes_identical_to_read() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7 % 251) as u8).collect();
        let p = tmp("roundtrip", &data);
        let r = MmapRegion::map_file(&p).unwrap();
        assert_eq!(r.len(), data.len());
        assert!(!r.is_empty());
        assert_eq!(r.bytes(), &data[..]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn heap_fallback_is_byte_identical_and_word_aligned() {
        let data: Vec<u8> = (0..777u32).map(|i| (i % 256) as u8).collect();
        let p = tmp("heap", &data);
        let f = std::fs::File::open(&p).unwrap();
        let r = MmapRegion::read_heap(&f, data.len()).unwrap();
        assert!(!r.is_mapped());
        assert_eq!(r.bytes(), &data[..]);
        assert_eq!(r.bytes().as_ptr() as usize % 8, 0, "heap fallback must be 8-byte aligned");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn empty_file_is_an_error() {
        let p = tmp("empty", &[]);
        assert!(MmapRegion::map_file(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn missing_file_is_an_error() {
        let p = std::env::temp_dir().join("lcquant_mmap_definitely_missing");
        let _ = std::fs::remove_file(&p);
        assert!(MmapRegion::map_file(&p).is_err());
    }
}
