//! Cholesky factorization / solves for symmetric positive-definite systems.
//!
//! Used by the closed-form L step of the linear-regression experiment (E2,
//! paper §5.2): the penalized least-squares solution is
//! `W (XXᵀ/N + (μ/2)·I_masked) = YXᵀ/N + (μ/2)·T_masked`, an SPD system in
//! the Gram matrix. f64 internally for numerical robustness.

use super::Mat;

/// Cholesky factor L (lower-triangular, row-major, n×n) of an SPD matrix.
pub struct Cholesky {
    n: usize,
    l: Vec<f64>,
}

impl Cholesky {
    /// Factor `a` (symmetric positive definite). Returns `None` if a pivot
    /// is non-positive (matrix not SPD within tolerance).
    pub fn factor(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows, a.cols, "square required");
        let n = a.rows;
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)] as f64;
                for p in 0..j {
                    s -= l[i * n + p] * l[j * n + p];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[i * n + i] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        Some(Cholesky { n, l })
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f32]) -> Vec<f32> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        let mut y = vec![0.0f64; n];
        // forward: L y = b
        for i in 0..n {
            let mut s = b[i] as f64;
            for p in 0..i {
                s -= self.l[i * n + p] * y[p];
            }
            y[i] = s / self.l[i * n + i];
        }
        // backward: Lᵀ x = y
        let mut x = vec![0.0f64; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for p in i + 1..n {
                s -= self.l[p * n + i] * x[p];
            }
            x[i] = s / self.l[i * n + i];
        }
        x.into_iter().map(|v| v as f32).collect()
    }

    /// Solve `A X = B` column-wise; `b` is (n, m), the result is (n, m).
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.n);
        let mut out = Mat::zeros(b.rows, b.cols);
        // Work column by column (gathers are fine at these sizes: n ≤ ~800).
        let mut col = vec![0.0f32; b.rows];
        for j in 0..b.cols {
            for i in 0..b.rows {
                col[i] = b[(i, j)];
            }
            let x = self.solve_vec(&col);
            for i in 0..b.rows {
                out[(i, j)] = x[i];
            }
        }
        out
    }
}

/// Solve `x A = b` for a row-vector unknown (i.e. `Aᵀ xᵀ = bᵀ`); since A is
/// symmetric this is the same as `A xᵀ = bᵀ`. Returns each row of `B`
/// solved independently: given B (m, n) and SPD A (n, n), returns X (m, n)
/// with `X A = B`.
pub fn solve_right(a: &Mat, b: &Mat) -> Option<Mat> {
    let ch = Cholesky::factor(a)?;
    let mut out = Mat::zeros(b.rows, b.cols);
    for r in 0..b.rows {
        let x = ch.solve_vec(b.row(r));
        out.row_mut(r).copy_from_slice(&x);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b};
    use crate::util::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Mat {
        // A = GᵀG + n·I is SPD.
        let mut g = Mat::zeros(n, n);
        rng.fill_normal(&mut g.data, 0.0, 1.0);
        let mut a = matmul_at_b(&g, &g);
        for i in 0..n {
            a[(i, i)] += n as f32;
        }
        a
    }

    #[test]
    fn factor_identity() {
        let ch = Cholesky::factor(&Mat::eye(5)).unwrap();
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(ch.solve_vec(&b), b);
    }

    #[test]
    fn solve_recovers_known_x() {
        let mut rng = Rng::new(42);
        for n in [1usize, 2, 5, 20, 60] {
            let a = spd(&mut rng, n);
            let mut x_true = vec![0.0f32; n];
            rng.fill_normal(&mut x_true, 0.0, 1.0);
            // b = A x
            let b: Vec<f32> = (0..n)
                .map(|i| crate::linalg::vecops::dot(a.row(i), &x_true))
                .collect();
            let x = Cholesky::factor(&a).unwrap().solve_vec(&b);
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-2, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn non_spd_rejected() {
        let m = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]); // indefinite
        assert!(Cholesky::factor(&m).is_none());
        let neg = Mat::from_vec(1, 1, vec![-3.0]);
        assert!(Cholesky::factor(&neg).is_none());
    }

    #[test]
    fn solve_right_matches_reconstruction() {
        let mut rng = Rng::new(7);
        let n = 12;
        let a = spd(&mut rng, n);
        let mut b = Mat::zeros(4, n);
        rng.fill_normal(&mut b.data, 0.0, 1.0);
        let x = solve_right(&a, &b).unwrap();
        let recon = matmul(&x, &a); // X·A should equal B (A symmetric)
        for i in 0..b.data.len() {
            assert!((recon.data[i] - b.data[i]).abs() < 1e-2);
        }
    }

    #[test]
    fn solve_mat_multiple_rhs() {
        let mut rng = Rng::new(9);
        let n = 10;
        let a = spd(&mut rng, n);
        let mut x_true = Mat::zeros(n, 3);
        rng.fill_normal(&mut x_true.data, 0.0, 1.0);
        let b = matmul(&a, &x_true);
        let x = Cholesky::factor(&a).unwrap().solve_mat(&b);
        for i in 0..x.data.len() {
            assert!((x.data[i] - x_true.data[i]).abs() < 1e-2);
        }
    }
}
