//! Vector primitives used on the LC hot path (penalty gradients, multiplier
//! updates, SGD, the LUT gather) — **SIMD-explicit**.
//!
//! The hot kernels process 8 lanes per step over `[f32; 8]` blocks
//! (`chunks_exact`), which the compiler lowers to one AVX register (or two
//! NEON quads) without nightly `portable_simd` or arch intrinsics: the
//! chunked shape removes bounds checks and loop-carried dependencies, so
//! codegen is straight vector loads/ops/stores plus an unrolled reduction.
//! Remainders fall through to the [`scalar`] reference forms.
//!
//! Two invariants keep the golden tests meaningful:
//!
//! * **Element-wise kernels** (`axpy`, `sub_into`, `shift_by_multipliers`,
//!   `nesterov_step`, `nesterov_step_penalized`, the λ half of
//!   `update_multipliers_fused`)
//!   perform the *same per-element operation sequence* as their scalar
//!   references — no FMA contraction, no reassociation — so they are
//!   **bit-for-bit identical** to the scalar forms (and to the pre-SIMD
//!   code), which is what keeps the LC-loop parity tests in
//!   `rust/tests/flat_params.rs` exact.
//! * **Reductions** (`dot`, `sum`, `gather_sum`, and the feasibility norms)
//!   are *defined* by an 8-lane decomposition: element `i` accumulates
//!   into lane `i % 8`, and lanes combine in the fixed tree
//!   `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))`. The [`scalar`] module
//!   implements that definition as a plain indexed loop, so the chunked
//!   kernels are bit-for-bit against it too (the 8 independent
//!   accumulators are also what breaks the dependency chain — the actual
//!   speedup for the gather). `gather_sum` additionally has a true AVX2
//!   `vgatherdps` form behind a `std::arch` runtime feature gate; it
//!   implements the *same* decomposition, so it is bit-for-bit against
//!   the scalar reference as well (property-tested below).

/// SIMD width: 8 × f32 = one 256-bit vector.
const LANES: usize = 8;
type F32x8 = [f32; LANES];

#[inline(always)]
fn ld(s: &[f32]) -> F32x8 {
    s.try_into().expect("8-lane load")
}

#[inline(always)]
fn st(d: &mut [f32], v: F32x8) {
    d.copy_from_slice(&v);
}

#[inline(always)]
fn splat(x: f32) -> F32x8 {
    [x; LANES]
}

#[inline(always)]
fn vadd(a: F32x8, b: F32x8) -> F32x8 {
    core::array::from_fn(|l| a[l] + b[l])
}

#[inline(always)]
fn vsub(a: F32x8, b: F32x8) -> F32x8 {
    core::array::from_fn(|l| a[l] - b[l])
}

#[inline(always)]
fn vmul(a: F32x8, b: F32x8) -> F32x8 {
    core::array::from_fn(|l| a[l] * b[l])
}

/// Fixed-order horizontal sum — part of the reduction definition above.
#[inline(always)]
fn hsum(a: F32x8) -> f32 {
    ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
}

/// Fixed-order horizontal sum for the f64 accumulator pairs.
#[inline(always)]
fn hsum64(a: [f64; LANES]) -> f64 {
    ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
}

/// Bit-exact scalar reference forms of the SIMD kernels above: plain
/// indexed loops implementing the same per-element operations (and, for
/// reductions, the same 8-lane decomposition). They serve as the golden
/// baseline for the parity tests, the tail path of the chunked kernels,
/// and the "scalar" side of the `bench_lstep` SIMD-vs-scalar measurement.
pub mod scalar {
    use super::LANES;

    /// Reference `y += alpha * x`.
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// Reference dot product (8-lane decomposition).
    pub fn dot(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        let mut acc = [0.0f32; LANES];
        for i in 0..x.len() {
            acc[i % LANES] += x[i] * y[i];
        }
        super::hsum(acc)
    }

    /// Reference `Σᵢ x[idx[i]]` (8-lane decomposition).
    pub fn gather_sum(x: &[f32], idx: &[u32]) -> f32 {
        let mut acc = [0.0f32; LANES];
        for (i, &j) in idx.iter().enumerate() {
            acc[i % LANES] += x[j as usize];
        }
        super::hsum(acc)
    }

    /// Reference sum of all entries (8-lane decomposition).
    pub fn sum(x: &[f32]) -> f32 {
        let mut acc = [0.0f32; LANES];
        for (i, &v) in x.iter().enumerate() {
            acc[i % LANES] += v;
        }
        super::hsum(acc)
    }

    /// Reference fused Nesterov step.
    pub fn nesterov_step(w: &mut [f32], g: &[f32], v: &mut [f32], lr: f32, m: f32) {
        debug_assert_eq!(w.len(), g.len());
        debug_assert_eq!(w.len(), v.len());
        for i in 0..w.len() {
            v[i] = m * v[i] - lr * g[i];
            w[i] += m * v[i] - lr * g[i];
        }
    }

    /// Reference fused Nesterov step with the LC penalty gradient.
    #[allow(clippy::too_many_arguments)]
    pub fn nesterov_step_penalized(
        w: &mut [f32],
        g: &[f32],
        v: &mut [f32],
        wc: &[f32],
        lambda: &[f32],
        mu: f32,
        lr: f32,
        m: f32,
    ) {
        debug_assert_eq!(w.len(), g.len());
        debug_assert_eq!(w.len(), v.len());
        debug_assert_eq!(w.len(), wc.len());
        debug_assert_eq!(w.len(), lambda.len());
        for i in 0..w.len() {
            let gi = g[i] + mu * (w[i] - wc[i]) - lambda[i];
            v[i] = m * v[i] - lr * gi;
            w[i] += m * v[i] - lr * gi;
        }
    }

    /// Reference `out = x - y`.
    pub fn sub_into(x: &[f32], y: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len(), out.len());
        for i in 0..x.len() {
            out[i] = x[i] - y[i];
        }
    }

    /// Reference `out[i] = w[i] - lambda[i] * (1/mu)` (the reciprocal is
    /// computed once, exactly as in the chunked form).
    pub fn shift_by_multipliers(w: &[f32], lambda: &[f32], mu: f32, out: &mut [f32]) {
        debug_assert_eq!(w.len(), lambda.len());
        debug_assert_eq!(w.len(), out.len());
        let inv_mu = 1.0 / mu;
        for i in 0..w.len() {
            out[i] = w[i] - lambda[i] * inv_mu;
        }
    }

    /// Reference fused multiplier update + feasibility norms (8-lane f64
    /// accumulators).
    pub fn update_multipliers_fused(
        lambda: &mut [f32],
        w: &[f32],
        wc: &[f32],
        mu: f32,
    ) -> (f32, f32) {
        debug_assert_eq!(lambda.len(), w.len());
        debug_assert_eq!(lambda.len(), wc.len());
        let mut dist2 = [0.0f64; LANES];
        let mut norm2 = [0.0f64; LANES];
        for i in 0..lambda.len() {
            let d = w[i] - wc[i];
            lambda[i] -= mu * d;
            dist2[i % LANES] += (d as f64) * (d as f64);
            norm2[i % LANES] += (w[i] as f64) * (w[i] as f64);
        }
        (super::hsum64(dist2).sqrt() as f32, super::hsum64(norm2).sqrt() as f32)
    }

    /// Reference `(‖w − wc‖₂, ‖w‖₂)` (8-lane f64 accumulators).
    pub fn feasibility(w: &[f32], wc: &[f32]) -> (f32, f32) {
        debug_assert_eq!(w.len(), wc.len());
        let mut dist2 = [0.0f64; LANES];
        let mut norm2 = [0.0f64; LANES];
        for i in 0..w.len() {
            let d = w[i] - wc[i];
            dist2[i % LANES] += (d as f64) * (d as f64);
            norm2[i % LANES] += (w[i] as f64) * (w[i] as f64);
        }
        (super::hsum64(dist2).sqrt() as f32, super::hsum64(norm2).sqrt() as f32)
    }

    // ---- popcount kernel family references (bit-sliced serve tier) ----
    //
    // These implement the decompositions documented on the public kernels
    // with plain positional loops: every bit k in 0..n_b is tested
    // explicitly, so the add sequence is spelled out rather than derived
    // from `trailing_zeros` arithmetic. The public forms must match them
    // bit-for-bit.

    /// Reference per-64-element block sums: block `wi` is
    /// `scalar::sum(&x[64wi .. 64wi+n_b])` — the same 8-lane reduction
    /// definition as every other sum in this module.
    pub fn block_sums(x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), x.len().div_ceil(64));
        for (wi, o) in out.iter_mut().enumerate() {
            let base = wi * 64;
            let end = (base + 64).min(x.len());
            *o = sum(&x[base..end]);
        }
    }

    /// Reference single-plane masked word sum: ascending-bit-order scan of
    /// the set bits of `w` (already masked to the block's valid bits),
    /// taking the complement branch when the plane is dense. Identical
    /// branch rule and add order as the optimized `plane_sum`.
    fn plane_sum(xs: &[f32], w: u64, valid: u64, block: f32) -> f32 {
        let pc = w.count_ones() as usize;
        if 2 * pc <= xs.len() {
            scan_sum(xs, w)
        } else {
            block - scan_sum(xs, !w & valid)
        }
    }

    /// Reference ascending set-bit scan: test every bit position in order.
    fn scan_sum(xs: &[f32], w: u64) -> f32 {
        let mut s = 0.0f32;
        for (k, &v) in xs.iter().enumerate() {
            if (w >> k) & 1 == 1 {
                s += v;
            }
        }
        s
    }

    /// Reference [`super::masked_sum_pc`]: per-word plane sums accumulate
    /// into lane `wi % 8`, combined with the fixed `hsum` tree.
    pub fn masked_sum_pc(x: &[f32], mask: &[u64], blocks: &[f32]) -> f32 {
        let n = x.len();
        let n_words = n.div_ceil(64);
        debug_assert_eq!(mask.len(), n_words);
        debug_assert_eq!(blocks.len(), n_words);
        let mut acc = [0.0f32; LANES];
        for wi in 0..n_words {
            let base = wi * 64;
            let n_b = (n - base).min(64);
            let valid = super::valid_mask(n_b);
            acc[wi % LANES] += plane_sum(&x[base..base + n_b], mask[wi] & valid, valid, blocks[wi]);
        }
        super::hsum(acc)
    }

    /// Reference [`super::ternary_sums`]: positive plane `s & m`, negative
    /// plane `!s & m`, each summed per word with the `plane_sum` branch
    /// rule and accumulated into lane `wi % 8`.
    pub fn ternary_sums(
        x: &[f32],
        sign: &[u64],
        mask: &[u64],
        blocks: &[f32],
    ) -> (f32, f32) {
        let n = x.len();
        let n_words = n.div_ceil(64);
        debug_assert_eq!(sign.len(), n_words);
        debug_assert_eq!(mask.len(), n_words);
        debug_assert_eq!(blocks.len(), n_words);
        let mut pos = [0.0f32; LANES];
        let mut neg = [0.0f32; LANES];
        for wi in 0..n_words {
            let base = wi * 64;
            let n_b = (n - base).min(64);
            let valid = super::valid_mask(n_b);
            let xs = &x[base..base + n_b];
            pos[wi % LANES] += plane_sum(xs, sign[wi] & mask[wi] & valid, valid, blocks[wi]);
            neg[wi % LANES] += plane_sum(xs, !sign[wi] & mask[wi] & valid, valid, blocks[wi]);
        }
        (super::hsum(pos), super::hsum(neg))
    }

    /// Reference [`super::code_accumulate`]: code `i` is extracted
    /// positionally (bit offset `i·bits`, LSB-first, straddling words as
    /// needed) and `acc[code] += x[i]` runs in ascending `i` order.
    pub fn code_accumulate(x: &[f32], codes: &[u64], bits: u32, acc: &mut [f32]) {
        let bits = bits as usize;
        debug_assert!((1..=16).contains(&bits));
        debug_assert!(acc.len() >= 1 << bits);
        debug_assert!(codes.len() >= (x.len() * bits).div_ceil(64));
        let m = (1u64 << bits) - 1;
        for (i, &xi) in x.iter().enumerate() {
            let bitpos = i * bits;
            let (wi, off) = (bitpos >> 6, bitpos & 63);
            let mut c = codes[wi] >> off;
            if off + bits > 64 {
                c |= codes[wi + 1] << (64 - off);
            }
            acc[(c & m) as usize] += xi;
        }
    }
}

/// y += alpha * x — 8-lane chunked; also the gemm cores' rank-1 update.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let main = x.len() - x.len() % LANES;
    let a8 = splat(alpha);
    let (xm, xt) = x.split_at(main);
    let (ym, yt) = y.split_at_mut(main);
    for (yc, xc) in ym.chunks_exact_mut(LANES).zip(xm.chunks_exact(LANES)) {
        st(yc, vadd(ld(yc), vmul(a8, ld(xc))));
    }
    scalar::axpy(alpha, xt, yt);
}

/// Dot product — 8 independent accumulator lanes.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let main = x.len() - x.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for (xc, yc) in x[..main].chunks_exact(LANES).zip(y[..main].chunks_exact(LANES)) {
        acc = vadd(acc, vmul(ld(xc), ld(yc)));
    }
    for (l, i) in (main..x.len()).enumerate() {
        acc[l] += x[i] * y[i];
    }
    hsum(acc)
}

/// Σᵢ x[idx[i]] — the gather-accumulate primitive of the LUT forward pass
/// ([`crate::serve::engine`]): per-centroid partial sums are gathers, the
/// multiply happens once per centroid instead of once per weight.
///
/// Two implementations share the *same* 8-lane reduction definition (lane
/// `l` accumulates element `8i + l`; fixed `hsum` combine tree), so they
/// are bit-for-bit interchangeable:
///
/// * on `x86_64` with AVX2 detected at runtime (`std::arch` feature gate),
///   a true `vgatherdps` form: each 8-index chunk is bounds-checked
///   against `x` with two vector ops and then gathered in one
///   `_mm256_i32gather_ps`, keeping the loads fully pipelined;
/// * everywhere else, the portable 8-accumulator scalar-load form — the
///   independent lanes still break the add dependency chain.
///
/// Out-of-range indices panic in both paths (the AVX2 path validates each
/// chunk against the slice bounds *before* its gather issues, so no
/// out-of-bounds load is ever performed).
#[inline]
pub fn gather_sum(x: &[f32], idx: &[u32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if idx.len() >= LANES
        && !x.is_empty()
        // the hardware gather sign-extends index lanes, so the unsigned
        // range gate inside is only sound while every valid index fits i32
        && x.len() <= i32::MAX as usize
        && avx2_available()
    {
        // SAFETY: AVX2 presence is checked at runtime; indices are
        // validated against `x` inside before each gather.
        return unsafe { gather_sum_avx2(x, idx) };
    }
    gather_sum_lanes(x, idx)
}

/// Portable 8-accumulator form of [`gather_sum`] (also the sub-8-element
/// and no-AVX2 path).
#[inline]
fn gather_sum_lanes(x: &[f32], idx: &[u32]) -> f32 {
    let main = idx.len() - idx.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for c in idx[..main].chunks_exact(LANES) {
        for l in 0..LANES {
            acc[l] += x[c[l] as usize];
        }
    }
    for (l, &j) in idx[main..].iter().enumerate() {
        acc[l] += x[j as usize];
    }
    hsum(acc)
}

/// Cached runtime AVX2 detection (`std::arch`'s detector already caches;
/// this keeps the hot-path check to one relaxed atomic load).
#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static AVX2: AtomicU8 = AtomicU8::new(0); // 0 = unknown, 1 = yes, 2 = no
    match AVX2.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2");
            AVX2.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
            yes
        }
    }
}

/// `vgatherdps` gather-sum: same 8-lane decomposition as
/// [`gather_sum_lanes`], with the per-chunk loads issued as one hardware
/// gather. Each chunk's indices are range-checked (vector `min`/`cmpeq` +
/// movemask) *before* its gather, so a bad index panics exactly like the
/// checked scalar form instead of reading out of bounds.
///
/// # Safety
/// Caller must ensure AVX2 is available and `1 <= x.len() <= i32::MAX`
/// (the gather sign-extends its index lanes, so larger slices would let
/// an unsigned-valid index wrap negative).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gather_sum_avx2(x: &[f32], idx: &[u32]) -> f32 {
    use std::arch::x86_64::*;
    debug_assert!(!x.is_empty() && x.len() <= i32::MAX as usize);
    let main = idx.len() - idx.len() % LANES;
    let max_idx = _mm256_set1_epi32((x.len() - 1) as u32 as i32);
    let mut acc = _mm256_setzero_ps();
    for c in idx[..main].chunks_exact(LANES) {
        let iv = _mm256_loadu_si256(c.as_ptr() as *const __m256i);
        // unsigned range gate: min(iv, max_idx) == iv ⇔ every lane ≤ max_idx
        let ok = _mm256_cmpeq_epi32(_mm256_min_epu32(iv, max_idx), iv);
        if _mm256_movemask_epi8(ok) != -1 {
            // panic like the checked scalar form would (first offending
            // index, in order) — reached before any load of this chunk
            let bad = c
                .iter()
                .find(|&&j| j as usize >= x.len())
                .expect("range gate fired but all indices were in bounds");
            panic!("gather_sum: index {bad} out of range for slice of len {}", x.len());
        }
        acc = _mm256_add_ps(acc, _mm256_i32gather_ps::<4>(x.as_ptr(), iv));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for (l, &j) in idx[main..].iter().enumerate() {
        lanes[l] += x[j as usize];
    }
    hsum(lanes)
}

/// Sum of all entries — 8 accumulator lanes.
#[inline]
pub fn sum(x: &[f32]) -> f32 {
    let main = x.len() - x.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for c in x[..main].chunks_exact(LANES) {
        acc = vadd(acc, ld(c));
    }
    for (l, &v) in x[main..].iter().enumerate() {
        acc[l] += v;
    }
    hsum(acc)
}

/// ||x - y||_2
#[inline]
pub fn l2_dist(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        let d = (a - b) as f64;
        s += d * d;
    }
    s.sqrt() as f32
}

/// ||x||_2
#[inline]
pub fn l2_norm(x: &[f32]) -> f32 {
    let mut s = 0.0f64;
    for a in x {
        s += (*a as f64) * (*a as f64);
    }
    s.sqrt() as f32
}

/// Mean of |x_i| — the optimal binarization scale (Thm A.2).
#[inline]
pub fn mean_abs(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    let s: f64 = x.iter().map(|v| v.abs() as f64).sum();
    (s / x.len() as f64) as f32
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// out = x - y (allocating)
pub fn sub(x: &[f32], y: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a - b).collect()
}

/// z = x - y, written into `out` (non-allocating hot-path form) — 8-lane
/// chunked, per-element ops identical to [`scalar::sub_into`].
#[inline]
pub fn sub_into(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    let main = x.len() - x.len() % LANES;
    let (om, ot) = out.split_at_mut(main);
    for ((oc, xc), yc) in om
        .chunks_exact_mut(LANES)
        .zip(x[..main].chunks_exact(LANES))
        .zip(y[..main].chunks_exact(LANES))
    {
        st(oc, vsub(ld(xc), ld(yc)));
    }
    scalar::sub_into(&x[main..], &y[main..], ot);
}

/// out[i] = w[i] - lambda[i] / mu — the shifted weights the C step
/// quantizes. 8-lane chunked; the reciprocal is computed once and the
/// per-element ops are identical to [`scalar::shift_by_multipliers`].
#[inline]
pub fn shift_by_multipliers(w: &[f32], lambda: &[f32], mu: f32, out: &mut [f32]) {
    debug_assert_eq!(w.len(), lambda.len());
    debug_assert_eq!(w.len(), out.len());
    let inv_mu = 1.0 / mu;
    let main = w.len() - w.len() % LANES;
    let inv8 = splat(inv_mu);
    let (om, ot) = out.split_at_mut(main);
    for ((oc, wc), lc) in om
        .chunks_exact_mut(LANES)
        .zip(w[..main].chunks_exact(LANES))
        .zip(lambda[..main].chunks_exact(LANES))
    {
        st(oc, vsub(ld(wc), vmul(ld(lc), inv8)));
    }
    scalar::shift_by_multipliers(&w[main..], &lambda[main..], mu, ot);
}

/// lambda[i] -= mu * (w[i] - wc[i]) — the augmented-Lagrangian multiplier
/// update from §3 of the paper.
#[inline]
pub fn update_multipliers(lambda: &mut [f32], w: &[f32], wc: &[f32], mu: f32) {
    debug_assert_eq!(lambda.len(), w.len());
    debug_assert_eq!(lambda.len(), wc.len());
    for i in 0..lambda.len() {
        lambda[i] -= mu * (w[i] - wc[i]);
    }
}

/// (‖w − wc‖₂, ‖w‖₂) in one pass — the LC feasibility check. Same 8-lane
/// f64 accumulation as [`update_multipliers_fused`], so the two agree
/// bit-for-bit on identical inputs.
#[inline]
pub fn feasibility(w: &[f32], wc: &[f32]) -> (f32, f32) {
    debug_assert_eq!(w.len(), wc.len());
    let n = w.len();
    let main = n - n % LANES;
    let mut dist2 = [0.0f64; LANES];
    let mut norm2 = [0.0f64; LANES];
    for (wch, cch) in w[..main].chunks_exact(LANES).zip(wc[..main].chunks_exact(LANES)) {
        let wv = ld(wch);
        let d = vsub(wv, ld(cch));
        for l in 0..LANES {
            dist2[l] += (d[l] as f64) * (d[l] as f64);
            norm2[l] += (wv[l] as f64) * (wv[l] as f64);
        }
    }
    for (l, i) in (main..n).enumerate() {
        let d = w[i] - wc[i];
        dist2[l] += (d as f64) * (d as f64);
        norm2[l] += (w[i] as f64) * (w[i] as f64);
    }
    (hsum64(dist2).sqrt() as f32, hsum64(norm2).sqrt() as f32)
}

/// Fused multiplier update + feasibility: `λ −= μ(w − w_C)` while
/// accumulating (‖w − wc‖₂, ‖w‖₂) in the same pass, so the LC outer loop
/// streams the weight arena once instead of twice. The λ update is
/// element-wise-exact (same ops as [`update_multipliers`]); the norms use
/// the 8-lane f64 accumulation shared with [`feasibility`].
#[inline]
pub fn update_multipliers_fused(
    lambda: &mut [f32],
    w: &[f32],
    wc: &[f32],
    mu: f32,
) -> (f32, f32) {
    debug_assert_eq!(lambda.len(), w.len());
    debug_assert_eq!(lambda.len(), wc.len());
    let n = w.len();
    let main = n - n % LANES;
    let mu8 = splat(mu);
    let mut dist2 = [0.0f64; LANES];
    let mut norm2 = [0.0f64; LANES];
    let (lm, lt) = lambda.split_at_mut(main);
    for ((lc, wch), cch) in lm
        .chunks_exact_mut(LANES)
        .zip(w[..main].chunks_exact(LANES))
        .zip(wc[..main].chunks_exact(LANES))
    {
        let wv = ld(wch);
        let d = vsub(wv, ld(cch));
        st(lc, vsub(ld(lc), vmul(mu8, d)));
        for l in 0..LANES {
            dist2[l] += (d[l] as f64) * (d[l] as f64);
            norm2[l] += (wv[l] as f64) * (wv[l] as f64);
        }
    }
    for (l, i) in (main..n).enumerate() {
        let d = w[i] - wc[i];
        lt[l] -= mu * d;
        dist2[l] += (d as f64) * (d as f64);
        norm2[l] += (w[i] as f64) * (w[i] as f64);
    }
    (hsum64(dist2).sqrt() as f32, hsum64(norm2).sqrt() as f32)
}

/// Fused Nesterov-momentum update (Lasagne formulation) over a flat
/// parameter slice: `v ← m·v − lr·g; w ← w + m·v − lr·g` — 8-lane
/// chunked, per-element ops identical to [`scalar::nesterov_step`].
#[inline]
pub fn nesterov_step(w: &mut [f32], g: &[f32], v: &mut [f32], lr: f32, m: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), v.len());
    let main = w.len() - w.len() % LANES;
    let m8 = splat(m);
    let lr8 = splat(lr);
    let (wm, wt) = w.split_at_mut(main);
    let (gm, gt) = g.split_at(main);
    let (vm, vt) = v.split_at_mut(main);
    for ((wc, gc), vc) in wm
        .chunks_exact_mut(LANES)
        .zip(gm.chunks_exact(LANES))
        .zip(vm.chunks_exact_mut(LANES))
    {
        let lrg = vmul(lr8, ld(gc));
        let vnew = vsub(vmul(m8, ld(vc)), lrg);
        st(vc, vnew);
        st(wc, vadd(ld(wc), vsub(vmul(m8, vnew), lrg)));
    }
    scalar::nesterov_step(wt, gt, vt, lr, m);
}

/// Nesterov update with the LC penalty gradient fused in:
/// the effective gradient is `g + μ(w − w_C) − λ` (paper §3), computed
/// inline so the penalized L step is one pass over the weight arena with
/// zero temporary buffers — 8-lane chunked, per-element ops identical to
/// [`scalar::nesterov_step_penalized`].
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn nesterov_step_penalized(
    w: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    wc: &[f32],
    lambda: &[f32],
    mu: f32,
    lr: f32,
    m: f32,
) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), v.len());
    debug_assert_eq!(w.len(), wc.len());
    debug_assert_eq!(w.len(), lambda.len());
    let main = w.len() - w.len() % LANES;
    let m8 = splat(m);
    let lr8 = splat(lr);
    let mu8 = splat(mu);
    let (wm, wt) = w.split_at_mut(main);
    let (gm, gt) = g.split_at(main);
    let (vm, vt) = v.split_at_mut(main);
    for (i, ((wch, gc), vc)) in wm
        .chunks_exact_mut(LANES)
        .zip(gm.chunks_exact(LANES))
        .zip(vm.chunks_exact_mut(LANES))
        .enumerate()
    {
        let base = i * LANES;
        let wv = ld(wch);
        let pen = vmul(mu8, vsub(wv, ld(&wc[base..base + LANES])));
        let gi = vsub(vadd(ld(gc), pen), ld(&lambda[base..base + LANES]));
        let lrg = vmul(lr8, gi);
        let vnew = vsub(vmul(m8, ld(vc)), lrg);
        st(vc, vnew);
        st(wch, vadd(wv, vsub(vmul(m8, vnew), lrg)));
    }
    scalar::nesterov_step_penalized(
        wt,
        gt,
        vt,
        &wc[main..],
        &lambda[main..],
        mu,
        lr,
        m,
    );
}

// ---- popcount kernel family (bit-sliced serve tier) ----------------------
//
// These four kernels let `serve::bitslice` compute layer outputs directly
// on packed `u64` assignment planes — popcount bookkeeping instead of
// per-weight f32 centroid gathers. Like the reductions above, each has a
// *documented decomposition* that the [`scalar`] references implement with
// plain positional loops, and the parity property tests below pin the two
// bit-for-bit. The decompositions:
//
// * **Per-word plane sum** (`masked_sum_pc`, `ternary_sums`): word `wi`
//   covers elements `64wi .. 64wi + n_b` with valid-bit mask `valid`. For
//   a plane word `w` (pre-masked to `valid`) with popcount `pc`:
//   if `2·pc ≤ n_b` the word's value is the **ascending-bit-order scan**
//   `Σ x[64wi+k]` over set bits `k` of `w`; otherwise it is
//   `blocks[wi] − scan(!w & valid)` — the precomputed block sum minus the
//   scan of the complement. The branch rule is part of the definition:
//   the complement form yields different float rounding than the direct
//   scan, so both implementations take the identical branch and add in
//   the identical order. Per-word values accumulate into lane `wi % 8`
//   and combine with the fixed `hsum` tree.
// * **Block sums** (`block_sums`): block `wi` is `sum(&x[64wi..64wi+n_b])`
//   — the module's standard 8-lane sum of that sub-slice.
// * **Code accumulate** (`code_accumulate`): codes are `bits` wide,
//   LSB-first, packed contiguously (code `i` at bit offset `i·bits`,
//   straddling word boundaries); `acc[code_i] += x[i]` executes in
//   ascending `i` order.

/// Mask selecting the low `n_b` valid bits of a 64-element block word.
#[inline(always)]
fn valid_mask(n_b: usize) -> u64 {
    debug_assert!((1..=64).contains(&n_b));
    if n_b == 64 {
        !0
    } else {
        (1u64 << n_b) - 1
    }
}

/// Ascending set-bit scan via `trailing_zeros` + clear-lowest-bit: visits
/// exactly the set bits of `w` in ascending order, so the add sequence is
/// identical to the positional reference scan in [`scalar`].
#[inline(always)]
fn scan_sum(xs: &[f32], mut w: u64) -> f32 {
    let mut s = 0.0f32;
    while w != 0 {
        s += xs[w.trailing_zeros() as usize];
        w &= w - 1;
    }
    s
}

/// One plane word's sum under the documented branch rule (`w` pre-masked
/// to `valid`): sparse → direct scan; dense → block sum minus complement
/// scan.
#[inline(always)]
fn plane_sum(xs: &[f32], w: u64, valid: u64, block: f32) -> f32 {
    let pc = w.count_ones() as usize;
    if 2 * pc <= xs.len() {
        scan_sum(xs, w)
    } else {
        block - scan_sum(xs, !w & valid)
    }
}

/// Per-64-element block sums of `x` into `out`
/// (`out.len() == x.len().div_ceil(64)`): the dense-word fallback operand
/// for [`masked_sum_pc`] / [`ternary_sums`], computed once per input row
/// and shared across every output column. Block `wi` is [`sum`] of the
/// sub-slice, so it is bit-for-bit against [`scalar::block_sums`].
#[inline]
pub fn block_sums(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), x.len().div_ceil(64));
    for (wi, o) in out.iter_mut().enumerate() {
        let base = wi * 64;
        let end = (base + 64).min(x.len());
        *o = sum(&x[base..end]);
    }
}

/// `Σ x[i]` over set bits of the packed 1-bit plane `mask` — the binary
/// (sign-plane) kernel: with `S⁺ = masked_sum_pc(x, sign_plane, blocks)`
/// and `T = sum(x)`, a ±a binary column is `a·(2S⁺ − T)`. `blocks` must be
/// [`block_sums`] of `x`. Decomposition documented on the family header
/// above; bit-for-bit against [`scalar::masked_sum_pc`].
#[inline]
pub fn masked_sum_pc(x: &[f32], mask: &[u64], blocks: &[f32]) -> f32 {
    let n = x.len();
    let n_words = n.div_ceil(64);
    debug_assert_eq!(mask.len(), n_words);
    debug_assert_eq!(blocks.len(), n_words);
    let mut acc = [0.0f32; LANES];
    for wi in 0..n_words {
        let base = wi * 64;
        let n_b = (n - base).min(64);
        let valid = valid_mask(n_b);
        acc[wi % LANES] += plane_sum(&x[base..base + n_b], mask[wi] & valid, valid, blocks[wi]);
    }
    hsum(acc)
}

/// Two-plane ternary kernel: returns `(Σ x over positive weights, Σ x
/// over negative weights)` where positive bits are `sign & mask` and
/// negative bits are `!sign & mask` (the sign plane is only meaningful
/// under the nonzero mask — the intersection makes hostile sign bits
/// outside the mask irrelevant). A ±a/0 ternary column is then
/// `a·(pos − neg)`. `blocks` must be [`block_sums`] of `x`. Bit-for-bit
/// against [`scalar::ternary_sums`].
#[inline]
pub fn ternary_sums(x: &[f32], sign: &[u64], mask: &[u64], blocks: &[f32]) -> (f32, f32) {
    let n = x.len();
    let n_words = n.div_ceil(64);
    debug_assert_eq!(sign.len(), n_words);
    debug_assert_eq!(mask.len(), n_words);
    debug_assert_eq!(blocks.len(), n_words);
    let mut pos = [0.0f32; LANES];
    let mut neg = [0.0f32; LANES];
    for wi in 0..n_words {
        let base = wi * 64;
        let n_b = (n - base).min(64);
        let valid = valid_mask(n_b);
        let xs = &x[base..base + n_b];
        let s = sign[wi];
        let m = mask[wi];
        pos[wi % LANES] += plane_sum(xs, s & m & valid, valid, blocks[wi]);
        neg[wi % LANES] += plane_sum(xs, !s & m & valid, valid, blocks[wi]);
    }
    (hsum(pos), hsum(neg))
}

/// Gather-free K-accumulator kernel for small coded codebooks:
/// `acc[code_i] += x[i]` in ascending `i` order, with code `i` read from
/// the contiguous LSB-first `bits`-wide stream in `codes`. The caller
/// finishes with one multiply per *centroid* (`Σ_c codebook[c]·acc[c]`)
/// instead of one gather per *weight*. Codes are masked to `bits`, so
/// `acc.len() ≥ 2^bits` guarantees in-bounds accumulation even for
/// streams whose codes exceed the model's K (those slots are simply
/// never combined). The optimized form streams a 128-bit refill buffer;
/// the positional [`scalar::code_accumulate`] reference extracts each
/// code independently — identical codes, identical add order, so the two
/// are bit-for-bit.
#[inline]
pub fn code_accumulate(x: &[f32], codes: &[u64], bits: u32, acc: &mut [f32]) {
    let bits = bits as usize;
    debug_assert!((1..=16).contains(&bits));
    debug_assert!(acc.len() >= 1 << bits);
    debug_assert!(codes.len() >= (x.len() * bits).div_ceil(64));
    let m = (1u64 << bits) - 1;
    let mut buf: u128 = 0;
    let mut avail = 0usize;
    let mut next = 0usize;
    for &xi in x {
        if avail < bits {
            buf |= (codes[next] as u128) << avail;
            next += 1;
            avail += 64;
        }
        acc[(buf as u64 & m) as usize] += xi;
        buf >>= bits;
        avail -= bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn dot_matches_naive() {
        check("dot==naive", 100, |g| {
            let n = g.usize_in(0, 67);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let y: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - naive).abs() < 1e-3);
        });
    }

    #[test]
    fn norms_and_distances() {
        let x = [3.0, 4.0];
        assert!((l2_norm(&x) - 5.0).abs() < 1e-6);
        assert!((l2_dist(&x, &[0.0, 0.0]) - 5.0).abs() < 1e-6);
        assert!((mean_abs(&[-2.0, 2.0, 4.0]) - 8.0 / 3.0).abs() < 1e-6);
        assert_eq!(mean_abs(&[]), 0.0);
    }

    #[test]
    fn gather_sum_matches_naive() {
        check("gather_sum==naive", 80, |g| {
            let n = g.usize_in(1, 50);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let m = g.usize_in(0, 70);
            let idx: Vec<u32> = (0..m).map(|_| g.usize_in(0, n - 1) as u32).collect();
            let naive: f32 = idx.iter().map(|&i| x[i as usize]).sum();
            assert!((gather_sum(&x, &idx) - naive).abs() < 1e-3);
            let total: f32 = x.iter().sum();
            assert!((sum(&x) - total).abs() < 1e-3);
        });
    }

    #[test]
    fn multiplier_updates_match_formula() {
        check("lambda update", 50, |g| {
            let n = g.usize_in(1, 20);
            let w: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let wc: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let mut lambda: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let before = lambda.clone();
            let mu = g.f32_in(0.01, 10.0);
            update_multipliers(&mut lambda, &w, &wc, mu);
            for i in 0..n {
                assert!((lambda[i] - (before[i] - mu * (w[i] - wc[i]))).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn fused_multiplier_update_matches_split_ops() {
        check("fused == split", 50, |g| {
            let n = g.usize_in(1, 40);
            let w: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let wc: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let lam0: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let mu = g.f32_in(0.01, 5.0);
            let mut lam_a = lam0.clone();
            let (dist, norm) = update_multipliers_fused(&mut lam_a, &w, &wc, mu);
            let mut lam_b = lam0.clone();
            update_multipliers(&mut lam_b, &w, &wc, mu);
            assert_eq!(lam_a, lam_b);
            assert!((dist - l2_dist(&w, &wc)).abs() < 1e-5);
            assert!((norm - l2_norm(&w)).abs() < 1e-5);
            let (d2, n2) = feasibility(&w, &wc);
            assert_eq!(d2, dist);
            assert_eq!(n2, norm);
        });
    }

    #[test]
    fn nesterov_step_matches_scalar_recurrence() {
        let mut w = [1.0f32, -2.0];
        let mut v = [0.1f32, 0.0];
        let g = [0.5f32, -0.5];
        let (lr, m) = (0.1f32, 0.9f32);
        let mut we = w;
        let mut ve = v;
        for i in 0..2 {
            ve[i] = m * ve[i] - lr * g[i];
            we[i] += m * ve[i] - lr * g[i];
        }
        nesterov_step(&mut w, &g, &mut v, lr, m);
        assert_eq!(w, we);
        assert_eq!(v, ve);
    }

    #[test]
    fn penalized_step_reduces_to_plain_when_mu_zero_and_lambda_zero() {
        let g = [0.3f32, -0.7, 0.2];
        let wc = [0.0f32; 3];
        let lam = [0.0f32; 3];
        let mut w_a = [0.5f32, -0.5, 1.0];
        let mut v_a = [0.0f32; 3];
        let mut w_b = w_a;
        let mut v_b = v_a;
        nesterov_step(&mut w_a, &g, &mut v_a, 0.05, 0.9);
        nesterov_step_penalized(&mut w_b, &g, &mut v_b, &wc, &lam, 0.0, 0.05, 0.9);
        assert_eq!(w_a, w_b);
        assert_eq!(v_a, v_b);
    }

    #[test]
    fn shift_consistency() {
        let w = [1.0, -1.0];
        let lam = [0.5, 0.5];
        let mut out = [0.0; 2];
        shift_by_multipliers(&w, &lam, 2.0, &mut out);
        assert_eq!(out, [0.75, -1.25]);
    }

    // ---- golden SIMD/scalar parity: every chunked kernel must be
    //      bit-for-bit against its scalar reference, across lengths that
    //      cover empty, sub-lane, exact-multiple and ragged cases --------

    fn parity_lens(g: &mut crate::util::prop::Gen) -> usize {
        // bias towards the interesting boundaries
        *[0usize, 1, 7, 8, 9, 15, 16, 17, 64, g.usize_in(0, 201)]
            .get(g.usize_in(0, 9))
            .unwrap()
    }

    #[test]
    fn simd_axpy_bitwise_matches_scalar() {
        check("axpy simd==scalar", 60, |g| {
            let n = parity_lens(g);
            let alpha = g.f32_in(-2.0, 2.0);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let y0: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let mut ya = y0.clone();
            axpy(alpha, &x, &mut ya);
            let mut yb = y0.clone();
            scalar::axpy(alpha, &x, &mut yb);
            assert_eq!(ya, yb);
        });
    }

    #[test]
    fn simd_reductions_bitwise_match_scalar() {
        check("reductions simd==scalar", 60, |g| {
            let n = parity_lens(g).max(1);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let y: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            assert_eq!(dot(&x, &y).to_bits(), scalar::dot(&x, &y).to_bits());
            assert_eq!(sum(&x).to_bits(), scalar::sum(&x).to_bits());
            let m = g.usize_in(0, 3 * n);
            let idx: Vec<u32> = (0..m).map(|_| g.usize_in(0, n - 1) as u32).collect();
            assert_eq!(
                gather_sum(&x, &idx).to_bits(),
                scalar::gather_sum(&x, &idx).to_bits()
            );
        });
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_gather_bitwise_matches_scalar_reference() {
        if !avx2_available() {
            eprintln!("(avx2 not detected; gather parity covered by the portable path)");
            return;
        }
        check("gather avx2==scalar", 80, |g| {
            // lengths straddle the 8-lane boundaries so both the gathered
            // chunks and the lane-tail path are exercised
            let n = g.usize_in(1, 70);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let m = *[8usize, 9, 15, 16, 17, 64, g.usize_in(8, 201)]
                .get(g.usize_in(0, 6))
                .unwrap();
            let idx: Vec<u32> = (0..m).map(|_| g.usize_in(0, n - 1) as u32).collect();
            let fast = unsafe { gather_sum_avx2(&x, &idx) };
            assert_eq!(fast.to_bits(), scalar::gather_sum(&x, &idx).to_bits());
            // and the public entry point routes to the same result
            assert_eq!(gather_sum(&x, &idx).to_bits(), fast.to_bits());
        });
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_gather_panics_on_out_of_range_like_the_scalar_form() {
        if !avx2_available() {
            return;
        }
        let x = vec![1.0f32; 10];
        let mut idx: Vec<u32> = (0..16).map(|i| i % 10).collect();
        idx[11] = 10; // out of range, inside the second gathered chunk
        let r = std::panic::catch_unwind(|| unsafe { gather_sum_avx2(&x, &idx) });
        assert!(r.is_err(), "out-of-range index must panic, not gather");
    }

    #[test]
    fn simd_nesterov_steps_bitwise_match_scalar() {
        check("nesterov simd==scalar", 60, |g| {
            let n = parity_lens(g);
            let (lr, m, mu) = (g.f32_in(0.001, 0.5), g.f32_in(0.0, 0.99), g.f32_in(0.0, 2.0));
            let w0: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let v0: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let gr: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let wc: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let lam: Vec<f32> = (0..n).map(|_| g.f32_in(-0.2, 0.2)).collect();

            let (mut wa, mut va) = (w0.clone(), v0.clone());
            nesterov_step(&mut wa, &gr, &mut va, lr, m);
            let (mut wb, mut vb) = (w0.clone(), v0.clone());
            scalar::nesterov_step(&mut wb, &gr, &mut vb, lr, m);
            assert_eq!(wa, wb);
            assert_eq!(va, vb);

            let (mut wa, mut va) = (w0.clone(), v0.clone());
            nesterov_step_penalized(&mut wa, &gr, &mut va, &wc, &lam, mu, lr, m);
            let (mut wb, mut vb) = (w0.clone(), v0.clone());
            scalar::nesterov_step_penalized(&mut wb, &gr, &mut vb, &wc, &lam, mu, lr, m);
            assert_eq!(wa, wb);
            assert_eq!(va, vb);
        });
    }

    #[test]
    fn simd_sub_and_shift_bitwise_match_scalar() {
        check("sub/shift simd==scalar", 60, |g| {
            let n = parity_lens(g);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let y: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let mut oa = vec![0.0f32; n];
            sub_into(&x, &y, &mut oa);
            let mut ob = vec![0.0f32; n];
            scalar::sub_into(&x, &y, &mut ob);
            assert_eq!(oa, ob);

            let lam: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let mu = g.f32_in(0.01, 5.0);
            let mut sa = vec![0.0f32; n];
            shift_by_multipliers(&x, &lam, mu, &mut sa);
            let mut sb = vec![0.0f32; n];
            scalar::shift_by_multipliers(&x, &lam, mu, &mut sb);
            assert_eq!(sa, sb);
        });
    }

    #[test]
    fn simd_fused_multiplier_update_bitwise_matches_scalar() {
        check("fused simd==scalar", 60, |g| {
            let n = parity_lens(g);
            let mu = g.f32_in(0.01, 5.0);
            let w: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let wc: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let lam0: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let mut lam_a = lam0.clone();
            let (da, na) = update_multipliers_fused(&mut lam_a, &w, &wc, mu);
            let mut lam_b = lam0.clone();
            let (db, nb) = scalar::update_multipliers_fused(&mut lam_b, &w, &wc, mu);
            assert_eq!(lam_a, lam_b);
            assert_eq!(da.to_bits(), db.to_bits());
            assert_eq!(na.to_bits(), nb.to_bits());
            let (fa, fb) = feasibility(&w, &wc);
            let (sa, sb) = scalar::feasibility(&w, &wc);
            assert_eq!(fa.to_bits(), sa.to_bits());
            assert_eq!(fb.to_bits(), sb.to_bits());
        });
    }

    // ---- popcount kernel family: bit-for-bit parity against the scalar
    //      references across word-boundary lengths and mask densities ----

    /// Length distribution biased to the 64-bit word boundaries the
    /// popcount kernels care about (plus the 8-lane ones).
    fn word_parity_lens(g: &mut crate::util::prop::Gen) -> usize {
        *[0usize, 1, 7, 8, 63, 64, 65, 127, 128, 129, g.usize_in(0, 400)]
            .get(g.usize_in(0, 10))
            .unwrap()
    }

    /// Mask words with varied density so both branches of the documented
    /// popcount rule (direct scan vs block-minus-complement) are hit.
    fn random_plane(g: &mut crate::util::prop::Gen, n_words: usize) -> Vec<u64> {
        (0..n_words)
            .map(|_| {
                let a = (g.usize_in(0, u32::MAX as usize) as u64) << 32
                    | g.usize_in(0, u32::MAX as usize) as u64;
                match g.usize_in(0, 3) {
                    0 => 0,                       // empty word
                    1 => !0,                      // full word (dense branch)
                    2 => a & ((g.usize_in(0, u32::MAX as usize) as u64) << 32
                        | g.usize_in(0, u32::MAX as usize) as u64), // sparse
                    _ => a,                       // ~half density
                }
            })
            .collect()
    }

    #[test]
    fn block_sums_bitwise_match_scalar() {
        check("block_sums simd==scalar", 60, |g| {
            let n = word_parity_lens(g);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let n_words = n.div_ceil(64);
            let mut a = vec![0.0f32; n_words];
            block_sums(&x, &mut a);
            let mut b = vec![0.0f32; n_words];
            scalar::block_sums(&x, &mut b);
            assert_eq!(a, b);
            // and each block agrees with the module's standard sum
            for wi in 0..n_words {
                let base = wi * 64;
                let end = (base + 64).min(n);
                assert_eq!(a[wi].to_bits(), sum(&x[base..end]).to_bits());
            }
        });
    }

    #[test]
    fn masked_sum_pc_bitwise_matches_scalar_and_naive() {
        check("masked_sum_pc simd==scalar", 80, |g| {
            let n = word_parity_lens(g);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let n_words = n.div_ceil(64);
            let mask = random_plane(g, n_words);
            let mut blocks = vec![0.0f32; n_words];
            block_sums(&x, &mut blocks);
            let fast = masked_sum_pc(&x, &mask, &blocks);
            let refv = scalar::masked_sum_pc(&x, &mask, &blocks);
            assert_eq!(fast.to_bits(), refv.to_bits());
            let naive: f64 = (0..n)
                .filter(|&i| (mask[i / 64] >> (i % 64)) & 1 == 1)
                .map(|i| x[i] as f64)
                .sum();
            assert!(
                (fast as f64 - naive).abs() < 1e-2,
                "masked_sum_pc {fast} vs naive {naive} (n={n})"
            );
        });
    }

    #[test]
    fn ternary_sums_bitwise_match_scalar_and_naive() {
        check("ternary_sums simd==scalar", 80, |g| {
            let n = word_parity_lens(g);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let n_words = n.div_ceil(64);
            let sign = random_plane(g, n_words);
            let mask = random_plane(g, n_words);
            let mut blocks = vec![0.0f32; n_words];
            block_sums(&x, &mut blocks);
            let (pf, nf) = ternary_sums(&x, &sign, &mask, &blocks);
            let (ps, ns) = scalar::ternary_sums(&x, &sign, &mask, &blocks);
            assert_eq!(pf.to_bits(), ps.to_bits());
            assert_eq!(nf.to_bits(), ns.to_bits());
            let mut pos = 0.0f64;
            let mut neg = 0.0f64;
            for i in 0..n {
                let (w, b) = (i / 64, i % 64);
                if (mask[w] >> b) & 1 == 1 {
                    if (sign[w] >> b) & 1 == 1 {
                        pos += x[i] as f64;
                    } else {
                        neg += x[i] as f64;
                    }
                }
            }
            assert!((pf as f64 - pos).abs() < 1e-2);
            assert!((nf as f64 - neg).abs() < 1e-2);
        });
    }

    #[test]
    fn code_accumulate_bitwise_matches_scalar_and_naive() {
        check("code_accumulate simd==scalar", 80, |g| {
            let n = word_parity_lens(g);
            let bits = g.usize_in(1, 4) as u32;
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let codes_raw: Vec<u64> = (0..n)
                .map(|_| g.usize_in(0, (1usize << bits) - 1) as u64)
                .collect();
            // pack LSB-first at `bits` per code, straddling words
            let n_words = (n * bits as usize).div_ceil(64);
            let mut codes = vec![0u64; n_words.max(1)];
            for (i, &c) in codes_raw.iter().enumerate() {
                let bitpos = i * bits as usize;
                let (wi, off) = (bitpos >> 6, bitpos & 63);
                codes[wi] |= c << off;
                if off + bits as usize > 64 {
                    codes[wi + 1] |= c >> (64 - off);
                }
            }
            let k = 1usize << bits;
            let mut acc_a = vec![0.0f32; k];
            code_accumulate(&x, &codes, bits, &mut acc_a);
            let mut acc_b = vec![0.0f32; k];
            scalar::code_accumulate(&x, &codes, bits, &mut acc_b);
            assert_eq!(acc_a, acc_b);
            let mut naive = vec![0.0f64; k];
            for i in 0..n {
                naive[codes_raw[i] as usize] += x[i] as f64;
            }
            for c in 0..k {
                assert!((acc_a[c] as f64 - naive[c]).abs() < 1e-2);
            }
        });
    }

    #[test]
    fn popcount_branch_rule_covers_both_forms() {
        // deterministic check that the dense branch really engages: a full
        // mask over 64 elements must equal block − scan(∅) = block exactly
        let x: Vec<f32> = (0..64).map(|i| (i as f32) * 0.25 - 8.0).collect();
        let mut blocks = vec![0.0f32; 1];
        block_sums(&x, &mut blocks);
        let full = masked_sum_pc(&x, &[!0u64], &blocks);
        assert_eq!(full.to_bits(), blocks[0].to_bits());
        // and the sparse branch: a single bit is exactly that element
        let one = masked_sum_pc(&x, &[1u64 << 17], &blocks);
        assert_eq!(one.to_bits(), x[17].to_bits());
        // empty mask sums nothing
        assert_eq!(masked_sum_pc(&x, &[0u64], &blocks), 0.0);
    }
}
