//! Vector primitives used on the LC hot path (penalty gradients, multiplier
//! updates, SGD). All operate on `&[f32]` slices; the compiler autovectorizes
//! the simple loops, and the chunked forms below help it along.

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Dot product.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    // 4 independent accumulators to break the dependency chain.
    let mut acc = [0.0f32; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        acc[0] += x[b] * y[b];
        acc[1] += x[b + 1] * y[b + 1];
        acc[2] += x[b + 2] * y[b + 2];
        acc[3] += x[b + 3] * y[b + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Σᵢ x[idx[i]] — the gather-accumulate primitive of the LUT forward pass
/// ([`crate::serve::engine`]): per-centroid partial sums are gathers, the
/// multiply happens once per centroid instead of once per weight.
#[inline]
pub fn gather_sum(x: &[f32], idx: &[u32]) -> f32 {
    // 4 accumulators, same rationale as `dot`.
    let mut acc = [0.0f32; 4];
    let chunks = idx.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        acc[0] += x[idx[b] as usize];
        acc[1] += x[idx[b + 1] as usize];
        acc[2] += x[idx[b + 2] as usize];
        acc[3] += x[idx[b + 3] as usize];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for &i in &idx[chunks * 4..] {
        s += x[i as usize];
    }
    s
}

/// Sum of all entries.
#[inline]
pub fn sum(x: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        acc[0] += x[b];
        acc[1] += x[b + 1];
        acc[2] += x[b + 2];
        acc[3] += x[b + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for v in &x[chunks * 4..] {
        s += v;
    }
    s
}

/// ||x - y||_2
#[inline]
pub fn l2_dist(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        let d = (a - b) as f64;
        s += d * d;
    }
    s.sqrt() as f32
}

/// ||x||_2
#[inline]
pub fn l2_norm(x: &[f32]) -> f32 {
    let mut s = 0.0f64;
    for a in x {
        s += (*a as f64) * (*a as f64);
    }
    s.sqrt() as f32
}

/// Mean of |x_i| — the optimal binarization scale (Thm A.2).
#[inline]
pub fn mean_abs(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    let s: f64 = x.iter().map(|v| v.abs() as f64).sum();
    (s / x.len() as f64) as f32
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// out = x - y (allocating)
pub fn sub(x: &[f32], y: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a - b).collect()
}

/// z = x - y, written into `out` (non-allocating hot-path form).
#[inline]
pub fn sub_into(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] - y[i];
    }
}

/// out[i] = w[i] - lambda[i] / mu — the shifted weights the C step quantizes.
#[inline]
pub fn shift_by_multipliers(w: &[f32], lambda: &[f32], mu: f32, out: &mut [f32]) {
    debug_assert_eq!(w.len(), lambda.len());
    debug_assert_eq!(w.len(), out.len());
    let inv_mu = 1.0 / mu;
    for i in 0..w.len() {
        out[i] = w[i] - lambda[i] * inv_mu;
    }
}

/// lambda[i] -= mu * (w[i] - wc[i]) — the augmented-Lagrangian multiplier
/// update from §3 of the paper.
#[inline]
pub fn update_multipliers(lambda: &mut [f32], w: &[f32], wc: &[f32], mu: f32) {
    debug_assert_eq!(lambda.len(), w.len());
    debug_assert_eq!(lambda.len(), wc.len());
    for i in 0..lambda.len() {
        lambda[i] -= mu * (w[i] - wc[i]);
    }
}

/// (‖w − wc‖₂, ‖w‖₂) in one pass — the LC feasibility check.
#[inline]
pub fn feasibility(w: &[f32], wc: &[f32]) -> (f32, f32) {
    debug_assert_eq!(w.len(), wc.len());
    let mut dist2 = 0.0f64;
    let mut norm2 = 0.0f64;
    for (a, b) in w.iter().zip(wc) {
        dist2 += ((a - b) as f64).powi(2);
        norm2 += (*a as f64).powi(2);
    }
    (dist2.sqrt() as f32, norm2.sqrt() as f32)
}

/// Fused multiplier update + feasibility: `λ −= μ(w − w_C)` while
/// accumulating (‖w − wc‖₂, ‖w‖₂) in the same pass, so the LC outer loop
/// streams the weight arena once instead of twice.
#[inline]
pub fn update_multipliers_fused(
    lambda: &mut [f32],
    w: &[f32],
    wc: &[f32],
    mu: f32,
) -> (f32, f32) {
    debug_assert_eq!(lambda.len(), w.len());
    debug_assert_eq!(lambda.len(), wc.len());
    let mut dist2 = 0.0f64;
    let mut norm2 = 0.0f64;
    for i in 0..lambda.len() {
        let d = w[i] - wc[i];
        lambda[i] -= mu * d;
        dist2 += (d as f64).powi(2);
        norm2 += (w[i] as f64).powi(2);
    }
    (dist2.sqrt() as f32, norm2.sqrt() as f32)
}

/// Fused Nesterov-momentum update (Lasagne formulation) over a flat
/// parameter slice: `v ← m·v − lr·g; w ← w + m·v − lr·g`.
#[inline]
pub fn nesterov_step(w: &mut [f32], g: &[f32], v: &mut [f32], lr: f32, m: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), v.len());
    for i in 0..w.len() {
        v[i] = m * v[i] - lr * g[i];
        w[i] += m * v[i] - lr * g[i];
    }
}

/// Nesterov update with the LC penalty gradient fused in:
/// the effective gradient is `g + μ(w − w_C) − λ` (paper §3), computed
/// inline so the penalized L step is one pass over the weight arena with
/// zero temporary buffers.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn nesterov_step_penalized(
    w: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    wc: &[f32],
    lambda: &[f32],
    mu: f32,
    lr: f32,
    m: f32,
) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), v.len());
    debug_assert_eq!(w.len(), wc.len());
    debug_assert_eq!(w.len(), lambda.len());
    for i in 0..w.len() {
        let gi = g[i] + mu * (w[i] - wc[i]) - lambda[i];
        v[i] = m * v[i] - lr * gi;
        w[i] += m * v[i] - lr * gi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn dot_matches_naive() {
        check("dot==naive", 100, |g| {
            let n = g.usize_in(0, 67);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let y: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - naive).abs() < 1e-3);
        });
    }

    #[test]
    fn norms_and_distances() {
        let x = [3.0, 4.0];
        assert!((l2_norm(&x) - 5.0).abs() < 1e-6);
        assert!((l2_dist(&x, &[0.0, 0.0]) - 5.0).abs() < 1e-6);
        assert!((mean_abs(&[-2.0, 2.0, 4.0]) - 8.0 / 3.0).abs() < 1e-6);
        assert_eq!(mean_abs(&[]), 0.0);
    }

    #[test]
    fn gather_sum_matches_naive() {
        check("gather_sum==naive", 80, |g| {
            let n = g.usize_in(1, 50);
            let x: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let m = g.usize_in(0, 70);
            let idx: Vec<u32> = (0..m).map(|_| g.usize_in(0, n - 1) as u32).collect();
            let naive: f32 = idx.iter().map(|&i| x[i as usize]).sum();
            assert!((gather_sum(&x, &idx) - naive).abs() < 1e-3);
            let total: f32 = x.iter().sum();
            assert!((sum(&x) - total).abs() < 1e-3);
        });
    }

    #[test]
    fn multiplier_updates_match_formula() {
        check("lambda update", 50, |g| {
            let n = g.usize_in(1, 20);
            let w: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let wc: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let mut lambda: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let before = lambda.clone();
            let mu = g.f32_in(0.01, 10.0);
            update_multipliers(&mut lambda, &w, &wc, mu);
            for i in 0..n {
                assert!((lambda[i] - (before[i] - mu * (w[i] - wc[i]))).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn fused_multiplier_update_matches_split_ops() {
        check("fused == split", 50, |g| {
            let n = g.usize_in(1, 40);
            let w: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let wc: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let lam0: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let mu = g.f32_in(0.01, 5.0);
            let mut lam_a = lam0.clone();
            let (dist, norm) = update_multipliers_fused(&mut lam_a, &w, &wc, mu);
            let mut lam_b = lam0.clone();
            update_multipliers(&mut lam_b, &w, &wc, mu);
            assert_eq!(lam_a, lam_b);
            assert!((dist - l2_dist(&w, &wc)).abs() < 1e-5);
            assert!((norm - l2_norm(&w)).abs() < 1e-5);
            let (d2, n2) = feasibility(&w, &wc);
            assert_eq!(d2, dist);
            assert_eq!(n2, norm);
        });
    }

    #[test]
    fn nesterov_step_matches_scalar_recurrence() {
        let mut w = [1.0f32, -2.0];
        let mut v = [0.1f32, 0.0];
        let g = [0.5f32, -0.5];
        let (lr, m) = (0.1f32, 0.9f32);
        let mut we = w;
        let mut ve = v;
        for i in 0..2 {
            ve[i] = m * ve[i] - lr * g[i];
            we[i] += m * ve[i] - lr * g[i];
        }
        nesterov_step(&mut w, &g, &mut v, lr, m);
        assert_eq!(w, we);
        assert_eq!(v, ve);
    }

    #[test]
    fn penalized_step_reduces_to_plain_when_mu_zero_and_lambda_zero() {
        let g = [0.3f32, -0.7, 0.2];
        let wc = [0.0f32; 3];
        let lam = [0.0f32; 3];
        let mut w_a = [0.5f32, -0.5, 1.0];
        let mut v_a = [0.0f32; 3];
        let mut w_b = w_a;
        let mut v_b = v_a;
        nesterov_step(&mut w_a, &g, &mut v_a, 0.05, 0.9);
        nesterov_step_penalized(&mut w_b, &g, &mut v_b, &wc, &lam, 0.0, 0.05, 0.9);
        assert_eq!(w_a, w_b);
        assert_eq!(v_a, v_b);
    }

    #[test]
    fn shift_consistency() {
        let w = [1.0, -1.0];
        let lam = [0.5, 0.5];
        let mut out = [0.0; 2];
        shift_by_multipliers(&w, &lam, 2.0, &mut out);
        assert_eq!(out, [0.75, -1.25]);
    }
}
