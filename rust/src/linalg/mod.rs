//! Small dense linear-algebra substrate: a row-major matrix type, a blocked
//! multi-threaded sgemm, vector ops used on the LC hot path, and a Cholesky
//! solver for the linear-regression closed-form L step (experiment E2).

pub mod gemm;
pub mod solve;
pub mod vecops;

/// Worker-thread count for the data-parallel kernels, capped at 16 — one
/// policy shared by gemm, the k-means assignment pass and the serve LUT
/// engine. Resolved **once** (the gemm hot path used to re-query
/// `available_parallelism()` on every call) and overridable with the
/// `LCQUANT_THREADS` environment variable (clamped to `1..=16`; useful for
/// pinning benchmarks or forcing deterministic single-threaded runs).
pub fn num_threads() -> usize {
    static NUM_THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *NUM_THREADS.get_or_init(|| {
        std::env::var("LCQUANT_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
            .clamp(1, 16)
    })
}

/// Dense row-major `f32` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_transpose() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 0)], 3.0);
        assert_eq!(t[(0, 1)], 4.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn eye_and_norm() {
        let i = Mat::eye(4);
        assert_eq!(i.fro_norm(), 2.0);
        assert_eq!(i[(2, 2)], 1.0);
        assert_eq!(i[(2, 1)], 0.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        let _ = Mat::from_vec(2, 2, vec![1.0; 3]);
    }
}
