//! Small dense linear-algebra substrate: a row-major matrix type, a blocked
//! multi-threaded sgemm, explicit-SIMD vector ops used on the LC hot path
//! ([`vecops`]), a Cholesky solver for the linear-regression closed-form
//! L step (experiment E2), and the **persistent worker pool** ([`pool`])
//! that every data-parallel kernel in the crate dispatches through.
//!
//! # Threading model
//!
//! There is exactly one thread policy: [`num_threads`] (resolved once,
//! `LCQUANT_THREADS`-overridable, clamped to `1..=16`) sizes the lazily
//! initialized [`pool::global`] worker pool, and the gemm cores, the
//! k-means assignment pass and the serve engine's LUT matvec all fan out
//! through [`pool::run`] / [`pool::run_bands`] with *borrowed* closures.
//! The pool is **multi-task**: up to [`pool::TASK_SLOTS`] dispatches may
//! be live at once (from different threads or nested inside a running
//! part), workers claim parts across all of them, and completion is
//! per-task — so the serve engine pipelines layer bands of concurrent
//! requests instead of serializing behind a single task slot. Nothing in
//! the compute plane spawns a thread after the pool is warm: publishing a
//! task is one futex-backed lock + notify and part claiming is a lock-free
//! generation-tagged counter, all with zero heap allocation, so the
//! threaded per-minibatch L step stays allocation-free end to end (the
//! single-threaded guarantee from the flat-parameter-plane refactor holds
//! for `LCQUANT_THREADS > 1` too — asserted in `rust/tests/flat_params.rs`).
//! Blocking request drivers (serve smoke clients) use [`pool::run_scoped`]
//! — scoped threads — so they never occupy the compute pool they are
//! exercising. Kernels keep their serial fallbacks for small shapes; the
//! pool's inline degenerate path makes `nt == 1` truly thread-free. The
//! dispatch state machine is drawn out in `docs/ARCHITECTURE.md`.

pub mod gemm;
pub mod pool;
pub mod solve;
pub mod vecops;

/// The `LCQUANT_THREADS` parse/clamp policy, separated from the cached
/// resolution so it stays unit-testable (the cache below is process-wide
/// and can only be observed once per process): a parseable value is
/// clamped to `1..=16`, anything else falls back to
/// `available_parallelism`.
pub fn resolve_threads(env: Option<&str>) -> usize {
    env.and_then(|s| s.trim().parse::<usize>().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .clamp(1, 16)
}

/// Worker-thread count for the data-parallel kernels, capped at 16 — one
/// policy shared by the whole compute plane: it sizes [`pool::global`],
/// and the kernels consult it for their serial-fallback thresholds.
/// Resolved **once** (the gemm hot path used to re-query
/// `available_parallelism()` on every call) and overridable with the
/// `LCQUANT_THREADS` environment variable (clamped to `1..=16`; useful for
/// pinning benchmarks or forcing deterministic single-threaded runs).
pub fn num_threads() -> usize {
    static NUM_THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *NUM_THREADS
        .get_or_init(|| resolve_threads(std::env::var("LCQUANT_THREADS").ok().as_deref()))
}

/// Dense row-major `f32` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_transpose() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 0)], 3.0);
        assert_eq!(t[(0, 1)], 4.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn eye_and_norm() {
        let i = Mat::eye(4);
        assert_eq!(i.fro_norm(), 2.0);
        assert_eq!(i[(2, 2)], 1.0);
        assert_eq!(i[(2, 1)], 0.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        let _ = Mat::from_vec(2, 2, vec![1.0; 3]);
    }
}
