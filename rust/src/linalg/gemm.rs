//! Blocked, multi-threaded sgemm and the transposed variants the MLP
//! backward pass needs. Row-major layout throughout.
//!
//! The inner loop is the classic `i,k,j` order (rank-1 update of a C row by
//! a scalar of A times a row of B), which streams both B and C rows and
//! autovectorizes. Blocking over k keeps the active B panel in L1/L2;
//! threading splits the rows of C, which are disjoint, so no locks.
//!
//! The cores (`gemm_into`, `gemm_at_b_into`, `gemm_a_bt_into`) operate on
//! raw `&[f32]` slices with explicit dimensions, so the flat parameter
//! plane ([`crate::nn::params::ParamSet`]) feeds weight-arena views
//! straight in and gradients accumulate straight into a
//! [`crate::nn::params::GradBuffer`] — no `Mat` temporaries on the
//! minibatch step path. The [`Mat`] wrappers below keep the ergonomic API
//! for everything else.
//!
//! Above the `PAR_MIN_ROWS` threshold the cores fan their output row
//! bands out through the persistent worker pool
//! ([`crate::linalg::pool::run_bands`]): no thread spawns, no band table,
//! no heap allocation — the threaded minibatch step path is as
//! allocation-free as the serial one. The rank-1 inner update is the
//! 8-lane [`vecops::axpy`] kernel.

use super::vecops;
use super::{num_threads, pool, Mat};

/// Rows-per-thread threshold below which we stay single-threaded.
const PAR_MIN_ROWS: usize = 64;
/// k-panel block size.
const KC: usize = 256;

/// C(m,n) = A(m,k) · B(k,n), overwriting `c`. All slices row-major.
pub fn gemm_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    c.fill(0.0);
    let do_rows = |rows: std::ops::Range<usize>, cdata: &mut [f32]| {
        for kk in (0..k).step_by(KC) {
            let kend = (kk + KC).min(k);
            for (local_i, i) in rows.clone().enumerate() {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut cdata[local_i * n..(local_i + 1) * n];
                for p in kk..kend {
                    let av = arow[p];
                    if av == 0.0 {
                        continue;
                    }
                    vecops::axpy(av, &b[p * n..(p + 1) * n], crow);
                }
            }
        }
    };
    if m < PAR_MIN_ROWS || num_threads() == 1 {
        do_rows(0..m, c);
        return;
    }
    pool::run_bands(m, n, c, do_rows);
}

/// C(k,n) = Aᵀ·B where A is (m,k) and B is (m,n), overwriting `c`. Used
/// for weight gradients `dW = Xᵀ·dY` without materializing the transpose.
pub fn gemm_at_b_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), m * n, "B shape");
    assert_eq!(c.len(), k * n, "C shape");
    c.fill(0.0);
    // C[p, j] = sum_i A[i, p] * B[i, j] — accumulate rank-1 updates row-wise
    // over i; each i touches all of C, so for threading we split over the
    // columns p of A (rows of C).
    let do_cols = |cols: std::ops::Range<usize>, cdata: &mut [f32]| {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (local_p, p) in cols.clone().enumerate() {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                vecops::axpy(av, brow, &mut cdata[local_p * n..(local_p + 1) * n]);
            }
        }
    };
    if k < PAR_MIN_ROWS || num_threads() == 1 {
        do_cols(0..k, c);
        return;
    }
    pool::run_bands(k, n, c, do_cols);
}

/// C(m,k) = A·Bᵀ where A is (m,n) and B is (k,n), overwriting `c`. Used
/// for input gradients `dX = dY·Wᵀ` without materializing the transpose.
pub fn gemm_a_bt_into(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * n, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * k, "C shape");
    let do_rows = |rows: std::ops::Range<usize>, cdata: &mut [f32]| {
        for (local_i, i) in rows.clone().enumerate() {
            let arow = &a[i * n..(i + 1) * n];
            let crow = &mut cdata[local_i * k..(local_i + 1) * k];
            for j in 0..k {
                crow[j] = vecops::dot(arow, &b[j * n..(j + 1) * n]);
            }
        }
    };
    if m < PAR_MIN_ROWS || num_threads() == 1 {
        do_rows(0..m, c);
        return;
    }
    pool::run_bands(m, k, c, do_rows);
}

/// C(m,n) = A(m,k) · B(k,n). `c` is overwritten.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows, "inner dims");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    gemm_into(a.rows, a.cols, b.cols, &a.data, &b.data, &mut c.data);
}

/// Allocating convenience wrapper.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C(k,n) = Aᵀ(k,m) · B(m,n) where A is (m,k).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "inner dims (rows of A and B)");
    let mut c = Mat::zeros(a.cols, b.cols);
    gemm_at_b_into(a.rows, a.cols, b.cols, &a.data, &b.data, &mut c.data);
    c
}

/// C(m,k) = A(m,n) · Bᵀ(n,k) where B is (k,n).
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "inner dims (cols of A and B)");
    let mut c = Mat::zeros(a.rows, b.rows);
    gemm_a_bt_into(a.rows, a.cols, b.rows, &a.data, &b.data, &mut c.data);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for p in 0..a.cols {
                    s += (a[(i, p)] * b[(p, j)]) as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(&mut m.data, 0.0, 1.0);
        m
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.cols, b.cols);
        for i in 0..a.data.len() {
            assert!(
                (a.data[i] - b.data[i]).abs() < tol,
                "idx {i}: {} vs {}",
                a.data[i],
                b.data[i]
            );
        }
    }

    #[test]
    fn matmul_small_exact() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        check("gemm==naive", 30, |g| {
            let mut rng = g.rng.split();
            let (m, k, n) = (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 40));
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-3);
        });
    }

    #[test]
    fn matmul_threaded_path_matches_naive() {
        let mut rng = Rng::new(31);
        let a = rand_mat(&mut rng, 200, 64);
        let b = rand_mat(&mut rng, 64, 48);
        assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-3);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        check("AtB", 20, |g| {
            let mut rng = g.rng.split();
            let (m, k, n) = (g.usize_in(1, 30), g.usize_in(1, 30), g.usize_in(1, 30));
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, m, n);
            assert_close(&matmul_at_b(&a, &b), &naive(&a.transpose(), &b), 1e-3);
        });
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        check("ABt", 20, |g| {
            let mut rng = g.rng.split();
            let (m, n, k) = (g.usize_in(1, 30), g.usize_in(1, 30), g.usize_in(1, 30));
            let a = rand_mat(&mut rng, m, n);
            let b = rand_mat(&mut rng, k, n);
            assert_close(&matmul_a_bt(&a, &b), &naive(&a, &b.transpose()), 1e-3);
        });
    }

    #[test]
    fn at_b_threaded_path() {
        let mut rng = Rng::new(77);
        let a = rand_mat(&mut rng, 128, 100);
        let b = rand_mat(&mut rng, 128, 32);
        assert_close(&matmul_at_b(&a, &b), &naive(&a.transpose(), &b), 2e-3);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(5);
        let a = rand_mat(&mut rng, 10, 10);
        assert_close(&matmul(&a, &Mat::eye(10)), &a, 1e-6);
        assert_close(&matmul(&Mat::eye(10), &a), &a, 1e-6);
    }

    #[test]
    fn slice_cores_overwrite_dirty_output() {
        // the `_into` forms must not accumulate into stale contents
        let mut rng = Rng::new(9);
        let a = rand_mat(&mut rng, 7, 5);
        let b = rand_mat(&mut rng, 5, 6);
        let want = naive(&a, &b);
        let mut c = vec![123.0f32; 7 * 6];
        gemm_into(7, 5, 6, &a.data, &b.data, &mut c);
        assert_close(&Mat::from_vec(7, 6, c), &want, 1e-4);

        let b2 = rand_mat(&mut rng, 7, 4);
        let want2 = naive(&a.transpose(), &b2);
        let mut c2 = vec![-9.0f32; 5 * 4];
        gemm_at_b_into(7, 5, 4, &a.data, &b2.data, &mut c2);
        assert_close(&Mat::from_vec(5, 4, c2), &want2, 1e-4);

        let b3 = rand_mat(&mut rng, 9, 5);
        let want3 = naive(&a, &b3.transpose());
        let mut c3 = vec![42.0f32; 7 * 9];
        gemm_a_bt_into(7, 5, 9, &a.data, &b3.data, &mut c3);
        assert_close(&Mat::from_vec(7, 9, c3), &want3, 1e-4);
    }
}
