//! Persistent worker pool — the one threading substrate of the compute
//! plane, with a **multi-task work queue**.
//!
//! Every data-parallel kernel in the crate (the gemm cores, the k-means
//! assignment pass, the serve engine's LUT matvec, the smoke-client
//! drivers) used to fan out with a fresh `std::thread::scope`, paying
//! ~50µs of spawn latency plus a handful of heap allocations *per call*.
//! This module replaces all of those call sites with one lazily-initialized
//! pool of long-lived workers. Since the multi-task refactor the pool runs
//! **several tasks concurrently**: dispatchers enqueue into a small fixed
//! ring of task slots ([`TASK_SLOTS`]) and workers claim parts across *all*
//! live tasks, so the serve engine can pipeline layer bands of different
//! requests instead of serializing behind whichever request dispatched
//! first.
//!
//! * **Sizing** — [`global`] spawns `num_threads() − 1` workers on first
//!   use (the dispatching caller is always participant #0, so a 1-thread
//!   configuration spawns nothing and every dispatch runs inline).
//!   [`crate::linalg::num_threads`] honors `LCQUANT_THREADS`, clamped to
//!   `1..=16`.
//! * **Dispatch** — [`Pool::run`] hands a *borrowed* closure to the
//!   workers: the closure is type-erased to a `(data, trampoline)` pointer
//!   pair that lives on the dispatcher's stack, published into a free task
//!   slot, and the dispatcher blocks until every part of *its* task has
//!   finished, so non-`'static` captures (weight arenas, gradient buffers,
//!   `&self`) stay sound. Publishing is one mutex lock + condvar notify
//!   (futex-backed on Linux: **no allocation**, no spawn); parts are
//!   claimed lock-free from a generation-tagged atomic counter per slot, so
//!   uneven bands load-balance and stale claims on a recycled slot are
//!   impossible.
//! * **Multi-task** — up to [`TASK_SLOTS`] tasks are live at once. Workers
//!   scan the ring starting from a **claim hint** (the last-published slot
//!   index, advisory) so a wake-up probes the fresh task first instead of
//!   sweeping from slot 0 every time, and take parts from any live task;
//!   completion is
//!   **per-task** (a mutex+condvar pair per slot — a futex per slot on
//!   Linux) rather than a pool-wide epoch barrier, so one long task never
//!   gates another task's completion. Every dispatcher participates in its
//!   own task, which also makes the queue deadlock-free: a task drains even
//!   if every worker is busy elsewhere.
//! * **Exhaustion & reentrancy** — a dispatch that finds no free slot
//!   (including deeply nested dispatch storms) degrades to inline execution
//!   on the caller; it never blocks waiting for a slot, so slot exhaustion
//!   cannot deadlock. A *nested* dispatch from inside a running part takes
//!   its own slot when one is free — nested parallelism now actually fans
//!   out instead of always running inline.
//! * **Bands** — [`Pool::run_bands`] is the row-band form shared by the
//!   gemm cores and the LUT engine: it splits an `m × n` output buffer
//!   into at most [`Pool::width`] contiguous row bands by index arithmetic
//!   (no per-call band `Vec`) and hands each part `(row_range, &mut band)`.
//! * **Panics** — a panicking part poisons neither its own task, its
//!   siblings, nor any *concurrent* task: remaining parts still run, the
//!   owning dispatcher re-raises after its task completes, other tasks are
//!   untouched, and the workers survive for the next dispatch.
//!
//! [`run_scoped`] is the second dispatch flavor, for **blocking** drivers
//! (the serve smoke clients): real scoped threads per part, so blocking
//! parts neither cap out at the pool width nor pin a task slot while they
//! sleep. [`DisjointMut`] is the escape hatch for call sites whose
//! per-part mutable state is not a contiguous row band (k-means assignment
//! chunks + reduction slots, per-client handles): it hands out disjoint
//! `&mut` sub-slices of one buffer by index, with the disjointness
//! obligation on the caller.
//!
//! The dispatch state machine is documented in prose form in
//! `docs/ARCHITECTURE.md` (§ "Pool dispatch state machine").
#![warn(missing_docs)]

use std::ops::Range;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Size of the task-slot ring: the maximum number of concurrently live
/// tasks per pool. Small on purpose — live tasks beyond the worker count
/// only add scan cost, and a dispatch that finds the ring full simply runs
/// inline. Eight covers the deepest realistic stack: a handful of
/// pipelined serve requests plus a nested kernel or two.
pub const TASK_SLOTS: usize = 8;

/// Total worker threads ever spawned by any [`Pool`] in this process.
/// Tests use the delta across a measured region to assert "zero thread
/// spawns after warm-up" on the threaded step path.
static SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total worker threads ever spawned by any pool in this process (the
/// zero-spawn-after-warm-up test hook; see `SPAWNED`).
pub fn total_spawned() -> u64 {
    SPAWNED.load(Ordering::Relaxed)
}

/// Worker lanes tracked by the per-worker profile counters. Workers with
/// an id past the last lane fold into it (wider pools are rare; the tail
/// lane stays meaningful as "everything else").
pub const PROFILE_WORKERS: usize = 16;

// Process-wide profiling counters (relaxed; observability only — shared by
// every pool in the process, like `SPAWNED`).
#[allow(clippy::declare_interior_mutable_const)]
const PROFILE_ZERO: AtomicU64 = AtomicU64::new(0);
/// Parts executed by each worker lane (dispatcher-claimed parts are not
/// counted here — they run on the caller's thread).
static PARTS_CLAIMED: [AtomicU64; PROFILE_WORKERS] = [PROFILE_ZERO; PROFILE_WORKERS];
/// Dispatches that ran inline because they were trivial (one part) or the
/// pool has no workers.
static INLINE_DISPATCHES: AtomicU64 = AtomicU64::new(0);
/// Dispatches that ran inline because every task slot was occupied.
static SLOT_EXHAUSTED: AtomicU64 = AtomicU64::new(0);

/// Point-in-time pool profile: where parts ran and how often dispatch
/// degraded to inline execution. All-time, process-wide.
#[derive(Clone, Debug)]
pub struct PoolProfile {
    /// Parts executed per worker lane (see [`PROFILE_WORKERS`]).
    pub parts_claimed: Vec<u64>,
    /// Inline dispatches (one part / no workers).
    pub inline_dispatches: u64,
    /// Inline fallbacks because the task ring was full.
    pub slot_exhausted: u64,
    /// Worker threads ever spawned ([`total_spawned`]).
    pub total_spawned: u64,
}

impl PoolProfile {
    /// Render for the observability snapshot.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            (
                "parts_claimed",
                Json::Arr(
                    self.parts_claimed.iter().map(|&n| Json::from(n as usize)).collect(),
                ),
            ),
            ("inline_dispatches", Json::from(self.inline_dispatches as usize)),
            ("slot_exhausted", Json::from(self.slot_exhausted as usize)),
            ("total_spawned", Json::from(self.total_spawned as usize)),
        ])
    }
}

/// Snapshot the process-wide pool profile counters.
pub fn profile() -> PoolProfile {
    PoolProfile {
        parts_claimed: PARTS_CLAIMED.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
        inline_dispatches: INLINE_DISPATCHES.load(Ordering::Relaxed),
        slot_exhausted: SLOT_EXHAUSTED.load(Ordering::Relaxed),
        total_spawned: total_spawned(),
    }
}

/// A dispatched task: a type-erased borrowed closure plus its part count.
/// The raw pointer targets the dispatcher's stack frame; it stays valid
/// because [`Pool::run`] does not return (or unwind) until every part of
/// its task has completed.
#[derive(Clone, Copy)]
struct Task {
    data: *const (),
    call: unsafe fn(*const (), usize),
    parts: usize,
}

// SAFETY: the closure behind `data` is `Sync` (enforced by `Pool::run`'s
// bound) and outlives the dispatch (the dispatcher blocks until the task's
// last part completes).
unsafe impl Send for Task {}

unsafe fn trampoline<F: Fn(usize) + Sync>(data: *const (), part: usize) {
    (*data.cast::<F>())(part)
}

/// One entry of the task ring. Control-plane fields (`Ctrl::tasks`,
/// `Ctrl::gens`) live under the pool's control mutex; the fields here are
/// the lock-free data plane of a live task.
struct Slot {
    /// Packed claim word: `generation-tag (high 32) | next-part (low 32)`.
    /// Parts are claimed by a gen-checked CAS increment, so a worker
    /// holding a stale task copy can never claim into a recycled slot
    /// (the tag changes on every publish).
    claim: AtomicU64,
    /// Parts of the current generation not yet *completed*. The decrement
    /// that reaches zero retires the task and wakes the dispatcher.
    remaining: AtomicUsize,
    /// Set when any part of the current generation panicked; read by the
    /// owning dispatcher after completion, before the slot is freed.
    panicked: AtomicBool,
    /// Last generation whose task fully completed. Paired with `done_cv`,
    /// this is the per-task completion futex.
    done: Mutex<u64>,
    /// The owning dispatcher waits here for `done >= its generation`.
    done_cv: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            claim: AtomicU64::new(0),
            remaining: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
        }
    }

    /// Claim the next unclaimed part of generation `tag`, lock-free.
    /// Fails once the task's parts are exhausted or the slot has been
    /// republished for a newer generation.
    fn try_claim(&self, tag: u32, parts: usize) -> Option<usize> {
        let mut cur = self.claim.load(Ordering::Acquire);
        loop {
            if (cur >> 32) as u32 != tag {
                return None;
            }
            let next = (cur & 0xffff_ffff) as usize;
            if next >= parts {
                return None;
            }
            match self.claim.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(next),
                Err(c) => cur = c,
            }
        }
    }

    /// Mark one claimed part complete; the finishing participant (worker
    /// *or* dispatcher) retires the task and wakes the owning dispatcher.
    fn finish_part(&self, gen: u64) {
        if self.remaining.fetch_sub(1, Ordering::Release) == 1 {
            // Synchronize with every other participant's part writes
            // before the dispatcher can observe completion.
            fence(Ordering::Acquire);
            let mut done = self.done.lock().unwrap();
            *done = gen;
            self.done_cv.notify_all();
        }
    }
}

/// Control plane, guarded by `Shared::ctrl`: which slots hold live tasks
/// and at which generation. Task bodies are *copied out* under this lock
/// and then executed lock-free.
struct Ctrl {
    /// `Some(task)` while the slot's current generation is live (published
    /// by a dispatcher, cleared by the same dispatcher after completion).
    tasks: [Option<Task>; TASK_SLOTS],
    /// Per-slot publish generation; its low 32 bits tag `Slot::claim`.
    gens: [u64; TASK_SLOTS],
    shutdown: bool,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    /// Workers wait here for new live tasks.
    work_cv: Condvar,
    slots: [Slot; TASK_SLOTS],
    /// Claim hint: the most recently published slot index. Workers start
    /// their ring scan here instead of always from slot 0, so a wake-up
    /// finds the fresh task on its first probe instead of sweeping over
    /// however many stale/busy slots precede it. Purely advisory (Relaxed;
    /// a stale hint only costs scan steps, never correctness).
    hint: AtomicUsize,
}

/// Run parts of one task until its claim counter is exhausted, catching
/// per-part panics so a panicking part neither kills the worker nor skips
/// the completion accounting of its siblings. Returns how many parts this
/// call executed (feeds the per-worker profile lanes).
fn run_claimed_parts(slot: &Slot, task: Task, tag: u32, gen: u64) -> u64 {
    let mut ran = 0u64;
    while let Some(part) = slot.try_claim(tag, task.parts) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: `task.data` is live for the whole dispatch: claiming
            // succeeded, so the owning dispatcher is still blocked.
            unsafe { (task.call)(task.data, part) };
        }));
        if result.is_err() {
            slot.panicked.store(true, Ordering::Release);
        }
        slot.finish_part(gen);
        ran += 1;
    }
    ran
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    let lane = &PARTS_CLAIMED[id.min(PROFILE_WORKERS - 1)];
    loop {
        // Find a live task with unclaimed parts (or sleep until one is
        // published). Task bodies are copied out under the control lock,
        // which is also what makes the publisher's plain-field writes
        // visible here.
        let found = {
            let mut ctrl = shared.ctrl.lock().unwrap();
            loop {
                if ctrl.shutdown {
                    return;
                }
                let mut hit = None;
                // start the ring sweep at the last-published slot (claim
                // hint) so a fresh wake probes the new task first
                let start = shared.hint.load(Ordering::Relaxed) % TASK_SLOTS;
                for off in 0..TASK_SLOTS {
                    let i = (start + off) % TASK_SLOTS;
                    if let Some(task) = ctrl.tasks[i] {
                        let tag = ctrl.gens[i] as u32;
                        let cur = shared.slots[i].claim.load(Ordering::Relaxed);
                        if (cur >> 32) as u32 == tag
                            && ((cur & 0xffff_ffff) as usize) < task.parts
                        {
                            hit = Some((i, task, ctrl.gens[i]));
                            break;
                        }
                    }
                }
                if let Some(found) = hit {
                    break found;
                }
                ctrl = shared.work_cv.wait(ctrl).unwrap();
            }
        };
        let (i, task, gen) = found;
        let ran = run_claimed_parts(&shared.slots[i], task, gen as u32, gen);
        if ran > 0 {
            lane.fetch_add(ran, Ordering::Relaxed);
        }
        // Loop back: rescan for more work across *all* live tasks.
    }
}

/// A persistent worker pool with a multi-task queue (see the module docs).
/// Library code uses the process-wide [`global`] pool; tests build private
/// pools of arbitrary width with [`Pool::new`].
pub struct Pool {
    shared: Arc<Shared>,
    /// Spawned workers — participants minus the dispatching caller.
    n_workers: usize,
}

impl Pool {
    /// Build a pool with `threads` total participants (the caller counts
    /// as one, so this spawns `threads − 1` workers; `threads == 1` spawns
    /// nothing and all dispatches run inline).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            ctrl: Mutex::new(Ctrl {
                tasks: [None; TASK_SLOTS],
                gens: [0; TASK_SLOTS],
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            slots: std::array::from_fn(|_| Slot::new()),
            hint: AtomicUsize::new(0),
        });
        let n_workers = threads - 1;
        for i in 0..n_workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("lcq-pool-{i}"))
                .spawn(move || worker_loop(sh, i))
                .expect("spawn pool worker");
            SPAWNED.fetch_add(1, Ordering::Relaxed);
        }
        Pool { shared, n_workers }
    }

    /// Maximum concurrent participants of one task (workers + caller).
    pub fn width(&self) -> usize {
        self.n_workers + 1
    }

    /// Run `f(part)` for every `part` in `0..parts`, fanned out across the
    /// workers and the calling thread; returns when all parts are done.
    ///
    /// The closure is borrowed, not `'static`: captures live on the
    /// caller's stack for the whole dispatch. Parts are claimed from a
    /// generation-tagged counter, so they load-balance but have no
    /// ordering guarantee. Up to [`TASK_SLOTS`] dispatches may be live
    /// concurrently — from different threads *or* nested from inside a
    /// running part — and workers serve all of them. Degenerate cases
    /// (one part, a 1-thread pool, a full task ring) run inline on the
    /// caller in part order. After warm-up this path performs **zero heap
    /// allocations and zero thread spawns**.
    pub fn run<F: Fn(usize) + Sync>(&self, parts: usize, f: F) {
        debug_assert!(parts < u32::MAX as usize, "part count overflows the claim tag");
        if parts == 0 {
            return;
        }
        if parts == 1 || self.n_workers == 0 {
            INLINE_DISPATCHES.fetch_add(1, Ordering::Relaxed);
            for part in 0..parts {
                f(part);
            }
            return;
        }
        let task =
            Task { data: (&f as *const F).cast::<()>(), call: trampoline::<F>, parts };
        // Acquire and publish a task slot (one lock, one notify).
        let (slot_idx, gen) = {
            let mut ctrl = self.shared.ctrl.lock().unwrap();
            let Some(i) = (0..TASK_SLOTS).find(|&i| ctrl.tasks[i].is_none()) else {
                // ring full: degrade to inline execution — never block on a
                // slot (a blocked dispatcher could itself be occupying one)
                drop(ctrl);
                SLOT_EXHAUSTED.fetch_add(1, Ordering::Relaxed);
                for part in 0..parts {
                    f(part);
                }
                return;
            };
            let gen = ctrl.gens[i] + 1;
            ctrl.gens[i] = gen;
            let slot = &self.shared.slots[i];
            slot.remaining.store(parts, Ordering::Relaxed);
            slot.panicked.store(false, Ordering::Relaxed);
            slot.claim.store((gen as u32 as u64) << 32, Ordering::Release);
            ctrl.tasks[i] = Some(task);
            self.shared.hint.store(i, Ordering::Relaxed);
            self.shared.work_cv.notify_all();
            (i, gen)
        };
        let slot = &self.shared.slots[slot_idx];
        let tag = gen as u32;
        // Participate in our own task. A panic in `f` on this thread is
        // held until the task completes — the workers still hold pointers
        // into this stack frame, so the unwind must not pass the wait
        // below. Remaining parts still run (matching worker behaviour).
        let mut my_panic: Option<Box<dyn std::any::Any + Send>> = None;
        while let Some(part) = slot.try_claim(tag, parts) {
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(part))) {
                Ok(()) => {}
                Err(payload) => {
                    slot.panicked.store(true, Ordering::Release);
                    if my_panic.is_none() {
                        my_panic = Some(payload);
                    }
                }
            }
            slot.finish_part(gen);
        }
        // Per-task completion wait: the finisher (possibly this thread)
        // stores our generation into the slot's done word.
        {
            let mut done = slot.done.lock().unwrap();
            while *done < gen {
                done = slot.done_cv.wait(done).unwrap();
            }
        }
        // Free the slot only now: `panicked` must be read before any
        // republish could reset it.
        let worker_panicked = slot.panicked.swap(false, Ordering::Acquire);
        {
            let mut ctrl = self.shared.ctrl.lock().unwrap();
            ctrl.tasks[slot_idx] = None;
        }
        if let Some(payload) = my_panic {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("pool worker panicked during a dispatched task");
        }
    }

    /// Row-banded dispatch over an `m × n` row-major output buffer: `out`
    /// is split into at most [`Pool::width`] contiguous row bands (by
    /// index arithmetic — no band table is allocated) and `f(rows, band)`
    /// runs once per band with `band.len() == rows.len() * n`.
    pub fn run_bands<F>(&self, m: usize, n: usize, out: &mut [f32], f: F)
    where
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        assert_eq!(out.len(), m * n, "band buffer shape");
        if m == 0 {
            return;
        }
        let parts = self.width().min(m);
        let per = m.div_ceil(parts);
        let bands = DisjointMut::new(out);
        self.run(parts, |part| {
            let start = part * per;
            let end = m.min(start + per);
            if start < end {
                // SAFETY: row bands are disjoint across parts by
                // construction, and each part index runs exactly once.
                let band = unsafe { bands.take(start * n..end * n) };
                f(start..end, band);
            }
        });
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        let mut ctrl = self.shared.ctrl.lock().unwrap();
        ctrl.shutdown = true;
        self.shared.work_cv.notify_all();
        // Workers wake, observe `shutdown` and return; they own the
        // `Shared` via `Arc`, so no join is needed.
    }
}

/// The process-wide pool used by the library kernels, sized by
/// [`crate::linalg::num_threads`] on first use.
pub fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool::new(super::num_threads()))
}

/// [`Pool::run`] on the [`global`] pool.
pub fn run<F: Fn(usize) + Sync>(parts: usize, f: F) {
    global().run(parts, f)
}

/// [`Pool::run_bands`] on the [`global`] pool.
pub fn run_bands<F>(m: usize, n: usize, out: &mut [f32], f: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    global().run_bands(m, n, out, f)
}

/// Scoped-thread fan-out for **blocking** drivers (serve smoke clients,
/// request generators): every part gets its own OS thread for the duration
/// of the call.
///
/// Unlike [`run`], parts here may block — on channel replies, I/O, the
/// micro-batcher's `max_wait` window — without capping concurrency at the
/// pool width or starving the compute plane: a blocking part parked inside
/// a pool task would pin one of the [`TASK_SLOTS`] task slots and a worker
/// for its whole sleep. Spawn cost is irrelevant next to the blocking time
/// these drivers measure; hot compute kernels belong on [`run`].
pub fn run_scoped<F: Fn(usize) + Sync>(parts: usize, f: F) {
    std::thread::scope(|s| {
        for part in 0..parts {
            let fref = &f;
            s.spawn(move || fref(part));
        }
    });
}

/// Hands out disjoint `&mut` sub-slices of one buffer by index — the
/// per-part mutable state of pool tasks whose partition is not a
/// contiguous row band (k-means assignment chunks + per-part reduction
/// slots, per-client handles in the serve drivers).
pub struct DisjointMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: moving/sharing the handle across threads only moves the raw
// pointer; actual access goes through `take`, whose disjointness
// obligation is documented there. `T: Send` because the referents are
// mutated from worker threads.
unsafe impl<T: Send> Send for DisjointMut<'_, T> {}
unsafe impl<T: Send> Sync for DisjointMut<'_, T> {}

impl<'a, T> DisjointMut<'a, T> {
    /// Wrap a buffer; the borrow lasts as long as the handle, so the
    /// underlying slice cannot be touched while parts hold sub-slices.
    pub fn new(slice: &'a mut [T]) -> DisjointMut<'a, T> {
        DisjointMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Mutable access to `range` of the wrapped buffer.
    ///
    /// # Safety
    /// Ranges taken by concurrently running parts must be pairwise
    /// disjoint, and no range may be taken twice while a previous
    /// sub-slice for an overlapping range is still alive.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn take(&self, range: Range<usize>) -> &'a mut [T] {
        assert!(range.start <= range.end && range.end <= self.len, "part out of range");
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_part_runs_exactly_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicU32> = (0..97).map(|_| AtomicU32::new(0)).collect();
        pool.run(hits.len(), |p| {
            hits[p].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_pool_runs_inline_in_order() {
        let pool = Pool::new(1);
        assert_eq!(pool.width(), 1);
        let order = Mutex::new(Vec::new());
        pool.run(8, |p| order.lock().unwrap().push(p));
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn borrowed_captures_are_visible_after_dispatch() {
        let pool = Pool::new(3);
        let input: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 64];
        let parts = DisjointMut::new(&mut out);
        pool.run(8, |p| {
            let band = unsafe { parts.take(p * 8..(p + 1) * 8) };
            for (o, i) in band.iter_mut().zip(&input[p * 8..(p + 1) * 8]) {
                *o = 2.0 * i;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 2.0 * i as f32);
        }
    }

    #[test]
    fn run_bands_covers_every_row_once() {
        let pool = Pool::new(4);
        for m in [1usize, 2, 3, 7, 16, 33] {
            let n = 5;
            let mut out = vec![-1.0f32; m * n];
            pool.run_bands(m, n, &mut out, |rows, band| {
                assert_eq!(band.len(), rows.len() * n);
                for (local, r) in rows.enumerate() {
                    for v in &mut band[local * n..(local + 1) * n] {
                        assert_eq!(*v, -1.0, "row {r} written twice");
                        *v = r as f32;
                    }
                }
            });
            for r in 0..m {
                for c in 0..n {
                    assert_eq!(out[r * n + c], r as f32, "row {r} col {c}");
                }
            }
        }
    }

    #[test]
    fn nested_dispatch_completes_and_covers_all_parts() {
        // Nested dispatch from inside a running part now *enqueues* into a
        // free task slot (inline only when the ring is full) — either way
        // the count must be exact and nothing may deadlock.
        let pool = Pool::new(4);
        let total = AtomicU32::new(0);
        pool.run(4, |_| {
            pool.run(5, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn pool_survives_a_panicking_part() {
        let pool = Pool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(6, |p| {
                if p == 3 {
                    panic!("part 3 exploded");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the dispatcher");
        // the pool keeps working afterwards
        let ok = AtomicU32::new(0);
        pool.run(6, |_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn zero_parts_is_a_noop() {
        let pool = Pool::new(2);
        pool.run(0, |_| panic!("must not run"));
        pool.run_bands(0, 4, &mut [], |_, _| panic!("must not run"));
    }

    #[test]
    fn profile_counters_observe_dispatch_modes() {
        // Counters are process-wide, so assert on deltas (other tests run
        // concurrently and may also bump them).
        let before = profile();
        assert_eq!(before.parts_claimed.len(), PROFILE_WORKERS);

        // Inline path: single-part dispatch.
        let pool = Pool::new(4);
        pool.run(1, |_| {});
        let after_inline = profile();
        assert!(after_inline.inline_dispatches > before.inline_dispatches);

        // Worker path: enough parts that at least one lands off-caller.
        let solo = Pool::new(1);
        for _ in 0..4 {
            solo.run(64, |_| std::thread::yield_now());
            pool.run(64, |_| std::thread::yield_now());
        }
        let after = profile();
        let claimed_before: u64 = before.parts_claimed.iter().sum();
        let claimed_after: u64 = after.parts_claimed.iter().sum();
        assert!(
            claimed_after > claimed_before,
            "workers claimed no parts across 4×64-part dispatches"
        );
        assert!(after.total_spawned >= 3, "Pool::new(4) spawned 3 workers");
        // slot_exhausted only moves under ring pressure; just check it
        // never runs backwards.
        assert!(after.slot_exhausted >= before.slot_exhausted);
    }

    #[test]
    fn slot_generations_do_not_leak_across_dispatches() {
        // Hammer one pool with many sequential dispatches so slots are
        // recycled many times; every dispatch must still be exact.
        let pool = Pool::new(3);
        for round in 0..200u32 {
            let hits: Vec<AtomicU32> = (0..7).map(|_| AtomicU32::new(0)).collect();
            pool.run(hits.len(), |p| {
                hits[p].fetch_add(1, Ordering::Relaxed);
            });
            for (p, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "round {round} part {p}");
            }
        }
    }
}
