//! Persistent worker pool — the one threading substrate of the compute
//! plane.
//!
//! Every data-parallel kernel in the crate (the gemm cores, the k-means
//! assignment pass, the serve engine's LUT matvec, the smoke-client
//! drivers) used to fan out with a fresh `std::thread::scope`, paying
//! ~50µs of spawn latency plus a handful of heap allocations *per call* —
//! on the per-minibatch L-step path that was the last remaining source of
//! allocation and by far the largest fixed cost. This module replaces all
//! of those call sites with one lazily-initialized pool of long-lived
//! workers:
//!
//! * **Sizing** — [`global`] spawns `num_threads() − 1` workers on first
//!   use (the dispatching caller is always participant #0, so a 1-thread
//!   configuration spawns nothing and every dispatch runs inline).
//!   [`crate::linalg::num_threads`] honors `LCQUANT_THREADS`, clamped to
//!   `1..=16`.
//! * **Dispatch** — [`Pool::run`] hands a *borrowed* closure to the
//!   workers: the closure is type-erased to a `(data, trampoline)` pointer
//!   pair that lives on the dispatcher's stack, and the dispatcher blocks
//!   until every worker has finished, so non-`'static` captures (weight
//!   arenas, gradient buffers, `&self`) are sound — the existing band
//!   kernels ported unchanged. Release/collect is a mutex+condvar epoch
//!   handshake (futex-backed on Linux: **no allocation**, no spawn), and
//!   parts are pulled from one shared atomic counter so uneven bands
//!   load-balance.
//! * **Reentrancy** — one task is in flight at a time (`dispatch` lock).
//!   A dispatch from inside a running task — same thread or a worker —
//!   fails the `try_lock` and simply runs inline on the caller, so nested
//!   parallelism degrades gracefully instead of deadlocking.
//! * **Bands** — [`Pool::run_bands`] is the row-band form shared by the
//!   gemm cores and the LUT engine: it splits an `m × n` output buffer
//!   into at most [`Pool::width`] contiguous row bands by index arithmetic
//!   (no per-call band `Vec` — the old `row_bands` allocation is gone) and
//!   hands each part `(row_range, &mut band)`.
//! * **Panics** — a panicking part poisons neither the pool nor its
//!   siblings: remaining parts still run, the dispatcher re-raises after
//!   the barrier, and the workers survive for the next dispatch.
//!
//! [`run_scoped`] is the second dispatch flavor, for **blocking** drivers
//! (the serve smoke clients): real scoped threads per part, so blocking
//! parts neither cap out at the pool width nor hold the pool's task slot
//! while the kernels they exercise need it. [`DisjointMut`] is the escape
//! hatch for call sites whose per-part mutable state is not a contiguous
//! row band (k-means assignment chunks, per-client handles): it hands out
//! disjoint `&mut` sub-slices of one buffer by index, with the
//! disjointness obligation on the caller.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Total worker threads ever spawned by any [`Pool`] in this process.
/// Tests use the delta across a measured region to assert "zero thread
/// spawns after warm-up" on the threaded step path.
static SPAWNED: AtomicU64 = AtomicU64::new(0);

/// See [`SPAWNED`].
pub fn total_spawned() -> u64 {
    SPAWNED.load(Ordering::Relaxed)
}

/// A dispatched task: a type-erased borrowed closure plus its part count.
/// The raw pointer targets the dispatcher's stack frame; it stays valid
/// because [`Pool::run`] does not return (or unwind) until every worker
/// has left the task.
#[derive(Clone, Copy)]
struct Task {
    data: *const (),
    call: unsafe fn(*const (), usize),
    parts: usize,
}

// SAFETY: the closure behind `data` is `Sync` (enforced by `Pool::run`'s
// bound) and outlives the dispatch (the dispatcher blocks on the barrier).
unsafe impl Send for Task {}

unsafe fn trampoline<F: Fn(usize) + Sync>(data: *const (), part: usize) {
    (*data.cast::<F>())(part)
}

struct State {
    /// Bumped once per dispatched task; a worker runs each epoch once.
    epoch: u64,
    task: Option<Task>,
    /// Workers still inside the current task.
    active: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new epoch.
    work_cv: Condvar,
    /// The dispatcher waits here for `active == 0`.
    done_cv: Condvar,
    /// Next unclaimed part index of the current task.
    next: AtomicUsize,
    /// Set by a worker whose part panicked; the dispatcher re-raises.
    panicked: AtomicBool,
}

/// Claim and run parts until the counter runs past `task.parts`.
fn run_parts(shared: &Shared, task: Task) {
    loop {
        let part = shared.next.fetch_add(1, Ordering::Relaxed);
        if part >= task.parts {
            return;
        }
        // SAFETY: `task.data` is live for the whole dispatch (see `Task`).
        unsafe { (task.call)(task.data, part) };
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.task.expect("epoch bumped without a task");
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_parts(&shared, task);
        }));
        if result.is_err() {
            shared.panicked.store(true, Ordering::Release);
        }
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_one();
        }
    }
}

/// A persistent worker pool (see the module docs). Library code uses the
/// process-wide [`global`] pool; tests build private pools of arbitrary
/// width with [`Pool::new`].
pub struct Pool {
    shared: Arc<Shared>,
    /// Spawned workers — participants minus the dispatching caller.
    n_workers: usize,
    /// One task in flight at a time; contenders (including reentrant
    /// dispatches from inside a task) run inline instead of blocking.
    /// An atomic flag rather than a `Mutex` so a panicking dispatch can
    /// never poison the pool (the guard resets it during unwinding).
    busy: AtomicBool,
}

/// Resets [`Pool::busy`] when the dispatch ends — including by panic.
struct BusyGuard<'a>(&'a AtomicBool);

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

impl Pool {
    /// Build a pool with `threads` total participants (the caller counts
    /// as one, so this spawns `threads − 1` workers; `threads == 1` spawns
    /// nothing and all dispatches run inline).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, task: None, active: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        });
        let n_workers = threads - 1;
        for i in 0..n_workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("lcq-pool-{i}"))
                .spawn(move || worker_loop(sh))
                .expect("spawn pool worker");
            SPAWNED.fetch_add(1, Ordering::Relaxed);
        }
        Pool { shared, n_workers, busy: AtomicBool::new(false) }
    }

    /// Maximum concurrent participants of one task (workers + caller).
    pub fn width(&self) -> usize {
        self.n_workers + 1
    }

    /// Run `f(part)` for every `part` in `0..parts`, fanned out across the
    /// workers and the calling thread; returns when all parts are done.
    ///
    /// The closure is borrowed, not `'static`: captures live on the
    /// caller's stack for the whole dispatch. Parts are claimed from a
    /// shared counter, so they load-balance but have no ordering
    /// guarantee. Degenerate cases (one part, a 1-thread pool, a dispatch
    /// already in flight — including from inside a running task) run
    /// inline on the caller in part order. After warm-up this path
    /// performs **zero heap allocations and zero thread spawns**.
    pub fn run<F: Fn(usize) + Sync>(&self, parts: usize, f: F) {
        if parts == 0 {
            return;
        }
        if parts == 1 || self.n_workers == 0 {
            for part in 0..parts {
                f(part);
            }
            return;
        }
        if self
            .busy
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            // busy (or reentrant): degrade to inline execution
            for part in 0..parts {
                f(part);
            }
            return;
        }
        let _guard = BusyGuard(&self.busy);
        let task =
            Task { data: (&f as *const F).cast::<()>(), call: trampoline::<F>, parts };
        {
            let mut st = self.shared.state.lock().unwrap();
            self.shared.next.store(0, Ordering::Relaxed);
            self.shared.panicked.store(false, Ordering::Relaxed);
            st.task = Some(task);
            st.epoch += 1;
            st.active = self.n_workers;
            self.shared.work_cv.notify_all();
        }
        // Participate — but even if `f` panics here, the workers still hold
        // pointers into this stack frame, so the unwind must not pass the
        // barrier below.
        let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_parts(&self.shared, task);
        }));
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.task = None;
        drop(st);
        if let Err(payload) = mine {
            std::panic::resume_unwind(payload);
        }
        if self.shared.panicked.swap(false, Ordering::Acquire) {
            panic!("pool worker panicked during a dispatched task");
        }
    }

    /// Row-banded dispatch over an `m × n` row-major output buffer: `out`
    /// is split into at most [`Pool::width`] contiguous row bands (by
    /// index arithmetic — no band table is allocated) and `f(rows, band)`
    /// runs once per band with `band.len() == rows.len() * n`.
    pub fn run_bands<F>(&self, m: usize, n: usize, out: &mut [f32], f: F)
    where
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        assert_eq!(out.len(), m * n, "band buffer shape");
        if m == 0 {
            return;
        }
        let parts = self.width().min(m);
        let per = m.div_ceil(parts);
        let bands = DisjointMut::new(out);
        self.run(parts, |part| {
            let start = part * per;
            let end = m.min(start + per);
            if start < end {
                // SAFETY: row bands are disjoint across parts by
                // construction, and each part index runs exactly once.
                let band = unsafe { bands.take(start * n..end * n) };
                f(start..end, band);
            }
        });
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.shutdown = true;
        self.shared.work_cv.notify_all();
        // Workers wake, observe `shutdown` and return; they own the
        // `Shared` via `Arc`, so no join is needed.
    }
}

/// The process-wide pool used by the library kernels, sized by
/// [`crate::linalg::num_threads`] on first use.
pub fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool::new(super::num_threads()))
}

/// [`Pool::run`] on the [`global`] pool.
pub fn run<F: Fn(usize) + Sync>(parts: usize, f: F) {
    global().run(parts, f)
}

/// [`Pool::run_bands`] on the [`global`] pool.
pub fn run_bands<F>(m: usize, n: usize, out: &mut [f32], f: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    global().run_bands(m, n, out, f)
}

/// Scoped-thread fan-out for **blocking** drivers (serve smoke clients,
/// request generators): every part gets its own OS thread for the duration
/// of the call.
///
/// Unlike [`run`], parts here may block — on channel replies, I/O, the
/// micro-batcher's `max_wait` window — without capping concurrency at the
/// pool width or starving the compute plane: a blocking part parked inside
/// a pool task would hold the pool's single task slot, forcing every
/// concurrent kernel (including the serve engine the driver is exercising)
/// onto its inline serial fallback. Spawn cost is irrelevant next to the
/// blocking time these drivers measure; hot compute kernels belong on
/// [`run`].
pub fn run_scoped<F: Fn(usize) + Sync>(parts: usize, f: F) {
    std::thread::scope(|s| {
        for part in 0..parts {
            let fref = &f;
            s.spawn(move || fref(part));
        }
    });
}

/// Hands out disjoint `&mut` sub-slices of one buffer by index — the
/// per-part mutable state of pool tasks whose partition is not a
/// contiguous row band (k-means assignment chunks + per-part reduction
/// slots, per-client handles in the serve drivers).
pub struct DisjointMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: moving/sharing the handle across threads only moves the raw
// pointer; actual access goes through `take`, whose disjointness
// obligation is documented there. `T: Send` because the referents are
// mutated from worker threads.
unsafe impl<T: Send> Send for DisjointMut<'_, T> {}
unsafe impl<T: Send> Sync for DisjointMut<'_, T> {}

impl<'a, T> DisjointMut<'a, T> {
    /// Wrap a buffer; the borrow lasts as long as the handle, so the
    /// underlying slice cannot be touched while parts hold sub-slices.
    pub fn new(slice: &'a mut [T]) -> DisjointMut<'a, T> {
        DisjointMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Mutable access to `range` of the wrapped buffer.
    ///
    /// # Safety
    /// Ranges taken by concurrently running parts must be pairwise
    /// disjoint, and no range may be taken twice while a previous
    /// sub-slice for an overlapping range is still alive.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn take(&self, range: Range<usize>) -> &'a mut [T] {
        assert!(range.start <= range.end && range.end <= self.len, "part out of range");
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_part_runs_exactly_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicU32> = (0..97).map(|_| AtomicU32::new(0)).collect();
        pool.run(hits.len(), |p| {
            hits[p].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_pool_runs_inline_in_order() {
        let pool = Pool::new(1);
        assert_eq!(pool.width(), 1);
        let order = Mutex::new(Vec::new());
        pool.run(8, |p| order.lock().unwrap().push(p));
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn borrowed_captures_are_visible_after_dispatch() {
        let pool = Pool::new(3);
        let input: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 64];
        let parts = DisjointMut::new(&mut out);
        pool.run(8, |p| {
            let band = unsafe { parts.take(p * 8..(p + 1) * 8) };
            for (o, i) in band.iter_mut().zip(&input[p * 8..(p + 1) * 8]) {
                *o = 2.0 * i;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 2.0 * i as f32);
        }
    }

    #[test]
    fn run_bands_covers_every_row_once() {
        let pool = Pool::new(4);
        for m in [1usize, 2, 3, 7, 16, 33] {
            let n = 5;
            let mut out = vec![-1.0f32; m * n];
            pool.run_bands(m, n, &mut out, |rows, band| {
                assert_eq!(band.len(), rows.len() * n);
                for (local, r) in rows.enumerate() {
                    for v in &mut band[local * n..(local + 1) * n] {
                        assert_eq!(*v, -1.0, "row {r} written twice");
                        *v = r as f32;
                    }
                }
            });
            for r in 0..m {
                for c in 0..n {
                    assert_eq!(out[r * n + c], r as f32, "row {r} col {c}");
                }
            }
        }
    }

    #[test]
    fn nested_dispatch_degrades_to_inline() {
        let pool = Pool::new(4);
        let total = AtomicU32::new(0);
        pool.run(4, |_| {
            // reentrant dispatch from inside a running task: must not
            // deadlock, must still run every inner part
            pool.run(5, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn pool_survives_a_panicking_part() {
        let pool = Pool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(6, |p| {
                if p == 3 {
                    panic!("part 3 exploded");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the dispatcher");
        // the pool keeps working afterwards
        let ok = AtomicU32::new(0);
        pool.run(6, |_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn zero_parts_is_a_noop() {
        let pool = Pool::new(2);
        pool.run(0, |_| panic!("must not run"));
        pool.run_bands(0, 4, &mut [], |_, _| panic!("must not run"));
    }
}
