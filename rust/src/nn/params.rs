//! The flat parameter plane: every learnable parameter of a net lives in
//! **one contiguous `f32` arena** — all multiplicative weights first, then
//! all biases — addressed through a [`ParamLayout`] offset/shape table.
//!
//! This is the representation the whole LC hot path runs on:
//!
//! * the L step's fused Nesterov update ([`crate::nn::sgd::FlatNesterov`])
//!   is a single flat loop over `w_flat()`/`b_flat()` — no per-layer
//!   dispatch, no `Vec<Vec<f32>>` traffic;
//! * the penalty targets `w_C` and the multipliers `λ` are plain
//!   weight-arena-length slices, so the penalized gradient
//!   `∇L + μ(w − w_C) − λ` fuses into the same loop
//!   ([`crate::linalg::vecops::nesterov_step_penalized`]);
//! * the C step quantizes per-layer **views** (`w_layer(l)`) of the same
//!   storage — no copies in, and the quantized result is written back
//!   through the same layout;
//! * gradients accumulate into a reusable [`GradBuffer`] with the identical
//!   layout, so `backend.next_loss_grads_into(&mut grads)` performs zero
//!   heap allocation in steady state.
//!
//! Per-layer `Vec<Vec<f32>>` forms survive only at API edges (results,
//! serialization, tests) via the `*_cloned`/`set_*_per_layer` converters.

#![warn(missing_docs)]

use std::ops::Range;

/// Shape of one dense layer's weight matrix: `(rows, cols)` = (fan-in,
/// fan-out), row-major — identical to [`crate::linalg::Mat`] layout. The
/// bias of the layer has `cols` entries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerShape {
    /// Fan-in: rows of the row-major weight matrix.
    pub rows: usize,
    /// Fan-out: columns of the weight matrix (and bias length).
    pub cols: usize,
}

impl LayerShape {
    /// Number of multiplicative weights in the layer.
    pub fn w_len(&self) -> usize {
        self.rows * self.cols
    }
}

/// Offset/shape table mapping layer indices to ranges of the flat arenas.
///
/// Weight offsets index the weight arena (`w_flat`), bias offsets index the
/// bias arena (`b_flat`); both are dense prefix sums, so per-layer views are
/// O(1) subslices.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamLayout {
    shapes: Vec<LayerShape>,
    /// Prefix sums of weight counts; `n_layers + 1` entries.
    w_off: Vec<usize>,
    /// Prefix sums of bias counts; `n_layers + 1` entries.
    b_off: Vec<usize>,
}

impl ParamLayout {
    /// Layout from explicit per-layer shapes (at least one).
    pub fn new(shapes: Vec<LayerShape>) -> ParamLayout {
        assert!(!shapes.is_empty(), "layout needs at least one layer");
        let mut w_off = Vec::with_capacity(shapes.len() + 1);
        let mut b_off = Vec::with_capacity(shapes.len() + 1);
        w_off.push(0);
        b_off.push(0);
        for s in &shapes {
            w_off.push(w_off.last().unwrap() + s.w_len());
            b_off.push(b_off.last().unwrap() + s.cols);
        }
        ParamLayout { shapes, w_off, b_off }
    }

    /// Layout of an MLP given its layer widths (including the input), e.g.
    /// `[784, 300, 100, 10]`.
    pub fn from_sizes(sizes: &[usize]) -> ParamLayout {
        ParamLayout::new(
            sizes
                .windows(2)
                .map(|w| LayerShape { rows: w[0], cols: w[1] })
                .collect(),
        )
    }

    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.shapes.len()
    }

    /// Shape of layer `l`.
    pub fn shape(&self, l: usize) -> LayerShape {
        self.shapes[l]
    }

    /// All layer shapes, in layer order.
    pub fn shapes(&self) -> &[LayerShape] {
        &self.shapes
    }

    /// Total multiplicative weights (P1).
    pub fn w_len(&self) -> usize {
        *self.w_off.last().unwrap()
    }

    /// Total biases (P0).
    pub fn b_len(&self) -> usize {
        *self.b_off.last().unwrap()
    }

    /// Range of layer `l`'s weights within the weight arena.
    pub fn w_range(&self, l: usize) -> Range<usize> {
        self.w_off[l]..self.w_off[l + 1]
    }

    /// Range of layer `l`'s bias within the bias arena.
    pub fn b_range(&self, l: usize) -> Range<usize> {
        self.b_off[l]..self.b_off[l + 1]
    }

    /// Layer view of a weight-arena-length slice (e.g. `w_C`, `λ`).
    pub fn w_slice<'a>(&self, flat: &'a [f32], l: usize) -> &'a [f32] {
        &flat[self.w_range(l)]
    }

    /// Mutable layer view of a weight-arena-length slice.
    pub fn w_slice_mut<'a>(&self, flat: &'a mut [f32], l: usize) -> &'a mut [f32] {
        &mut flat[self.w_range(l)]
    }

    /// Split a weight-arena-length slice into its per-layer owned vectors
    /// (API-edge conversion, e.g. for [`crate::coordinator::LcResult`]).
    pub fn w_per_layer(&self, flat: &[f32]) -> Vec<Vec<f32>> {
        assert_eq!(flat.len(), self.w_len());
        (0..self.n_layers())
            .map(|l| flat[self.w_range(l)].to_vec())
            .collect()
    }
}

/// The contiguous parameter arena: one `Vec<f32>` holding
/// `[w_0 | w_1 | … | b_0 | b_1 | …]`, plus the [`ParamLayout`] that
/// addresses it.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    layout: ParamLayout,
    /// `[weights (w_len) | biases (b_len)]`.
    data: Vec<f32>,
}

impl ParamSet {
    /// Zero-initialized arena for the given layout.
    pub fn zeros(layout: ParamLayout) -> ParamSet {
        let n = layout.w_len() + layout.b_len();
        ParamSet { layout, data: vec![0.0; n] }
    }

    /// The offset/shape table addressing this arena.
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.layout.n_layers()
    }

    /// All multiplicative weights, contiguous.
    pub fn w_flat(&self) -> &[f32] {
        &self.data[..self.layout.w_len()]
    }

    /// Mutable view of all multiplicative weights.
    pub fn w_flat_mut(&mut self) -> &mut [f32] {
        let n = self.layout.w_len();
        &mut self.data[..n]
    }

    /// All biases, contiguous.
    pub fn b_flat(&self) -> &[f32] {
        &self.data[self.layout.w_len()..]
    }

    /// Mutable view of all biases.
    pub fn b_flat_mut(&mut self) -> &mut [f32] {
        let n = self.layout.w_len();
        &mut self.data[n..]
    }

    /// Disjoint mutable views of the weight and bias arenas — what the
    /// fused optimizer step borrows.
    pub fn split_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        self.data.split_at_mut(self.layout.w_len())
    }

    /// Layer `l`'s weight matrix, row-major `(rows, cols)`.
    pub fn w_layer(&self, l: usize) -> &[f32] {
        &self.data[self.layout.w_range(l)]
    }

    /// Mutable view of layer `l`'s weight matrix.
    pub fn w_layer_mut(&mut self, l: usize) -> &mut [f32] {
        let r = self.layout.w_range(l);
        &mut self.data[r]
    }

    /// Layer `l`'s bias vector.
    pub fn b_layer(&self, l: usize) -> &[f32] {
        let r = self.layout.b_range(l);
        let w = self.layout.w_len();
        &self.data[w + r.start..w + r.end]
    }

    /// Mutable view of layer `l`'s bias vector.
    pub fn b_layer_mut(&mut self, l: usize) -> &mut [f32] {
        let r = self.layout.b_range(l);
        let w = self.layout.w_len();
        &mut self.data[w + r.start..w + r.end]
    }

    // ---- API-edge conversions (allocating; not on the step path) --------

    /// Clone the weights into per-layer vectors.
    pub fn w_cloned(&self) -> Vec<Vec<f32>> {
        self.layout.w_per_layer(self.w_flat())
    }

    /// Clone the biases into per-layer vectors.
    pub fn b_cloned(&self) -> Vec<Vec<f32>> {
        (0..self.n_layers()).map(|l| self.b_layer(l).to_vec()).collect()
    }

    /// Overwrite the weights from per-layer vectors (shape-checked).
    pub fn set_w_per_layer(&mut self, w: &[Vec<f32>]) {
        assert_eq!(w.len(), self.n_layers(), "layer count mismatch");
        for (l, wl) in w.iter().enumerate() {
            let dst = self.w_layer_mut(l);
            assert_eq!(dst.len(), wl.len(), "layer {l} weight length");
            dst.copy_from_slice(wl);
        }
    }

    /// Overwrite the biases from per-layer vectors (shape-checked).
    pub fn set_b_per_layer(&mut self, b: &[Vec<f32>]) {
        assert_eq!(b.len(), self.n_layers(), "layer count mismatch");
        for (l, bl) in b.iter().enumerate() {
            let dst = self.b_layer_mut(l);
            assert_eq!(dst.len(), bl.len(), "layer {l} bias length");
            dst.copy_from_slice(bl);
        }
    }
}

/// Reusable gradient accumulator with the same arena layout as the
/// [`ParamSet`] it mirrors. Backends write into it in place
/// (`Backend::next_loss_grads_into`); the optimizer reads it as two flat
/// slices. Allocated once per SGD run, never on the per-minibatch path.
#[derive(Clone, Debug)]
pub struct GradBuffer {
    inner: ParamSet,
}

impl GradBuffer {
    /// Zero-initialized gradient arena for the given layout.
    pub fn zeros(layout: ParamLayout) -> GradBuffer {
        GradBuffer { inner: ParamSet::zeros(layout) }
    }

    /// The offset/shape table addressing this buffer.
    pub fn layout(&self) -> &ParamLayout {
        self.inner.layout()
    }

    /// Flat weight gradients (∂L/∂w, arena order).
    pub fn w_flat(&self) -> &[f32] {
        self.inner.w_flat()
    }

    /// Flat bias gradients.
    pub fn b_flat(&self) -> &[f32] {
        self.inner.b_flat()
    }

    /// Layer `l`'s weight gradients.
    pub fn w_layer(&self, l: usize) -> &[f32] {
        self.inner.w_layer(l)
    }

    /// Layer `l`'s bias gradients.
    pub fn b_layer(&self, l: usize) -> &[f32] {
        self.inner.b_layer(l)
    }

    /// Mutable view of layer `l`'s weight gradients (backends accumulate
    /// here in place).
    pub fn w_layer_mut(&mut self, l: usize) -> &mut [f32] {
        self.inner.w_layer_mut(l)
    }

    /// Mutable view of layer `l`'s bias gradients.
    pub fn b_layer_mut(&mut self, l: usize) -> &mut [f32] {
        self.inner.b_layer_mut(l)
    }

    /// Reset every gradient to zero.
    pub fn zero(&mut self) {
        self.inner.data.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout_4_3_2() -> ParamLayout {
        ParamLayout::from_sizes(&[4, 3, 2])
    }

    #[test]
    fn layout_offsets_and_lengths() {
        let lo = layout_4_3_2();
        assert_eq!(lo.n_layers(), 2);
        assert_eq!(lo.shape(0), LayerShape { rows: 4, cols: 3 });
        assert_eq!(lo.shape(1), LayerShape { rows: 3, cols: 2 });
        assert_eq!(lo.w_len(), 12 + 6);
        assert_eq!(lo.b_len(), 3 + 2);
        assert_eq!(lo.w_range(0), 0..12);
        assert_eq!(lo.w_range(1), 12..18);
        assert_eq!(lo.b_range(0), 0..3);
        assert_eq!(lo.b_range(1), 3..5);
    }

    #[test]
    fn views_address_disjoint_regions() {
        let mut p = ParamSet::zeros(layout_4_3_2());
        p.w_layer_mut(0)[0] = 1.0;
        p.w_layer_mut(1)[5] = 2.0;
        p.b_layer_mut(0)[2] = 3.0;
        p.b_layer_mut(1)[1] = 4.0;
        assert_eq!(p.w_flat()[0], 1.0);
        assert_eq!(p.w_flat()[17], 2.0);
        assert_eq!(p.b_flat()[2], 3.0);
        assert_eq!(p.b_flat()[4], 4.0);
        let (w, b) = p.split_mut();
        assert_eq!(w.len(), 18);
        assert_eq!(b.len(), 5);
        assert_eq!(w[17], 2.0);
        assert_eq!(b[4], 4.0);
    }

    #[test]
    fn per_layer_roundtrip() {
        let mut p = ParamSet::zeros(layout_4_3_2());
        let w = vec![(0..12).map(|i| i as f32).collect::<Vec<_>>(), vec![9.0; 6]];
        let b = vec![vec![0.5; 3], vec![-0.5; 2]];
        p.set_w_per_layer(&w);
        p.set_b_per_layer(&b);
        assert_eq!(p.w_cloned(), w);
        assert_eq!(p.b_cloned(), b);
        assert_eq!(p.w_layer(0)[3], 3.0);
        assert_eq!(p.b_layer(1), &[-0.5, -0.5]);
    }

    #[test]
    fn layout_slices_weight_length_buffers() {
        let lo = layout_4_3_2();
        let flat: Vec<f32> = (0..lo.w_len()).map(|i| i as f32).collect();
        assert_eq!(lo.w_slice(&flat, 1), &flat[12..18]);
        let per = lo.w_per_layer(&flat);
        assert_eq!(per.len(), 2);
        assert_eq!(per[0], flat[..12].to_vec());
        assert_eq!(per[1], flat[12..].to_vec());
    }

    #[test]
    fn grad_buffer_mirrors_layout() {
        let mut g = GradBuffer::zeros(layout_4_3_2());
        g.w_layer_mut(1)[0] = 7.0;
        g.b_layer_mut(0)[1] = -1.0;
        assert_eq!(g.w_flat()[12], 7.0);
        assert_eq!(g.b_flat()[1], -1.0);
        g.zero();
        assert!(g.w_flat().iter().all(|&v| v == 0.0));
        assert!(g.b_flat().iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic]
    fn set_w_per_layer_checks_shapes() {
        let mut p = ParamSet::zeros(layout_4_3_2());
        p.set_w_per_layer(&[vec![0.0; 11], vec![0.0; 6]]);
    }
}
