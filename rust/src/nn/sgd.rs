//! SGD with Nesterov momentum (the paper trains with Nesterov's accelerated
//! gradient, §5.3) and the penalized L-step gradient.
//!
//! The L step of the LC algorithm minimizes
//! `L(w) + μ/2 ‖w − w_C − λ/μ‖²`, whose gradient adds `μ(w − w_C) − λ`
//! to the loss gradient **of the multiplicative weights only** (biases are
//! not quantized). [`Penalty`] carries the per-layer targets.

use super::mlp::{Grads, Mlp};
use crate::linalg::Mat;

#[derive(Clone, Copy, Debug)]
pub struct SgdConfig {
    pub lr: f32,
    pub momentum: f32,
}

/// Per-layer penalty targets for the L step.
pub struct Penalty<'a> {
    /// Quantized weights Δ(Θ), per layer.
    pub wc: &'a [Vec<f32>],
    /// Lagrange multiplier estimates, per layer (zeros for the
    /// quadratic-penalty method).
    pub lambda: &'a [Vec<f32>],
    pub mu: f32,
}

/// Nesterov-momentum optimizer (Lasagne formulation:
/// `v ← m·v − lr·g; w ← w + m·v − lr·g`).
pub struct Nesterov {
    vw: Vec<Mat>,
    vb: Vec<Vec<f32>>,
    pub cfg: SgdConfig,
}

impl Nesterov {
    pub fn new(net: &Mlp, cfg: SgdConfig) -> Nesterov {
        Nesterov {
            vw: net.layers.iter().map(|l| Mat::zeros(l.w.rows, l.w.cols)).collect(),
            vb: net.layers.iter().map(|l| vec![0.0; l.b.len()]).collect(),
            cfg,
        }
    }

    /// Reset velocities (used when a new L step starts from a fresh w).
    pub fn reset(&mut self) {
        for v in self.vw.iter_mut() {
            v.data.fill(0.0);
        }
        for v in self.vb.iter_mut() {
            v.fill(0.0);
        }
    }

    /// One update. `penalty` augments the weight gradients with
    /// `μ(w − w_C) − λ`.
    pub fn step(&mut self, net: &mut Mlp, grads: &Grads, penalty: Option<&Penalty>) {
        let (lr, m) = (self.cfg.lr, self.cfg.momentum);
        for l in 0..net.layers.len() {
            let w = &mut net.layers[l].w.data;
            let g = &grads.dw[l].data;
            let v = &mut self.vw[l].data;
            match penalty {
                Some(p) => {
                    let wc = &p.wc[l];
                    let lam = &p.lambda[l];
                    debug_assert_eq!(wc.len(), w.len());
                    for i in 0..w.len() {
                        let gi = g[i] + p.mu * (w[i] - wc[i]) - lam[i];
                        v[i] = m * v[i] - lr * gi;
                        w[i] += m * v[i] - lr * gi;
                    }
                }
                None => {
                    for i in 0..w.len() {
                        v[i] = m * v[i] - lr * g[i];
                        w[i] += m * v[i] - lr * g[i];
                    }
                }
            }
            let b = &mut net.layers[l].b;
            let gb = &grads.db[l];
            let vb = &mut self.vb[l];
            for i in 0..b.len() {
                vb[i] = m * vb[i] - lr * gb[i];
                b[i] += m * vb[i] - lr * gb[i];
            }
        }
    }
}

/// The paper's clipped learning-rate schedule for the L step (§3.3):
/// `η′_t = min(η_t, 1/μ)` with a base schedule `η_t = η₀ · decay^t`.
#[derive(Clone, Copy, Debug)]
pub struct ClippedLrSchedule {
    pub eta0: f32,
    pub decay: f32,
}

impl ClippedLrSchedule {
    /// Learning rate for epoch/iteration index `t` under penalty `mu`.
    pub fn lr(&self, t: usize, mu: f32) -> f32 {
        let base = self.eta0 * self.decay.powi(t as i32);
        if mu > 0.0 {
            base.min(1.0 / mu)
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::MlpSpec;
    use crate::util::rng::Rng;

    #[test]
    fn momentum_accelerates_descent_on_quadratic() {
        // minimize 0.5*w² via explicit gradient; momentum should reach small
        // |w| faster than plain gd with same lr.
        let run = |momentum: f32| {
            let mut w = 1.0f32;
            let mut v = 0.0f32;
            let lr = 0.02;
            let mut steps = 0;
            while w.abs() > 1e-3 && steps < 10_000 {
                let g = w;
                v = momentum * v - lr * g;
                w += momentum * v - lr * g;
                steps += 1;
            }
            steps
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn penalty_pulls_weights_toward_target() {
        let spec = MlpSpec { sizes: vec![2, 3, 2], hidden_activation: crate::nn::Activation::Tanh, dropout_keep: vec![] };
        let mut net = Mlp::new(&spec, 1);
        let wc: Vec<Vec<f32>> = net
            .weights()
            .iter()
            .map(|w| vec![0.5; w.len()])
            .collect();
        let lambda: Vec<Vec<f32>> = net.weights().iter().map(|w| vec![0.0; w.len()]).collect();
        let mut opt = Nesterov::new(&net, SgdConfig { lr: 0.05, momentum: 0.9 });
        // zero loss gradient: only the penalty acts
        let grads = crate::nn::mlp::Grads::zeros_like(&net);
        let penalty = Penalty { wc: &wc, lambda: &lambda, mu: 1.0 };
        let d0: f32 = net
            .weights()
            .iter()
            .flat_map(|w| w.iter().map(|v| (v - 0.5).powi(2)))
            .sum();
        for _ in 0..200 {
            opt.step(&mut net, &grads, Some(&penalty));
        }
        let d1: f32 = net
            .weights()
            .iter()
            .flat_map(|w| w.iter().map(|v| (v - 0.5).powi(2)))
            .sum();
        assert!(d1 < d0 * 0.01, "penalty distance {d0} -> {d1}");
    }

    #[test]
    fn lambda_shifts_the_attractor() {
        // With wc=0 and λ≠0, minimizing μ/2‖w − 0 − λ/μ‖² settles at λ/μ.
        let spec = MlpSpec { sizes: vec![1, 1], hidden_activation: crate::nn::Activation::Tanh, dropout_keep: vec![] };
        let mut net = Mlp::new(&spec, 2);
        let wc = vec![vec![0.0f32]];
        let lambda = vec![vec![0.8f32]];
        let mu = 2.0;
        let mut opt = Nesterov::new(&net, SgdConfig { lr: 0.05, momentum: 0.9 });
        let grads = crate::nn::mlp::Grads::zeros_like(&net);
        for _ in 0..500 {
            opt.step(&mut net, &grads, Some(&Penalty { wc: &wc, lambda: &lambda, mu }));
        }
        assert!((net.layers[0].w.data[0] - 0.4).abs() < 1e-3); // λ/μ = 0.4
    }

    #[test]
    fn clipped_schedule() {
        let s = ClippedLrSchedule { eta0: 0.1, decay: 0.99 };
        assert_eq!(s.lr(0, 0.0), 0.1);
        assert!((s.lr(1, 0.0) - 0.099).abs() < 1e-6);
        // clip at 1/mu
        assert_eq!(s.lr(0, 100.0), 0.01);
        assert_eq!(s.lr(0, 5.0), 0.1); // 1/5 = 0.2 > 0.1, no clip
    }

    #[test]
    fn reset_zeroes_velocity() {
        let spec = MlpSpec { sizes: vec![2, 2], hidden_activation: crate::nn::Activation::Tanh, dropout_keep: vec![] };
        let mut net = Mlp::new(&spec, 3);
        let mut rng = Rng::new(4);
        let mut g = crate::nn::mlp::Grads::zeros_like(&net);
        rng.fill_normal(&mut g.dw[0].data, 0.0, 1.0);
        let mut opt = Nesterov::new(&net, SgdConfig { lr: 0.1, momentum: 0.9 });
        opt.step(&mut net, &g, None);
        assert!(opt.vw[0].data.iter().any(|&v| v != 0.0));
        opt.reset();
        assert!(opt.vw[0].data.iter().all(|&v| v == 0.0));
    }
}
