//! SGD with Nesterov momentum (the paper trains with Nesterov's accelerated
//! gradient, §5.3) over the **flat parameter plane**, and the paper's
//! clipped learning-rate schedule.
//!
//! The L step of the LC algorithm minimizes
//! `L(w) + μ/2 ‖w − w_C − λ/μ‖²`, whose gradient adds `μ(w − w_C) − λ`
//! to the loss gradient **of the multiplicative weights only** (biases are
//! not quantized). [`PenaltyState`] borrows the flat `w_C` and `λ` arenas
//! owned by the coordinator — no per-L-step clones — and
//! [`FlatNesterov::step`] fuses the penalty gradient, the velocity update
//! and the parameter update into one pass over each arena
//! ([`crate::linalg::vecops::nesterov_step_penalized`]).

use super::params::{GradBuffer, ParamLayout, ParamSet};
use crate::linalg::vecops;

/// Penalty targets for the L step, borrowed as weight-arena-length slices
/// (`w_C` and `λ` live in the LC coordinator's flat buffers).
pub struct PenaltyState<'a> {
    /// Quantized weights Δ(Θ), flat arena order.
    pub wc: &'a [f32],
    /// Lagrange multiplier estimates (all zeros under the quadratic-penalty
    /// method), flat arena order.
    pub lambda: &'a [f32],
    pub mu: f32,
}

/// Nesterov-momentum optimizer over the flat parameter arena (Lasagne
/// formulation: `v ← m·v − lr·g; w ← w + m·v − lr·g`). Velocities are two
/// contiguous buffers mirroring the weight and bias arenas.
pub struct FlatNesterov {
    vw: Vec<f32>,
    vb: Vec<f32>,
    pub momentum: f32,
}

impl FlatNesterov {
    pub fn new(layout: &ParamLayout, momentum: f32) -> FlatNesterov {
        FlatNesterov {
            vw: vec![0.0; layout.w_len()],
            vb: vec![0.0; layout.b_len()],
            momentum,
        }
    }

    /// Reset velocities (used when a new L step starts from a fresh w).
    pub fn reset(&mut self) {
        self.vw.fill(0.0);
        self.vb.fill(0.0);
    }

    /// One fused in-place update of the parameter arena given gradients,
    /// lr, and an optional penalty (applied to weights only). No heap
    /// allocation, no parameter copies.
    pub fn step(
        &mut self,
        params: &mut ParamSet,
        grads: &GradBuffer,
        lr: f32,
        penalty: Option<&PenaltyState>,
    ) {
        let m = self.momentum;
        let (w, b) = params.split_mut();
        match penalty {
            Some(p) if p.mu > 0.0 => vecops::nesterov_step_penalized(
                w,
                grads.w_flat(),
                &mut self.vw,
                p.wc,
                p.lambda,
                p.mu,
                lr,
                m,
            ),
            _ => vecops::nesterov_step(w, grads.w_flat(), &mut self.vw, lr, m),
        }
        vecops::nesterov_step(b, grads.b_flat(), &mut self.vb, lr, m);
    }

    /// True when every velocity entry is zero (freshly built or reset).
    pub fn is_reset(&self) -> bool {
        self.vw.iter().all(|&v| v == 0.0) && self.vb.iter().all(|&v| v == 0.0)
    }
}

/// The paper's clipped learning-rate schedule for the L step (§3.3):
/// `η′_t = min(η_t, 1/μ)` with a base schedule `η_t = η₀ · decay^t`.
#[derive(Clone, Copy, Debug)]
pub struct ClippedLrSchedule {
    pub eta0: f32,
    pub decay: f32,
}

impl ClippedLrSchedule {
    /// Learning rate for epoch/iteration index `t` under penalty `mu`.
    pub fn lr(&self, t: usize, mu: f32) -> f32 {
        let base = self.eta0 * self.decay.powi(t as i32);
        if mu > 0.0 {
            base.min(1.0 / mu)
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::MlpSpec;
    use crate::nn::Mlp;
    use crate::util::rng::Rng;

    #[test]
    fn momentum_accelerates_descent_on_quadratic() {
        // minimize 0.5*w² via explicit gradient; momentum should reach small
        // |w| faster than plain gd with same lr.
        let run = |momentum: f32| {
            let mut w = 1.0f32;
            let mut v = 0.0f32;
            let lr = 0.02;
            let mut steps = 0;
            while w.abs() > 1e-3 && steps < 10_000 {
                let g = w;
                v = momentum * v - lr * g;
                w += momentum * v - lr * g;
                steps += 1;
            }
            steps
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn penalty_pulls_weights_toward_target() {
        let spec = MlpSpec {
            sizes: vec![2, 3, 2],
            hidden_activation: crate::nn::Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut net = Mlp::new(&spec, 1);
        let layout = net.params().layout().clone();
        let wc = vec![0.5f32; layout.w_len()];
        let lambda = vec![0.0f32; layout.w_len()];
        let mut opt = FlatNesterov::new(&layout, 0.9);
        // zero loss gradient: only the penalty acts
        let grads = GradBuffer::zeros(layout.clone());
        let dist = |p: &crate::nn::params::ParamSet| -> f32 {
            p.w_flat().iter().map(|v| (v - 0.5).powi(2)).sum()
        };
        let d0 = dist(net.params());
        for _ in 0..200 {
            let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu: 1.0 };
            opt.step(net.params_mut(), &grads, 0.05, Some(&penalty));
        }
        let d1 = dist(net.params());
        assert!(d1 < d0 * 0.01, "penalty distance {d0} -> {d1}");
    }

    #[test]
    fn lambda_shifts_the_attractor() {
        // With wc=0 and λ≠0, minimizing μ/2‖w − 0 − λ/μ‖² settles at λ/μ.
        let spec = MlpSpec {
            sizes: vec![1, 1],
            hidden_activation: crate::nn::Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut net = Mlp::new(&spec, 2);
        let wc = vec![0.0f32];
        let lambda = vec![0.8f32];
        let mu = 2.0;
        let mut opt = FlatNesterov::new(net.params().layout(), 0.9);
        let grads = GradBuffer::zeros(net.params().layout().clone());
        for _ in 0..500 {
            let penalty = PenaltyState { wc: &wc, lambda: &lambda, mu };
            opt.step(net.params_mut(), &grads, 0.05, Some(&penalty));
        }
        assert!((net.weight(0)[0] - 0.4).abs() < 1e-3); // λ/μ = 0.4
    }

    #[test]
    fn clipped_schedule() {
        let s = ClippedLrSchedule { eta0: 0.1, decay: 0.99 };
        assert_eq!(s.lr(0, 0.0), 0.1);
        assert!((s.lr(1, 0.0) - 0.099).abs() < 1e-6);
        // clip at 1/mu
        assert_eq!(s.lr(0, 100.0), 0.01);
        assert_eq!(s.lr(0, 5.0), 0.1); // 1/5 = 0.2 > 0.1, no clip
    }

    #[test]
    fn reset_zeroes_velocity() {
        let spec = MlpSpec {
            sizes: vec![2, 2],
            hidden_activation: crate::nn::Activation::Tanh,
            dropout_keep: vec![],
        };
        let mut net = Mlp::new(&spec, 3);
        let mut rng = Rng::new(4);
        let mut g = GradBuffer::zeros(net.params().layout().clone());
        rng.fill_normal(g.w_layer_mut(0), 0.0, 1.0);
        let mut opt = FlatNesterov::new(net.params().layout(), 0.9);
        assert!(opt.is_reset());
        opt.step(net.params_mut(), &g, 0.1, None);
        assert!(!opt.is_reset());
        opt.reset();
        assert!(opt.is_reset());
    }
}
