//! Pure-rust neural network substrate: dense layers over the flat
//! [`params::ParamSet`] parameter arena, activations, softmax/cross-entropy,
//! full forward/backward with reusable scratch ([`mlp::MlpScratch`]), and
//! the fused flat Nesterov optimizer + clipped learning-rate schedule.
//!
//! This is the **native L-step backend**: it implements exactly the same
//! math as the AOT JAX artifact (`python/compile/model.py`), letting every
//! coordinator test and most experiments run without artifacts, and giving
//! a cross-check for the PJRT path (`runtime::PjrtBackend`).

pub mod loss;
pub mod mlp;
pub mod params;
pub mod sgd;

pub use loss::{cross_entropy_grad, softmax_cross_entropy};
pub use mlp::{Activation, EvalScratch, Mlp, MlpScratch, MlpSpec};
pub use params::{GradBuffer, LayerShape, ParamLayout, ParamSet};
pub use sgd::{ClippedLrSchedule, FlatNesterov, PenaltyState};
