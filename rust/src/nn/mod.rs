//! Pure-rust neural network substrate: dense layers, activations,
//! softmax/cross-entropy, full forward/backward, and SGD with Nesterov
//! momentum + the paper's clipped learning-rate schedule.
//!
//! This is the **native L-step backend**: it implements exactly the same
//! math as the AOT JAX artifact (`python/compile/model.py`), letting every
//! coordinator test and most experiments run without artifacts, and giving
//! a cross-check for the PJRT path (`runtime::PjrtBackend`).

pub mod loss;
pub mod mlp;
pub mod sgd;

pub use loss::{cross_entropy_grad, softmax_cross_entropy};
pub use mlp::{Activation, Mlp, MlpSpec};
pub use sgd::{Nesterov, SgdConfig};
