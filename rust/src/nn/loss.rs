//! Softmax + average cross-entropy (the paper's loss for classification).

use crate::linalg::Mat;

/// Row-wise softmax in place (numerically stable).
pub fn softmax_rows(logits: &mut Mat) {
    for r in 0..logits.rows {
        let row = logits.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Average cross-entropy of logits vs one-hot targets. Returns
/// (loss, probabilities).
pub fn softmax_cross_entropy(logits: &Mat, targets: &Mat) -> (f32, Mat) {
    assert_eq!(logits.rows, targets.rows);
    assert_eq!(logits.cols, targets.cols);
    let mut probs = logits.clone();
    softmax_rows(&mut probs);
    let mut loss = 0.0f64;
    for r in 0..probs.rows {
        for c in 0..probs.cols {
            if targets[(r, c)] > 0.0 {
                loss -= (targets[(r, c)] as f64) * (probs[(r, c)].max(1e-12) as f64).ln();
            }
        }
    }
    ((loss / probs.rows as f64) as f32, probs)
}

/// Non-allocating form of [`softmax_cross_entropy`]: writes the
/// probabilities into `probs` (pre-sized to the logits' shape) and returns
/// the loss. This is the minibatch-step-path variant.
pub fn softmax_cross_entropy_into(logits: &Mat, targets: &Mat, probs: &mut Mat) -> f32 {
    assert_eq!(logits.rows, targets.rows);
    assert_eq!(logits.cols, targets.cols);
    assert_eq!(probs.rows, logits.rows);
    assert_eq!(probs.cols, logits.cols);
    probs.data.copy_from_slice(&logits.data);
    softmax_rows(probs);
    let mut loss = 0.0f64;
    for r in 0..probs.rows {
        for c in 0..probs.cols {
            if targets[(r, c)] > 0.0 {
                loss -= (targets[(r, c)] as f64) * (probs[(r, c)].max(1e-12) as f64).ln();
            }
        }
    }
    (loss / probs.rows as f64) as f32
}

/// Gradient of average CE wrt logits: (probs - targets) / batch.
pub fn cross_entropy_grad(probs: &Mat, targets: &Mat) -> Mat {
    let mut g = probs.clone();
    cross_entropy_grad_inplace(&mut g, targets);
    g
}

/// In-place form of [`cross_entropy_grad`]: `probs ← (probs − targets)/B`.
pub fn cross_entropy_grad_inplace(probs: &mut Mat, targets: &Mat) {
    debug_assert_eq!(probs.rows, targets.rows);
    debug_assert_eq!(probs.cols, targets.cols);
    let b = probs.rows as f32;
    for (g, t) in probs.data.iter_mut().zip(&targets.data) {
        *g = (*g - t) / b;
    }
}

/// Classification error rate (%) from logits and labels.
pub fn error_rate(logits: &Mat, labels: &[u8]) -> f32 {
    let mut wrong = 0usize;
    for r in 0..logits.rows {
        let row = logits.row(r);
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred != labels[r] as usize {
            wrong += 1;
        }
    }
    100.0 * wrong as f32 / logits.rows as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -10.0, 0.0, 10.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(m.row(r).iter().all(|&v| v > 0.0));
        }
        // larger logit -> larger prob
        assert!(m[(0, 2)] > m[(0, 1)] && m[(0, 1)] > m[(0, 0)]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut m = Mat::from_vec(1, 2, vec![1000.0, 1001.0]);
        softmax_rows(&mut m);
        assert!(m.data.iter().all(|v| v.is_finite()));
        assert!((m.data.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ce_of_perfect_prediction_near_zero() {
        let logits = Mat::from_vec(1, 3, vec![100.0, 0.0, 0.0]);
        let targets = Mat::from_vec(1, 3, vec![1.0, 0.0, 0.0]);
        let (loss, _) = softmax_cross_entropy(&logits, &targets);
        assert!(loss < 1e-5);
    }

    #[test]
    fn ce_of_uniform_is_log_k() {
        let logits = Mat::zeros(4, 10);
        let mut targets = Mat::zeros(4, 10);
        for r in 0..4 {
            targets[(r, r)] = 1.0;
        }
        let (loss, _) = softmax_cross_entropy(&logits, &targets);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn grad_matches_finite_difference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let mut logits = Mat::zeros(3, 5);
        rng.fill_normal(&mut logits.data, 0.0, 1.0);
        let mut targets = Mat::zeros(3, 5);
        for r in 0..3 {
            targets[(r, r)] = 1.0;
        }
        let (_, probs) = softmax_cross_entropy(&logits, &targets);
        let g = cross_entropy_grad(&probs, &targets);
        let eps = 1e-3;
        for idx in [0usize, 4, 7, 14] {
            let mut lp = logits.clone();
            lp.data[idx] += eps;
            let (l1, _) = softmax_cross_entropy(&lp, &targets);
            let mut lm = logits.clone();
            lm.data[idx] -= eps;
            let (l0, _) = softmax_cross_entropy(&lm, &targets);
            let fd = (l1 - l0) / (2.0 * eps);
            assert!(
                (fd - g.data[idx]).abs() < 1e-3,
                "idx {idx}: fd {fd} vs analytic {}",
                g.data[idx]
            );
        }
    }

    #[test]
    fn into_forms_match_allocating_forms() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let mut logits = Mat::zeros(4, 6);
        rng.fill_normal(&mut logits.data, 0.0, 1.5);
        let mut targets = Mat::zeros(4, 6);
        for r in 0..4 {
            targets[(r, r)] = 1.0;
        }
        let (loss_a, probs_a) = softmax_cross_entropy(&logits, &targets);
        let mut probs_b = Mat::zeros(4, 6);
        let loss_b = softmax_cross_entropy_into(&logits, &targets, &mut probs_b);
        assert_eq!(loss_a, loss_b);
        assert_eq!(probs_a.data, probs_b.data);
        let grad_a = cross_entropy_grad(&probs_a, &targets);
        cross_entropy_grad_inplace(&mut probs_b, &targets);
        assert_eq!(grad_a.data, probs_b.data);
    }

    #[test]
    fn error_rate_counts_argmax_mismatches() {
        let logits = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(error_rate(&logits, &[0, 1]), 0.0);
        assert_eq!(error_rate(&logits, &[1, 1]), 50.0);
        assert_eq!(error_rate(&logits, &[1, 0]), 100.0);
    }
}
