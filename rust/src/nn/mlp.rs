//! Multi-layer perceptron with explicit forward/backward over the **flat
//! parameter plane**: all weights and biases live in one contiguous
//! [`ParamSet`] arena ([`crate::nn::params`]), and every layer operates on
//! per-layer views of it.
//!
//! Matches the paper's LeNet300 (784-300-100-10, tanh) and the deep-MLP
//! stand-in for LeNet5 (see DESIGN.md §5). Weights are `(in, out)`
//! row-major so the forward pass is `X·W + b`.
//!
//! The hot path is [`Mlp::loss_grads_into`]: forward + loss + backward with
//! all activations in a caller-owned [`MlpScratch`] and gradients
//! accumulated into a [`GradBuffer`] — zero heap allocation once the
//! scratch is warm. The tuple-returning conveniences (`forward`,
//! `loss_and_grads`) allocate a fresh scratch and exist for tests, examples
//! and evaluation, not for the SGD loop.

use super::params::{GradBuffer, ParamLayout, ParamSet};
use crate::linalg::gemm::{gemm_a_bt_into, gemm_at_b_into, gemm_into};
use crate::linalg::Mat;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Tanh,
    Relu,
    /// No nonlinearity (output layer; softmax lives in the loss).
    Linear,
}

/// Architecture description.
#[derive(Clone, Debug, PartialEq)]
pub struct MlpSpec {
    /// Layer widths including input, e.g. `[784, 300, 100, 10]`.
    pub sizes: Vec<usize>,
    /// Activation after each hidden layer (the output layer is linear).
    pub hidden_activation: Activation,
    /// Dropout keep-probability per layer input (1.0 = no dropout). Must
    /// have `sizes.len() - 1` entries or be empty.
    pub dropout_keep: Vec<f32>,
}

impl MlpSpec {
    /// Paper's LeNet300: 784-300-100-10, tanh (P1 = 266,200 weights,
    /// P0 = 410 biases).
    pub fn lenet300() -> MlpSpec {
        MlpSpec {
            sizes: vec![784, 300, 100, 10],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        }
    }

    /// Deep-MLP stand-in for the paper's LeNet5 (ReLU + dropout on the
    /// dense layers; ≈560k parameters — same order as LeNet5's 430k).
    pub fn lenet5_mlp() -> MlpSpec {
        MlpSpec {
            sizes: vec![784, 500, 300, 100, 10],
            hidden_activation: Activation::Relu,
            dropout_keep: vec![1.0, 0.5, 0.5, 1.0],
        }
    }

    /// Single-hidden-layer net used by the Fig. 6 tradeoff experiment.
    pub fn single_hidden(d: usize, h: usize, classes: usize) -> MlpSpec {
        MlpSpec {
            sizes: vec![d, h, classes],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        }
    }

    pub fn n_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    /// The flat-arena layout of this architecture.
    pub fn layout(&self) -> ParamLayout {
        ParamLayout::from_sizes(&self.sizes)
    }

    /// Count of multiplicative weights (P1) and biases (P0).
    pub fn param_counts(&self) -> (usize, usize) {
        let mut p1 = 0;
        let mut p0 = 0;
        for w in self.sizes.windows(2) {
            p1 += w[0] * w[1];
            p0 += w[1];
        }
        (p1, p0)
    }
}

/// Reusable forward/backward workspace: per-layer activation buffers sized
/// for one batch shape. `ensure` reallocates only when the batch size or
/// architecture changes, so a steady minibatch loop never allocates.
///
/// During the backward pass the buffers are reused as delta storage (the
/// input buffer of layer `l+1` holds the delta flowing into layer `l`), so
/// backprop needs no additional scratch.
pub struct MlpScratch {
    batch: usize,
    /// `inputs[l]`: input to layer `l` (post-dropout), `B × sizes[l]`.
    inputs: Vec<Mat>,
    /// `outputs[l]`: activation output of layer `l`, `B × sizes[l+1]`.
    outputs: Vec<Mat>,
    /// Dropout masks (empty when inactive).
    masks: Vec<Vec<f32>>,
    /// Softmax probabilities / logits gradient, `B × sizes[last]`.
    probs: Mat,
}

impl MlpScratch {
    pub fn new() -> MlpScratch {
        MlpScratch {
            batch: 0,
            inputs: Vec::new(),
            outputs: Vec::new(),
            masks: Vec::new(),
            probs: Mat::zeros(0, 0),
        }
    }

    fn ensure(&mut self, sizes: &[usize], batch: usize) {
        let nl = sizes.len() - 1;
        let fits = self.batch == batch
            && self.inputs.len() == nl
            && self.inputs.iter().zip(sizes).all(|(m, &s)| m.cols == s)
            && self.outputs.iter().zip(&sizes[1..]).all(|(m, &s)| m.cols == s);
        if fits {
            return;
        }
        self.batch = batch;
        self.inputs = (0..nl).map(|l| Mat::zeros(batch, sizes[l])).collect();
        self.outputs = (0..nl).map(|l| Mat::zeros(batch, sizes[l + 1])).collect();
        self.masks = vec![Vec::new(); nl];
        self.probs = Mat::zeros(batch, sizes[nl]);
    }

    /// Logits of the last forward pass.
    pub fn logits(&self) -> &Mat {
        self.outputs.last().expect("no forward pass recorded")
    }
}

impl Default for MlpScratch {
    fn default() -> Self {
        MlpScratch::new()
    }
}

/// The MLP: spec + flat parameter arena + per-layer metadata.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub spec: MlpSpec,
    params: ParamSet,
    acts: Vec<Activation>,
    keeps: Vec<f32>,
}

impl Mlp {
    /// Zero-initialized net: arena + per-layer metadata, no RNG traffic.
    fn bare(spec: &MlpSpec) -> Mlp {
        let keeps = if spec.dropout_keep.is_empty() {
            vec![1.0; spec.n_layers()]
        } else {
            assert_eq!(spec.dropout_keep.len(), spec.n_layers());
            spec.dropout_keep.clone()
        };
        let acts = (0..spec.n_layers())
            .map(|li| {
                if li + 1 == spec.n_layers() {
                    Activation::Linear
                } else {
                    spec.hidden_activation
                }
            })
            .collect();
        Mlp { spec: spec.clone(), params: ParamSet::zeros(spec.layout()), acts, keeps }
    }

    /// Glorot-uniform initialization.
    pub fn new(spec: &MlpSpec, seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        let mut net = Mlp::bare(spec);
        for (li, win) in spec.sizes.windows(2).enumerate() {
            let (fan_in, fan_out) = (win[0], win[1]);
            let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
            for v in net.params.w_layer_mut(li).iter_mut() {
                *v = rng.uniform_in(-limit, limit);
            }
        }
        net
    }

    pub fn n_layers(&self) -> usize {
        self.acts.len()
    }

    /// Rebuild a net from per-layer weight vectors and biases (e.g. the
    /// dense expansion of a packed model). Panics on shape mismatch.
    pub fn from_parts(spec: &MlpSpec, weights: &[Vec<f32>], biases: &[Vec<f32>]) -> Mlp {
        let mut net = Mlp::bare(spec);
        net.params.set_w_per_layer(weights);
        net.params.set_b_per_layer(biases);
        net
    }

    // ---- parameter plane ------------------------------------------------

    /// The flat parameter arena (weights then biases, contiguous).
    pub fn params(&self) -> &ParamSet {
        &self.params
    }

    pub fn params_mut(&mut self) -> &mut ParamSet {
        &mut self.params
    }

    /// Layer `l`'s weight matrix, row-major `(sizes[l], sizes[l+1])`.
    pub fn weight(&self, l: usize) -> &[f32] {
        self.params.w_layer(l)
    }

    pub fn weight_mut(&mut self, l: usize) -> &mut [f32] {
        self.params.w_layer_mut(l)
    }

    /// Layer `l`'s bias vector.
    pub fn bias(&self, l: usize) -> &[f32] {
        self.params.b_layer(l)
    }

    pub fn bias_mut(&mut self, l: usize) -> &mut [f32] {
        self.params.b_layer_mut(l)
    }

    /// Per-layer multiplicative weight views (the quantized parameters;
    /// biases stay full precision, paper §5).
    pub fn weights(&self) -> Vec<&[f32]> {
        (0..self.n_layers()).map(|l| self.params.w_layer(l)).collect()
    }

    /// Copy all multiplicative weights into per-layer owned vectors.
    pub fn weights_cloned(&self) -> Vec<Vec<f32>> {
        self.params.w_cloned()
    }

    /// Overwrite weights from per-layer vectors.
    pub fn set_weights(&mut self, per_layer: &[Vec<f32>]) {
        self.params.set_w_per_layer(per_layer);
    }

    /// Overwrite biases from per-layer vectors.
    pub fn set_biases(&mut self, per_layer: &[Vec<f32>]) {
        self.params.set_b_per_layer(per_layer);
    }

    pub fn activation(&self, l: usize) -> Activation {
        self.acts[l]
    }

    /// Dropout keep-probability of layer `l`'s input.
    pub fn keep(&self, l: usize) -> f32 {
        self.keeps[l]
    }

    pub fn has_dropout(&self) -> bool {
        self.keeps.iter().any(|&k| k < 1.0)
    }

    /// Total multiplicative weights (P1) and biases (P0).
    pub fn param_counts(&self) -> (usize, usize) {
        self.spec.param_counts()
    }

    // ---- forward / backward ---------------------------------------------

    /// Forward pass into a reusable scratch. `train` enables dropout
    /// (inverted scaling); `rng` is only used when dropout is active.
    /// Logits land in `scratch.logits()`.
    pub fn forward_into(
        &self,
        x: &Mat,
        train: bool,
        mut rng: Option<&mut Rng>,
        scratch: &mut MlpScratch,
    ) {
        assert_eq!(x.cols, self.spec.sizes[0], "input dim");
        scratch.ensure(&self.spec.sizes, x.rows);
        scratch.inputs[0].data.copy_from_slice(&x.data);
        for l in 0..self.n_layers() {
            // dropout on the layer input
            let keep = self.keeps[l];
            scratch.masks[l].clear();
            if train && keep < 1.0 {
                let r = rng.as_deref_mut().expect("dropout needs rng");
                let inv = 1.0 / keep;
                let cur = &mut scratch.inputs[l];
                let mask = &mut scratch.masks[l];
                mask.resize(cur.data.len(), 0.0);
                for (mi, v) in mask.iter_mut().zip(cur.data.iter_mut()) {
                    if (r.uniform() as f32) < keep {
                        *mi = inv;
                        *v *= inv;
                    } else {
                        *mi = 0.0;
                        *v = 0.0;
                    }
                }
            }
            let shape = self.params.layout().shape(l);
            // z = X·W + b, activation in place
            let xin = &scratch.inputs[l];
            let z = &mut scratch.outputs[l];
            gemm_into(xin.rows, xin.cols, shape.cols, &xin.data, self.params.w_layer(l), &mut z.data);
            let bvec = self.params.b_layer(l);
            for r in 0..z.rows {
                for (v, b) in z.row_mut(r).iter_mut().zip(bvec) {
                    *v += b;
                }
            }
            match self.acts[l] {
                Activation::Tanh => {
                    for v in z.data.iter_mut() {
                        *v = v.tanh();
                    }
                }
                Activation::Relu => {
                    for v in z.data.iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                Activation::Linear => {}
            }
            if l + 1 < self.n_layers() {
                let (outs, ins) = (&scratch.outputs[l], &mut scratch.inputs[l + 1]);
                ins.data.copy_from_slice(&outs.data);
            }
        }
    }

    /// Allocating convenience forward: returns the logits and the scratch
    /// (which holds the cached activations). Not for the SGD loop.
    pub fn forward(&self, x: &Mat, train: bool, rng: Option<&mut Rng>) -> (Mat, MlpScratch) {
        let mut scratch = MlpScratch::new();
        self.forward_into(x, train, rng, &mut scratch);
        (scratch.logits().clone(), scratch)
    }

    /// Backward pass from the logits gradient already stored in
    /// `scratch.probs` (see [`Mlp::loss_grads_into`]). Parameter gradients
    /// are written (overwriting) into `grads`; the scratch's input buffers
    /// are consumed as delta storage.
    fn backward_into(&self, scratch: &mut MlpScratch, grads: &mut GradBuffer) {
        let nl = self.n_layers();
        for l in (0..nl).rev() {
            let (inputs_head, inputs_tail) = scratch.inputs.split_at_mut(l + 1);
            // delta w.r.t. layer l's activation output: the logits gradient
            // for the top layer, otherwise the dx written by layer l+1.
            let delta: &mut Mat = if l + 1 == nl {
                &mut scratch.probs
            } else {
                &mut inputs_tail[0]
            };
            // activation derivative (output cached)
            match self.acts[l] {
                Activation::Tanh => {
                    let out = &scratch.outputs[l];
                    for (d, a) in delta.data.iter_mut().zip(&out.data) {
                        *d *= 1.0 - a * a;
                    }
                }
                Activation::Relu => {
                    let out = &scratch.outputs[l];
                    for (d, a) in delta.data.iter_mut().zip(&out.data) {
                        if *a <= 0.0 {
                            *d = 0.0;
                        }
                    }
                }
                Activation::Linear => {}
            }
            // db = column sums of delta
            let db = grads.b_layer_mut(l);
            db.fill(0.0);
            for r in 0..delta.rows {
                for (c, v) in delta.row(r).iter().enumerate() {
                    db[c] += v;
                }
            }
            // dW = Xᵀ·delta, straight into the gradient arena
            let xin = &inputs_head[l];
            gemm_at_b_into(xin.rows, xin.cols, delta.cols, &xin.data, &delta.data, grads.w_layer_mut(l));
            if l > 0 {
                // dX = delta·Wᵀ, written into inputs[l] (no longer needed),
                // then the dropout mask — this becomes layer l-1's delta.
                let shape = self.params.layout().shape(l);
                let dst = &mut inputs_head[l];
                gemm_a_bt_into(delta.rows, delta.cols, shape.rows, &delta.data, self.params.w_layer(l), &mut dst.data);
                if !scratch.masks[l].is_empty() {
                    for (v, m) in dst.data.iter_mut().zip(&scratch.masks[l]) {
                        *v *= m;
                    }
                }
            }
        }
    }

    /// The minibatch step path: forward + softmax-CE loss + backward, with
    /// every intermediate in `scratch` and gradients written into `grads`.
    /// Returns (loss, error %). Zero heap allocation once `scratch` is
    /// sized for this batch shape.
    #[allow(clippy::too_many_arguments)]
    pub fn loss_grads_into(
        &self,
        x: &Mat,
        y_onehot: &Mat,
        labels: &[u8],
        train: bool,
        rng: Option<&mut Rng>,
        scratch: &mut MlpScratch,
        grads: &mut GradBuffer,
    ) -> (f32, f32) {
        self.forward_into(x, train, rng, scratch);
        let logits = scratch.outputs.last().unwrap();
        let loss = super::loss::softmax_cross_entropy_into(logits, y_onehot, &mut scratch.probs);
        let err = super::loss::error_rate(logits, labels);
        super::loss::cross_entropy_grad_inplace(&mut scratch.probs, y_onehot);
        self.backward_into(scratch, grads);
        (loss, err)
    }

    /// Allocating convenience: loss + error + gradients for one batch.
    pub fn loss_and_grads(
        &self,
        x: &Mat,
        y_onehot: &Mat,
        labels: &[u8],
        train: bool,
        rng: Option<&mut Rng>,
    ) -> (f32, f32, GradBuffer) {
        let mut scratch = MlpScratch::new();
        let mut grads = GradBuffer::zeros(self.params.layout().clone());
        let (loss, err) = self.loss_grads_into(x, y_onehot, labels, train, rng, &mut scratch, &mut grads);
        (loss, err, grads)
    }

    /// Evaluate loss and error (no dropout).
    pub fn evaluate(&self, x: &Mat, y_onehot: &Mat, labels: &[u8]) -> (f32, f32) {
        let (logits, _) = self.forward(x, false, None);
        let (loss, _) = super::loss::softmax_cross_entropy(&logits, y_onehot);
        (loss, super::loss::error_rate(&logits, labels))
    }

    /// Evaluate over a dataset in chunks (memory-bounded), staging every
    /// chunk through a caller-owned [`EvalScratch`]: after the first call
    /// the whole evaluation pass is allocation-free, so the LC loop's
    /// periodic train/test evaluation no longer churns the allocator
    /// (`eval_every` used to be the last un-scratched path).
    pub fn evaluate_dataset_into(
        &self,
        data: &crate::data::Dataset,
        chunk: usize,
        scratch: &mut EvalScratch,
    ) -> (f32, f32) {
        let n = data.len();
        let chunk = chunk.max(1);
        let mut loss_sum = 0.0f64;
        let mut err_sum = 0.0f64;
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            let b = end - start;
            let bufs = scratch.bufs(b, data.dim(), data.n_classes);
            bufs.y.data.fill(0.0);
            bufs.labels.clear();
            for (r, i) in (start..end).enumerate() {
                bufs.x.row_mut(r).copy_from_slice(data.images.row(i));
                bufs.y[(r, data.labels[i] as usize)] = 1.0;
                bufs.labels.push(data.labels[i]);
            }
            self.forward_into(&bufs.x, false, None, &mut bufs.fwd);
            let fwd = &mut bufs.fwd;
            let logits = fwd.outputs.last().expect("forward pass recorded");
            let loss =
                super::loss::softmax_cross_entropy_into(logits, &bufs.y, &mut fwd.probs);
            let err = super::loss::error_rate(logits, &bufs.labels);
            loss_sum += loss as f64 * b as f64;
            err_sum += err as f64 * b as f64;
            start = end;
        }
        ((loss_sum / n as f64) as f32, (err_sum / n as f64) as f32)
    }

    /// Evaluate over a dataset in chunks (allocating convenience around
    /// [`Mlp::evaluate_dataset_into`]).
    pub fn evaluate_dataset(&self, data: &crate::data::Dataset, chunk: usize) -> (f32, f32) {
        let mut scratch = EvalScratch::new();
        self.evaluate_dataset_into(data, chunk, &mut scratch)
    }
}

/// Reusable dataset-evaluation workspace for [`Mlp::evaluate_dataset_into`]:
/// one staging set (batch matrix, one-hot targets, labels, forward scratch)
/// per distinct chunk row-count. A pass over a dataset sees at most two —
/// the full chunk and the final remainder — so a steady evaluation cadence
/// allocates only on its first call.
pub struct EvalScratch {
    sets: Vec<EvalBufs>,
}

struct EvalBufs {
    x: Mat,
    y: Mat,
    labels: Vec<u8>,
    fwd: MlpScratch,
}

impl EvalScratch {
    pub fn new() -> EvalScratch {
        EvalScratch { sets: Vec::new() }
    }

    /// The staging set for a `b × dim` chunk with `classes` targets
    /// (created on first sight of this shape, reused thereafter).
    fn bufs(&mut self, b: usize, dim: usize, classes: usize) -> &mut EvalBufs {
        if let Some(i) = self
            .sets
            .iter()
            .position(|s| s.x.rows == b && s.x.cols == dim && s.y.cols == classes)
        {
            return &mut self.sets[i];
        }
        self.sets.push(EvalBufs {
            x: Mat::zeros(b, dim),
            y: Mat::zeros(b, classes),
            labels: Vec::with_capacity(b),
            fwd: MlpScratch::new(),
        });
        self.sets.last_mut().expect("just pushed")
    }
}

impl Default for EvalScratch {
    fn default() -> Self {
        EvalScratch::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_net(seed: u64) -> Mlp {
        Mlp::new(
            &MlpSpec {
                sizes: vec![4, 6, 3],
                hidden_activation: Activation::Tanh,
                dropout_keep: vec![],
            },
            seed,
        )
    }

    fn toy_batch(rng: &mut Rng, b: usize) -> (Mat, Mat, Vec<u8>) {
        let mut x = Mat::zeros(b, 4);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        let mut y = Mat::zeros(b, 3);
        let mut labels = Vec::new();
        for r in 0..b {
            let l = rng.below(3);
            y[(r, l)] = 1.0;
            labels.push(l as u8);
        }
        (x, y, labels)
    }

    #[test]
    fn param_counts_match_paper() {
        let (p1, p0) = MlpSpec::lenet300().param_counts();
        assert_eq!(p1, 266_200); // paper: P1 = 266,200
        assert_eq!(p0, 410); // paper: P0 = 410
        let layout = MlpSpec::lenet300().layout();
        assert_eq!(layout.w_len(), p1);
        assert_eq!(layout.b_len(), p0);
    }

    #[test]
    fn forward_shapes() {
        let net = toy_net(1);
        let mut rng = Rng::new(2);
        let (x, _, _) = toy_batch(&mut rng, 5);
        let (logits, scratch) = net.forward(&x, false, None);
        assert_eq!(logits.rows, 5);
        assert_eq!(logits.cols, 3);
        assert_eq!(scratch.inputs.len(), 2);
        assert_eq!(scratch.outputs.len(), 2);
        assert_eq!(scratch.logits().data, logits.data);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut net = toy_net(3);
        let mut rng = Rng::new(4);
        let (x, y, labels) = toy_batch(&mut rng, 7);
        let (_, _, grads) = net.loss_and_grads(&x, &y, &labels, false, None);
        let eps = 1e-3;
        // check a scatter of weight and bias entries in every layer
        for l in 0..net.n_layers() {
            for &idx in &[0usize, 3, 11] {
                if idx >= net.weight(l).len() {
                    continue;
                }
                let orig = net.weight(l)[idx];
                net.weight_mut(l)[idx] = orig + eps;
                let (lp, _) = net.evaluate(&x, &y, &labels);
                net.weight_mut(l)[idx] = orig - eps;
                let (lm, _) = net.evaluate(&x, &y, &labels);
                net.weight_mut(l)[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads.w_layer(l)[idx];
                assert!(
                    (fd - an).abs() < 2e-3,
                    "layer {l} w[{idx}]: fd {fd} vs analytic {an}"
                );
            }
            for &idx in &[0usize, 2] {
                if idx >= net.bias(l).len() {
                    continue;
                }
                let orig = net.bias(l)[idx];
                net.bias_mut(l)[idx] = orig + eps;
                let (lp, _) = net.evaluate(&x, &y, &labels);
                net.bias_mut(l)[idx] = orig - eps;
                let (lm, _) = net.evaluate(&x, &y, &labels);
                net.bias_mut(l)[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads.b_layer(l)[idx];
                assert!(
                    (fd - an).abs() < 2e-3,
                    "layer {l} b[{idx}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn relu_gradients_match_finite_differences() {
        let mut net = Mlp::new(
            &MlpSpec {
                sizes: vec![3, 5, 2],
                hidden_activation: Activation::Relu,
                dropout_keep: vec![],
            },
            5,
        );
        let mut rng = Rng::new(6);
        let mut x = Mat::zeros(4, 3);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        let mut y = Mat::zeros(4, 2);
        let labels = vec![0u8, 1, 0, 1];
        for (r, &l) in labels.iter().enumerate() {
            y[(r, l as usize)] = 1.0;
        }
        let (_, _, grads) = net.loss_and_grads(&x, &y, &labels, false, None);
        let eps = 1e-3;
        for &idx in &[0usize, 7, 13] {
            let orig = net.weight(0)[idx];
            net.weight_mut(0)[idx] = orig + eps;
            let (lp, _) = net.evaluate(&x, &y, &labels);
            net.weight_mut(0)[idx] = orig - eps;
            let (lm, _) = net.evaluate(&x, &y, &labels);
            net.weight_mut(0)[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - grads.w_layer(0)[idx]).abs() < 2e-3);
        }
    }

    #[test]
    fn dropout_scales_expectation() {
        let spec = MlpSpec {
            sizes: vec![10, 8, 2],
            hidden_activation: Activation::Relu,
            dropout_keep: vec![0.5, 1.0],
        };
        let net = Mlp::new(&spec, 7);
        let x = Mat::from_vec(1, 10, vec![1.0; 10]);
        // Average many dropout forwards ≈ eval forward (inverted dropout).
        let mut rng = Rng::new(8);
        let mut acc = vec![0.0f64; 2];
        let n = 3000;
        let mut scratch = MlpScratch::new();
        for _ in 0..n {
            net.forward_into(&x, true, Some(&mut rng), &mut scratch);
            for (a, v) in acc.iter_mut().zip(&scratch.logits().data) {
                *a += *v as f64;
            }
        }
        let (eval_out, _) = net.forward(&x, false, None);
        for (a, e) in acc.iter().zip(&eval_out.data) {
            let mean = *a / n as f64;
            assert!(
                (mean - *e as f64).abs() < 0.25,
                "dropout mean {mean} vs eval {e}"
            );
        }
    }

    #[test]
    fn dropout_gradients_respect_mask() {
        // With dropout active, the backward pass must route gradients
        // through the same mask the forward pass drew.
        let spec = MlpSpec {
            sizes: vec![6, 5, 3],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![1.0, 0.5],
        };
        let net = Mlp::new(&spec, 17);
        let mut rng = Rng::new(18);
        let mut x = Mat::zeros(3, 6);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        let mut y = Mat::zeros(3, 3);
        let labels = vec![0u8, 1, 2];
        for (r, &l) in labels.iter().enumerate() {
            y[(r, l as usize)] = 1.0;
        }
        let mut scratch = MlpScratch::new();
        let mut grads = GradBuffer::zeros(net.params().layout().clone());
        let mut drop_rng = Rng::new(99);
        let (loss, _) = net.loss_grads_into(
            &x, &y, &labels, true, Some(&mut drop_rng), &mut scratch, &mut grads,
        );
        assert!(loss.is_finite());
        // layer-1 weight gradient rows for dropped inputs must be zero:
        // dW[i, :] = Σ_r X[r, i]·delta[r, :], and X[r, i] = 0 when dropped.
        let mask = scratch.masks[1].clone();
        assert!(!mask.is_empty());
        let dropped_everywhere: Vec<usize> = (0..5)
            .filter(|i| (0..3).all(|r| mask[r * 5 + i] == 0.0))
            .collect();
        for &i in &dropped_everywhere {
            for j in 0..3 {
                assert_eq!(grads.w_layer(1)[i * 3 + j], 0.0, "dropped input {i} leaked");
            }
        }
    }

    #[test]
    fn set_weights_roundtrip() {
        let mut net = toy_net(9);
        let mut w = net.weights_cloned();
        w[0][0] = 123.0;
        net.set_weights(&w);
        assert_eq!(net.weight(0)[0], 123.0);
        assert_eq!(net.weights()[0][0], 123.0);
        assert_eq!(net.params().w_flat()[0], 123.0);
    }

    #[test]
    fn from_parts_roundtrip() {
        let net = toy_net(10);
        let w = net.weights_cloned();
        let b = net.params().b_cloned();
        let rebuilt = Mlp::from_parts(&net.spec, &w, &b);
        assert_eq!(rebuilt.params(), net.params());
    }

    #[test]
    fn training_reduces_loss_on_toy_problem() {
        use crate::nn::sgd::FlatNesterov;
        let mut net = toy_net(11);
        let mut rng = Rng::new(12);
        let (x, y, labels) = toy_batch(&mut rng, 64);
        let (loss0, _) = net.evaluate(&x, &y, &labels);
        let mut opt = FlatNesterov::new(net.params().layout(), 0.9);
        let mut scratch = MlpScratch::new();
        let mut grads = GradBuffer::zeros(net.params().layout().clone());
        for _ in 0..100 {
            net.loss_grads_into(&x, &y, &labels, false, None, &mut scratch, &mut grads);
            opt.step(net.params_mut(), &grads, 0.5, None);
        }
        let (loss1, _) = net.evaluate(&x, &y, &labels);
        assert!(loss1 < loss0 * 0.5, "loss {loss0} -> {loss1}");
    }

    #[test]
    fn eval_scratch_reuse_matches_per_chunk_evaluate() {
        // evaluate_dataset_into (reused EvalScratch, non-allocating loss
        // path) must reproduce the per-chunk evaluate() reference exactly,
        // including across repeated calls and ragged final chunks.
        let net = toy_net(21);
        let mut rng = Rng::new(22);
        let n = 23; // chunk=10 → chunks of 10, 10, 3
        let mut images = Mat::zeros(n, 4);
        rng.fill_normal(&mut images.data, 0.0, 1.0);
        let labels: Vec<u8> = (0..n).map(|_| rng.below(3) as u8).collect();
        let data = crate::data::Dataset { images, labels, n_classes: 3 };

        // reference: the pre-scratch implementation, chunk by chunk
        let chunk = 10usize;
        let (mut loss_sum, mut err_sum) = (0.0f64, 0.0f64);
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            let b = end - start;
            let mut x = Mat::zeros(b, 4);
            let mut y = Mat::zeros(b, 3);
            let mut lbl = Vec::new();
            for (r, i) in (start..end).enumerate() {
                x.row_mut(r).copy_from_slice(data.images.row(i));
                y[(r, data.labels[i] as usize)] = 1.0;
                lbl.push(data.labels[i]);
            }
            let (l, e) = net.evaluate(&x, &y, &lbl);
            loss_sum += l as f64 * b as f64;
            err_sum += e as f64 * b as f64;
            start = end;
        }
        let want = ((loss_sum / n as f64) as f32, (err_sum / n as f64) as f32);

        let mut scratch = EvalScratch::new();
        let first = net.evaluate_dataset_into(&data, chunk, &mut scratch);
        let second = net.evaluate_dataset_into(&data, chunk, &mut scratch);
        assert_eq!(first, want);
        assert_eq!(second, want, "warm EvalScratch must not change results");
        assert_eq!(net.evaluate_dataset(&data, chunk), want);
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        // reusing a warm scratch across steps must give identical results
        let net = toy_net(13);
        let mut rng = Rng::new(14);
        let (x, y, labels) = toy_batch(&mut rng, 9);
        let (l_fresh, e_fresh, g_fresh) = net.loss_and_grads(&x, &y, &labels, false, None);
        let mut scratch = MlpScratch::new();
        let mut grads = GradBuffer::zeros(net.params().layout().clone());
        // run twice through the same buffers; second pass must be identical
        net.loss_grads_into(&x, &y, &labels, false, None, &mut scratch, &mut grads);
        let (l2, e2) = net.loss_grads_into(&x, &y, &labels, false, None, &mut scratch, &mut grads);
        assert_eq!(l_fresh, l2);
        assert_eq!(e_fresh, e2);
        assert_eq!(g_fresh.w_flat(), grads.w_flat());
        assert_eq!(g_fresh.b_flat(), grads.b_flat());
    }
}
