//! Multi-layer perceptron with explicit forward/backward.
//!
//! Matches the paper's LeNet300 (784-300-100-10, tanh) and the deep-MLP
//! stand-in for LeNet5 (see DESIGN.md §5). Weights are `(in, out)`
//! row-major so the forward pass is `X·W + b`.

use crate::linalg::gemm::{matmul, matmul_a_bt, matmul_at_b};
use crate::linalg::Mat;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Tanh,
    Relu,
    /// No nonlinearity (output layer; softmax lives in the loss).
    Linear,
}

/// Architecture description.
#[derive(Clone, Debug, PartialEq)]
pub struct MlpSpec {
    /// Layer widths including input, e.g. `[784, 300, 100, 10]`.
    pub sizes: Vec<usize>,
    /// Activation after each hidden layer (the output layer is linear).
    pub hidden_activation: Activation,
    /// Dropout keep-probability per layer input (1.0 = no dropout). Must
    /// have `sizes.len() - 1` entries or be empty.
    pub dropout_keep: Vec<f32>,
}

impl MlpSpec {
    /// Paper's LeNet300: 784-300-100-10, tanh (P1 = 266,200 weights,
    /// P0 = 410 biases).
    pub fn lenet300() -> MlpSpec {
        MlpSpec {
            sizes: vec![784, 300, 100, 10],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        }
    }

    /// Deep-MLP stand-in for the paper's LeNet5 (ReLU + dropout on the
    /// dense layers; ≈560k parameters — same order as LeNet5's 430k).
    pub fn lenet5_mlp() -> MlpSpec {
        MlpSpec {
            sizes: vec![784, 500, 300, 100, 10],
            hidden_activation: Activation::Relu,
            dropout_keep: vec![1.0, 0.5, 0.5, 1.0],
        }
    }

    /// Single-hidden-layer net used by the Fig. 6 tradeoff experiment.
    pub fn single_hidden(d: usize, h: usize, classes: usize) -> MlpSpec {
        MlpSpec {
            sizes: vec![d, h, classes],
            hidden_activation: Activation::Tanh,
            dropout_keep: vec![],
        }
    }

    pub fn n_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    /// Count of multiplicative weights (P1) and biases (P0).
    pub fn param_counts(&self) -> (usize, usize) {
        let mut p1 = 0;
        let mut p0 = 0;
        for w in self.sizes.windows(2) {
            p1 += w[0] * w[1];
            p0 += w[1];
        }
        (p1, p0)
    }
}

/// One dense layer.
#[derive(Clone, Debug)]
pub struct Dense {
    /// (in, out) row-major.
    pub w: Mat,
    pub b: Vec<f32>,
    pub act: Activation,
    pub keep: f32,
}

/// Per-layer gradients.
#[derive(Clone, Debug)]
pub struct Grads {
    pub dw: Vec<Mat>,
    pub db: Vec<Vec<f32>>,
}

impl Grads {
    pub fn zeros_like(net: &Mlp) -> Grads {
        Grads {
            dw: net.layers.iter().map(|l| Mat::zeros(l.w.rows, l.w.cols)).collect(),
            db: net.layers.iter().map(|l| vec![0.0; l.b.len()]).collect(),
        }
    }
}

/// The MLP.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub spec: MlpSpec,
    pub layers: Vec<Dense>,
}

/// Activations cached by `forward` for the backward pass.
pub struct ForwardCache {
    /// inputs[l] = input to layer l (post-dropout); inputs[0] = x.
    inputs: Vec<Mat>,
    /// outputs[l] = activation output of layer l.
    outputs: Vec<Mat>,
    /// dropout masks (empty when not training / keep == 1).
    masks: Vec<Vec<f32>>,
}

impl Mlp {
    /// Glorot-uniform initialization.
    pub fn new(spec: &MlpSpec, seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        let keeps = if spec.dropout_keep.is_empty() {
            vec![1.0; spec.n_layers()]
        } else {
            assert_eq!(spec.dropout_keep.len(), spec.n_layers());
            spec.dropout_keep.clone()
        };
        for (li, win) in spec.sizes.windows(2).enumerate() {
            let (fan_in, fan_out) = (win[0], win[1]);
            let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
            let mut w = Mat::zeros(fan_in, fan_out);
            for v in w.data.iter_mut() {
                *v = rng.uniform_in(-limit, limit);
            }
            let act = if li + 1 == spec.n_layers() {
                Activation::Linear
            } else {
                spec.hidden_activation
            };
            layers.push(Dense { w, b: vec![0.0; fan_out], act, keep: keeps[li] });
        }
        Mlp { spec: spec.clone(), layers }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Rebuild a net from per-layer weight vectors and biases (e.g. the
    /// dense expansion of a packed model). Panics on shape mismatch.
    pub fn from_parts(spec: &MlpSpec, weights: &[Vec<f32>], biases: &[Vec<f32>]) -> Mlp {
        let mut net = Mlp::new(spec, 0);
        assert_eq!(weights.len(), net.n_layers());
        assert_eq!(biases.len(), net.n_layers());
        net.set_weights(weights);
        for (l, b) in net.layers.iter_mut().zip(biases) {
            assert_eq!(l.b.len(), b.len());
            l.b.copy_from_slice(b);
        }
        net
    }

    /// Forward pass. `train` enables dropout (inverted scaling); `rng` is
    /// only used when dropout is active.
    pub fn forward(&self, x: &Mat, train: bool, rng: Option<&mut Rng>) -> (Mat, ForwardCache) {
        let mut cache = ForwardCache { inputs: Vec::new(), outputs: Vec::new(), masks: Vec::new() };
        let mut cur = x.clone();
        let mut local_rng = rng;
        for layer in &self.layers {
            // dropout on the layer input
            let mask = if train && layer.keep < 1.0 {
                let r = local_rng.as_deref_mut().expect("dropout needs rng");
                let inv = 1.0 / layer.keep;
                let mut m = vec![0.0f32; cur.data.len()];
                for (mi, v) in m.iter_mut().zip(cur.data.iter_mut()) {
                    if (r.uniform() as f32) < layer.keep {
                        *mi = inv;
                        *v *= inv;
                    } else {
                        *mi = 0.0;
                        *v = 0.0;
                    }
                }
                m
            } else {
                Vec::new()
            };
            cache.masks.push(mask);
            cache.inputs.push(cur.clone());
            let mut z = matmul(&cur, &layer.w);
            for r in 0..z.rows {
                let row = z.row_mut(r);
                for (v, b) in row.iter_mut().zip(&layer.b) {
                    *v += b;
                }
            }
            match layer.act {
                Activation::Tanh => {
                    for v in z.data.iter_mut() {
                        *v = v.tanh();
                    }
                }
                Activation::Relu => {
                    for v in z.data.iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                Activation::Linear => {}
            }
            cache.outputs.push(z.clone());
            cur = z;
        }
        (cur, cache)
    }

    /// Backward pass from the loss gradient wrt logits. Returns parameter
    /// gradients.
    pub fn backward(&self, dlogits: &Mat, cache: &ForwardCache) -> Grads {
        let mut grads = Grads::zeros_like(self);
        let mut delta = dlogits.clone();
        for l in (0..self.layers.len()).rev() {
            let layer = &self.layers[l];
            // activation derivative (output cached)
            match layer.act {
                Activation::Tanh => {
                    let out = &cache.outputs[l];
                    for i in 0..delta.data.len() {
                        let a = out.data[i];
                        delta.data[i] *= 1.0 - a * a;
                    }
                }
                Activation::Relu => {
                    let out = &cache.outputs[l];
                    for i in 0..delta.data.len() {
                        if out.data[i] <= 0.0 {
                            delta.data[i] = 0.0;
                        }
                    }
                }
                Activation::Linear => {}
            }
            // dW = Xᵀ·delta ; db = column sums of delta
            grads.dw[l] = matmul_at_b(&cache.inputs[l], &delta);
            let db = &mut grads.db[l];
            for r in 0..delta.rows {
                for (c, v) in delta.row(r).iter().enumerate() {
                    db[c] += v;
                }
            }
            if l > 0 {
                // dX = delta·Wᵀ, then dropout mask
                let mut dx = matmul_a_bt(&delta, &layer.w);
                if !cache.masks[l].is_empty() {
                    for (v, m) in dx.data.iter_mut().zip(&cache.masks[l]) {
                        *v *= m;
                    }
                }
                delta = dx;
            }
        }
        grads
    }

    /// Convenience: loss + grads + error for a classification batch.
    pub fn loss_and_grads(
        &self,
        x: &Mat,
        y_onehot: &Mat,
        labels: &[u8],
        train: bool,
        rng: Option<&mut Rng>,
    ) -> (f32, f32, Grads) {
        let (logits, cache) = self.forward(x, train, rng);
        let (loss, probs) = super::loss::softmax_cross_entropy(&logits, y_onehot);
        let err = super::loss::error_rate(&logits, labels);
        let dlogits = super::loss::cross_entropy_grad(&probs, y_onehot);
        (loss, err, self.backward(&dlogits, &cache))
    }

    /// Evaluate loss and error (no dropout).
    pub fn evaluate(&self, x: &Mat, y_onehot: &Mat, labels: &[u8]) -> (f32, f32) {
        let (logits, _) = self.forward(x, false, None);
        let (loss, _) = super::loss::softmax_cross_entropy(&logits, y_onehot);
        (loss, super::loss::error_rate(&logits, labels))
    }

    /// Evaluate over a dataset in chunks (memory-bounded).
    pub fn evaluate_dataset(&self, data: &crate::data::Dataset, chunk: usize) -> (f32, f32) {
        let n = data.len();
        let mut loss_sum = 0.0f64;
        let mut err_sum = 0.0f64;
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            let b = end - start;
            let mut x = Mat::zeros(b, data.dim());
            let mut y = Mat::zeros(b, data.n_classes);
            let mut labels = Vec::with_capacity(b);
            for (r, i) in (start..end).enumerate() {
                x.row_mut(r).copy_from_slice(data.images.row(i));
                y[(r, data.labels[i] as usize)] = 1.0;
                labels.push(data.labels[i]);
            }
            let (l, e) = self.evaluate(&x, &y, &labels);
            loss_sum += l as f64 * b as f64;
            err_sum += e as f64 * b as f64;
            start = end;
        }
        ((loss_sum / n as f64) as f32, (err_sum / n as f64) as f32)
    }

    // ---- parameter views for the coordinator / quantizer ----------------

    /// Per-layer multiplicative weight slices (the quantized parameters;
    /// biases stay full precision, paper §5).
    pub fn weights(&self) -> Vec<&[f32]> {
        self.layers.iter().map(|l| l.w.data.as_slice()).collect()
    }

    pub fn weights_mut(&mut self) -> Vec<&mut [f32]> {
        self.layers.iter_mut().map(|l| l.w.data.as_mut_slice()).collect()
    }

    /// Copy all multiplicative weights into per-layer owned vectors.
    pub fn weights_cloned(&self) -> Vec<Vec<f32>> {
        self.layers.iter().map(|l| l.w.data.clone()).collect()
    }

    /// Overwrite weights from per-layer vectors.
    pub fn set_weights(&mut self, per_layer: &[Vec<f32>]) {
        assert_eq!(per_layer.len(), self.layers.len());
        for (l, w) in self.layers.iter_mut().zip(per_layer) {
            assert_eq!(l.w.data.len(), w.len());
            l.w.data.copy_from_slice(w);
        }
    }

    /// Total multiplicative weights (P1) and biases (P0).
    pub fn param_counts(&self) -> (usize, usize) {
        self.spec.param_counts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_net(seed: u64) -> Mlp {
        Mlp::new(
            &MlpSpec {
                sizes: vec![4, 6, 3],
                hidden_activation: Activation::Tanh,
                dropout_keep: vec![],
            },
            seed,
        )
    }

    fn toy_batch(rng: &mut Rng, b: usize) -> (Mat, Mat, Vec<u8>) {
        let mut x = Mat::zeros(b, 4);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        let mut y = Mat::zeros(b, 3);
        let mut labels = Vec::new();
        for r in 0..b {
            let l = rng.below(3);
            y[(r, l)] = 1.0;
            labels.push(l as u8);
        }
        (x, y, labels)
    }

    #[test]
    fn param_counts_match_paper() {
        let (p1, p0) = MlpSpec::lenet300().param_counts();
        assert_eq!(p1, 266_200); // paper: P1 = 266,200
        assert_eq!(p0, 410); // paper: P0 = 410
    }

    #[test]
    fn forward_shapes() {
        let net = toy_net(1);
        let mut rng = Rng::new(2);
        let (x, _, _) = toy_batch(&mut rng, 5);
        let (logits, cache) = net.forward(&x, false, None);
        assert_eq!(logits.rows, 5);
        assert_eq!(logits.cols, 3);
        assert_eq!(cache.inputs.len(), 2);
        assert_eq!(cache.outputs.len(), 2);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut net = toy_net(3);
        let mut rng = Rng::new(4);
        let (x, y, labels) = toy_batch(&mut rng, 7);
        let (_, _, grads) = net.loss_and_grads(&x, &y, &labels, false, None);
        let eps = 1e-3;
        // check a scatter of weight and bias entries in every layer
        for l in 0..net.n_layers() {
            for &idx in &[0usize, 3, 11] {
                if idx >= net.layers[l].w.data.len() {
                    continue;
                }
                let orig = net.layers[l].w.data[idx];
                net.layers[l].w.data[idx] = orig + eps;
                let (lp, _) = net.evaluate(&x, &y, &labels);
                net.layers[l].w.data[idx] = orig - eps;
                let (lm, _) = net.evaluate(&x, &y, &labels);
                net.layers[l].w.data[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads.dw[l].data[idx];
                assert!(
                    (fd - an).abs() < 2e-3,
                    "layer {l} w[{idx}]: fd {fd} vs analytic {an}"
                );
            }
            for &idx in &[0usize, 2] {
                if idx >= net.layers[l].b.len() {
                    continue;
                }
                let orig = net.layers[l].b[idx];
                net.layers[l].b[idx] = orig + eps;
                let (lp, _) = net.evaluate(&x, &y, &labels);
                net.layers[l].b[idx] = orig - eps;
                let (lm, _) = net.evaluate(&x, &y, &labels);
                net.layers[l].b[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads.db[l][idx];
                assert!(
                    (fd - an).abs() < 2e-3,
                    "layer {l} b[{idx}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn relu_gradients_match_finite_differences() {
        let mut net = Mlp::new(
            &MlpSpec {
                sizes: vec![3, 5, 2],
                hidden_activation: Activation::Relu,
                dropout_keep: vec![],
            },
            5,
        );
        let mut rng = Rng::new(6);
        let mut x = Mat::zeros(4, 3);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        let mut y = Mat::zeros(4, 2);
        let labels = vec![0u8, 1, 0, 1];
        for (r, &l) in labels.iter().enumerate() {
            y[(r, l as usize)] = 1.0;
        }
        let (_, _, grads) = net.loss_and_grads(&x, &y, &labels, false, None);
        let eps = 1e-3;
        for &idx in &[0usize, 7, 13] {
            let orig = net.layers[0].w.data[idx];
            net.layers[0].w.data[idx] = orig + eps;
            let (lp, _) = net.evaluate(&x, &y, &labels);
            net.layers[0].w.data[idx] = orig - eps;
            let (lm, _) = net.evaluate(&x, &y, &labels);
            net.layers[0].w.data[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - grads.dw[0].data[idx]).abs() < 2e-3);
        }
    }

    #[test]
    fn dropout_scales_expectation() {
        let spec = MlpSpec {
            sizes: vec![10, 8, 2],
            hidden_activation: Activation::Relu,
            dropout_keep: vec![0.5, 1.0],
        };
        let net = Mlp::new(&spec, 7);
        let x = Mat::from_vec(1, 10, vec![1.0; 10]);
        // Average many dropout forwards ≈ eval forward (inverted dropout).
        let mut rng = Rng::new(8);
        let mut acc = vec![0.0f64; 2];
        let n = 3000;
        for _ in 0..n {
            let (out, _) = net.forward(&x, true, Some(&mut rng));
            for (a, v) in acc.iter_mut().zip(&out.data) {
                *a += *v as f64;
            }
        }
        let (eval_out, _) = net.forward(&x, false, None);
        for (a, e) in acc.iter().zip(&eval_out.data) {
            let mean = *a / n as f64;
            assert!(
                (mean - *e as f64).abs() < 0.25,
                "dropout mean {mean} vs eval {e}"
            );
        }
    }

    #[test]
    fn set_weights_roundtrip() {
        let mut net = toy_net(9);
        let mut w = net.weights_cloned();
        w[0][0] = 123.0;
        net.set_weights(&w);
        assert_eq!(net.layers[0].w.data[0], 123.0);
        assert_eq!(net.weights()[0][0], 123.0);
    }

    #[test]
    fn training_reduces_loss_on_toy_problem() {
        use crate::nn::sgd::{Nesterov, SgdConfig};
        let mut net = toy_net(11);
        let mut rng = Rng::new(12);
        let (x, y, labels) = toy_batch(&mut rng, 64);
        let (loss0, _) = net.evaluate(&x, &y, &labels);
        let mut opt = Nesterov::new(&net, SgdConfig { lr: 0.5, momentum: 0.9 });
        for _ in 0..100 {
            let (_, _, g) = net.loss_and_grads(&x, &y, &labels, false, None);
            opt.step(&mut net, &g, None);
        }
        let (loss1, _) = net.evaluate(&x, &y, &labels);
        assert!(loss1 < loss0 * 0.5, "loss {loss0} -> {loss1}");
    }
}
