//! CIFAR10 stand-in: 32×32×3 synthetic images (paper §5.4).
//!
//! Each class is a smooth random color field (low-frequency cosine mixture
//! with class-specific coefficients) composited with a class-specific
//! geometric blob; instances add random phase shifts and pixel noise. The
//! set is learnable by a small conv/dense net but not linearly trivial,
//! which is what the §5.4 experiment needs (train a net, quantize at K=2,
//! compare test error).

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;

pub const SIDE: usize = 32;
pub const CHANNELS: usize = 3;
pub const DIM: usize = SIDE * SIDE * CHANNELS;

/// Class-conditional cosine-mixture texture parameters.
struct ClassProto {
    // per channel: (freq_x, freq_y, phase, amplitude) × 3 components
    comps: [[(f32, f32, f32, f32); 3]; CHANNELS],
    // blob centre/radius per class
    blob: (f32, f32, f32),
}

fn proto(class: u8, rng: &mut Rng) -> ClassProto {
    // Derive deterministic per-class parameters from a class-seeded stream.
    let mut crng = Rng::new(0xC1FA_u64 * 131 + class as u64);
    let mut comps = [[(0.0, 0.0, 0.0, 0.0); 3]; CHANNELS];
    for ch in comps.iter_mut() {
        for comp in ch.iter_mut() {
            *comp = (
                crng.uniform_in(0.5, 3.5),
                crng.uniform_in(0.5, 3.5),
                crng.uniform_in(0.0, std::f32::consts::TAU),
                crng.uniform_in(0.1, 0.35),
            );
        }
    }
    let blob = (
        crng.uniform_in(0.25, 0.75) + rng.normal(0.0, 0.04),
        crng.uniform_in(0.25, 0.75) + rng.normal(0.0, 0.04),
        crng.uniform_in(0.12, 0.3),
    );
    ClassProto { comps, blob }
}

/// Generate `n` images. Layout: channel-major rows, i.e. `[c][y][x]`
/// flattened — matches how the conv net in `python/compile/model.py`
/// interprets the input.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut images = Mat::zeros(n, DIM);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = (i % 10) as u8;
        let p = proto(class, &mut rng);
        let phase_jitter = rng.uniform_in(0.0, 1.5);
        let noise = 0.06;
        let row = images.row_mut(i);
        for c in 0..CHANNELS {
            for y in 0..SIDE {
                for x in 0..SIDE {
                    let (fx, fy) = (x as f32 / SIDE as f32, y as f32 / SIDE as f32);
                    let mut v = 0.5f32;
                    for &(wx, wy, ph, amp) in &p.comps[c] {
                        v += amp
                            * (std::f32::consts::TAU * (wx * fx + wy * fy)
                                + ph
                                + phase_jitter)
                                .cos();
                    }
                    // blob mask raises one channel inside the class blob
                    let d2 = (fx - p.blob.0).powi(2) + (fy - p.blob.1).powi(2);
                    if c == (class as usize % 3) {
                        v += 0.5 * (-d2 / (p.blob.2 * p.blob.2)).exp();
                    }
                    v += rng.normal(0.0, noise);
                    row[c * SIDE * SIDE + y * SIDE + x] = v.clamp(0.0, 1.0);
                }
            }
        }
        labels.push(class);
    }
    let perm = rng.permutation(n);
    let mut shuffled = Mat::zeros(n, DIM);
    let mut shuffled_labels = vec![0u8; n];
    for (dst, &src) in perm.iter().enumerate() {
        shuffled.row_mut(dst).copy_from_slice(images.row(src));
        shuffled_labels[dst] = labels[src];
    }
    Dataset { images: shuffled, labels: shuffled_labels, n_classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = generate(20, 4);
        assert_eq!(a.dim(), DIM);
        assert_eq!(a.len(), 20);
        let b = generate(20, 4);
        assert_eq!(a.images.data, b.images.data);
    }

    #[test]
    fn values_in_unit_range() {
        let d = generate(10, 6);
        assert!(d.images.data.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn classes_distinguishable() {
        let d = generate(100, 8);
        // nearest-centroid in pixel space should beat chance comfortably
        let mut centroids = vec![vec![0.0f64; DIM]; 10];
        let mut counts = [0usize; 10];
        for i in 0..50 {
            let l = d.labels[i] as usize;
            counts[l] += 1;
            for (j, &v) in d.images.row(i).iter().enumerate() {
                centroids[l][j] += v as f64;
            }
        }
        for (c, cnt) in centroids.iter_mut().zip(counts) {
            for v in c.iter_mut() {
                *v /= cnt.max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 50..100 {
            let row = d.images.row(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = row
                        .iter()
                        .zip(&centroids[a])
                        .map(|(x, c)| (*x as f64 - c).powi(2))
                        .sum();
                    let db: f64 = row
                        .iter()
                        .zip(&centroids[b])
                        .map(|(x, c)| (*x as f64 - c).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == d.labels[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 25, "nearest-centroid accuracy too low: {correct}/50");
    }
}
