//! Minibatch iteration with epoch shuffling.

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// A minibatch: inputs `[b, dim]`, one-hot targets `[b, n_classes]` and the
/// raw labels. Reused across steps via [`Batcher::next_batch_into`].
pub struct Batch {
    pub x: Mat,
    pub y: Mat,
    pub labels: Vec<u8>,
}

impl Batch {
    /// An empty batch; [`Batcher::next_batch_into`] sizes it on first use.
    pub fn empty() -> Batch {
        Batch { x: Mat::zeros(0, 0), y: Mat::zeros(0, 0), labels: Vec::new() }
    }
}

/// Cyclic minibatcher: shuffles indices each epoch, yields fixed-size
/// batches (the last partial batch of an epoch is dropped, like the paper's
/// fixed 512-point minibatches).
pub struct Batcher {
    order: Vec<usize>,
    pos: usize,
    pub batch_size: usize,
    rng: Rng,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(n: usize, batch_size: usize, seed: u64) -> Batcher {
        assert!(batch_size > 0 && batch_size <= n, "batch {batch_size} > n {n}");
        let mut rng = Rng::new(seed);
        let order = rng.permutation(n);
        Batcher { order, pos: 0, batch_size, rng, epoch: 0 }
    }

    /// Next batch of indices; reshuffles when the epoch is exhausted.
    pub fn next_indices(&mut self) -> &[usize] {
        if self.pos + self.batch_size > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            self.epoch += 1;
        }
        let s = &self.order[self.pos..self.pos + self.batch_size];
        self.pos += self.batch_size;
        s
    }

    /// Materialize the next batch into a reusable [`Batch`] — the step-path
    /// form: after the first call sizes the buffers, subsequent calls
    /// perform no heap allocation.
    pub fn next_batch_into(&mut self, data: &Dataset, out: &mut Batch) {
        let b = self.batch_size;
        if out.x.rows != b || out.x.cols != data.dim() {
            out.x = Mat::zeros(b, data.dim());
        }
        if out.y.rows != b || out.y.cols != data.n_classes {
            out.y = Mat::zeros(b, data.n_classes);
        }
        out.y.data.fill(0.0);
        out.labels.clear();
        for (r, &i) in self.next_indices().iter().enumerate() {
            out.x.row_mut(r).copy_from_slice(data.images.row(i));
            out.y[(r, data.labels[i] as usize)] = 1.0;
            out.labels.push(data.labels[i]);
        }
    }

    /// Materialize the next batch from a dataset (allocating convenience).
    pub fn next_batch(&mut self, data: &Dataset) -> Batch {
        let mut out = Batch::empty();
        self.next_batch_into(data, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist::SynthMnist;

    #[test]
    fn batches_cover_epoch() {
        let mut b = Batcher::new(10, 3, 1);
        let mut seen = vec![0usize; 10];
        for _ in 0..3 {
            for &i in b.next_indices() {
                seen[i] += 1;
            }
        }
        // 9 of 10 indices seen exactly once in epoch 0 (last partial dropped)
        assert_eq!(seen.iter().sum::<usize>(), 9);
        assert!(seen.iter().all(|&c| c <= 1));
        assert_eq!(b.epoch, 0);
        b.next_indices();
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn batch_contents_match_dataset() {
        let data = SynthMnist::generate(20, 2);
        let mut b = Batcher::new(20, 4, 3);
        let batch = b.next_batch(&data);
        assert_eq!(batch.x.rows, 4);
        assert_eq!(batch.y.rows, 4);
        for r in 0..4 {
            let l = batch.labels[r] as usize;
            assert_eq!(batch.y[(r, l)], 1.0);
            assert_eq!(batch.y.row(r).iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn into_form_matches_allocating_form() {
        let data = SynthMnist::generate(30, 5);
        let mut a = Batcher::new(30, 8, 9);
        let mut b = Batcher::new(30, 8, 9);
        let mut buf = Batch::empty();
        for _ in 0..6 {
            let fresh = a.next_batch(&data);
            b.next_batch_into(&data, &mut buf);
            assert_eq!(fresh.x.data, buf.x.data);
            assert_eq!(fresh.y.data, buf.y.data);
            assert_eq!(fresh.labels, buf.labels);
        }
        assert_eq!(a.epoch, b.epoch);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Batcher::new(50, 8, 7);
        let mut b = Batcher::new(50, 8, 7);
        for _ in 0..20 {
            assert_eq!(a.next_indices(), b.next_indices());
        }
    }

    #[test]
    #[should_panic]
    fn batch_larger_than_dataset_panics() {
        let _ = Batcher::new(5, 10, 0);
    }
}
