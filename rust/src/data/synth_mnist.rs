//! Procedural MNIST stand-in: 28×28 grayscale digits rendered from
//! per-class stroke templates with random affine jitter and pixel noise.
//!
//! Each digit class is a polyline/ellipse skeleton in a normalized [0,1]²
//! box, rasterized with a Gaussian pen. Jitter (shift, rotation, scale,
//! stroke width) makes the classes non-trivially separable; an
//! MLP of LeNet300 capacity reaches ≈0% train error, which is the regime
//! the paper's experiments operate in (reference nets at 0% E_train).

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;

/// A stroke: straight segment or elliptical arc in template space.
#[derive(Clone, Copy)]
enum Stroke {
    /// Segment (x0,y0) → (x1,y1).
    Seg(f32, f32, f32, f32),
    /// Elliptic arc centred (cx,cy), radii (rx,ry), angles [a0,a1] radians.
    Arc(f32, f32, f32, f32, f32, f32),
}

use Stroke::*;

const TAU: f32 = std::f32::consts::TAU;
const PI: f32 = std::f32::consts::PI;

/// Skeletons in a [0,1]² box, y increasing downward.
fn template(class: u8) -> Vec<Stroke> {
    match class {
        0 => vec![Arc(0.5, 0.5, 0.30, 0.40, 0.0, TAU)],
        1 => vec![Seg(0.35, 0.30, 0.55, 0.12), Seg(0.55, 0.12, 0.55, 0.88)],
        2 => vec![
            Arc(0.5, 0.30, 0.25, 0.20, PI, TAU),
            Seg(0.75, 0.32, 0.25, 0.88),
            Seg(0.25, 0.88, 0.78, 0.88),
        ],
        3 => vec![
            Arc(0.48, 0.30, 0.24, 0.19, -0.6 * PI, 0.5 * PI),
            Arc(0.48, 0.69, 0.26, 0.20, -0.5 * PI, 0.6 * PI),
        ],
        4 => vec![
            Seg(0.62, 0.10, 0.22, 0.60),
            Seg(0.22, 0.60, 0.80, 0.60),
            Seg(0.62, 0.10, 0.62, 0.90),
        ],
        5 => vec![
            Seg(0.75, 0.12, 0.30, 0.12),
            Seg(0.30, 0.12, 0.28, 0.48),
            Arc(0.48, 0.67, 0.26, 0.22, -0.5 * PI, 0.7 * PI),
        ],
        6 => vec![
            Arc(0.52, 0.30, 0.26, 0.32, 0.6 * PI, 1.3 * PI),
            Arc(0.48, 0.68, 0.22, 0.20, 0.0, TAU),
        ],
        7 => vec![Seg(0.22, 0.14, 0.78, 0.14), Seg(0.78, 0.14, 0.40, 0.90)],
        8 => vec![
            Arc(0.5, 0.30, 0.20, 0.17, 0.0, TAU),
            Arc(0.5, 0.68, 0.24, 0.21, 0.0, TAU),
        ],
        9 => vec![
            Arc(0.52, 0.32, 0.22, 0.20, 0.0, TAU),
            Seg(0.74, 0.34, 0.60, 0.90),
        ],
        _ => panic!("class must be 0..=9"),
    }
}

/// Sample points densely along a stroke (in template coordinates).
fn sample_stroke(s: &Stroke, out: &mut Vec<(f32, f32)>) {
    const STEPS: usize = 24;
    match *s {
        Seg(x0, y0, x1, y1) => {
            for i in 0..=STEPS {
                let t = i as f32 / STEPS as f32;
                out.push((x0 + t * (x1 - x0), y0 + t * (y1 - y0)));
            }
        }
        Arc(cx, cy, rx, ry, a0, a1) => {
            for i in 0..=STEPS {
                let t = a0 + (a1 - a0) * i as f32 / STEPS as f32;
                out.push((cx + rx * t.cos(), cy + ry * t.sin()));
            }
        }
    }
}

/// Render one digit with the given jitter into a DIM-length buffer.
#[allow(clippy::too_many_arguments)]
fn render(
    class: u8,
    dx: f32,
    dy: f32,
    rot: f32,
    sx: f32,
    sy: f32,
    pen: f32,
    noise_rng: &mut Rng,
    noise: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), DIM);
    let mut pts: Vec<(f32, f32)> = Vec::with_capacity(128);
    for s in template(class) {
        sample_stroke(&s, &mut pts);
    }
    // affine: centre, scale, rotate, translate; map to pixel coords.
    let (sinr, cosr) = rot.sin_cos();
    let px: Vec<(f32, f32)> = pts
        .iter()
        .map(|&(x, y)| {
            let (x, y) = ((x - 0.5) * sx, (y - 0.5) * sy);
            let (x, y) = (x * cosr - y * sinr, x * sinr + y * cosr);
            (
                (x + 0.5 + dx) * (SIDE as f32 - 1.0),
                (y + 0.5 + dy) * (SIDE as f32 - 1.0),
            )
        })
        .collect();
    let inv2s2 = 1.0 / (2.0 * pen * pen);
    // Rasterize with a Gaussian pen. For efficiency, only pixels within a
    // 3-pen radius of a sample point are touched.
    out.fill(0.0);
    let rad = (3.0 * pen).ceil() as i64;
    for &(x, y) in &px {
        let (cx, cy) = (x.round() as i64, y.round() as i64);
        for py in (cy - rad).max(0)..=(cy + rad).min(SIDE as i64 - 1) {
            for pxx in (cx - rad).max(0)..=(cx + rad).min(SIDE as i64 - 1) {
                let d2 = (pxx as f32 - x).powi(2) + (py as f32 - y).powi(2);
                let v = (-d2 * inv2s2).exp();
                let cell = &mut out[py as usize * SIDE + pxx as usize];
                *cell = cell.max(v);
            }
        }
    }
    if noise > 0.0 {
        for v in out.iter_mut() {
            *v = (*v + noise_rng.normal(0.0, noise)).clamp(0.0, 1.0);
        }
    }
}

/// Deterministic synthetic MNIST-like dataset.
pub struct SynthMnist;

impl SynthMnist {
    /// Generate `n` images with the default jitter/noise profile.
    pub fn generate(n: usize, seed: u64) -> Dataset {
        Self::generate_with(n, seed, 0.08)
    }

    /// Generate with an explicit pixel-noise level.
    pub fn generate_with(n: usize, seed: u64, noise: f32) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut images = Mat::zeros(n, DIM);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = (i % 10) as u8;
            let dx = rng.normal(0.0, 0.04);
            let dy = rng.normal(0.0, 0.04);
            let rot = rng.normal(0.0, 0.10);
            let sx = 1.0 + rng.normal(0.0, 0.08);
            let sy = 1.0 + rng.normal(0.0, 0.08);
            let pen = 1.1 + rng.uniform_in(0.0, 0.5);
            render(
                class,
                dx,
                dy,
                rot,
                sx,
                sy,
                pen,
                &mut rng,
                noise,
                images.row_mut(i),
            );
            labels.push(class);
        }
        // Shuffle so class order is not the index order.
        let perm = rng.permutation(n);
        let mut shuffled = Mat::zeros(n, DIM);
        let mut shuffled_labels = vec![0u8; n];
        for (dst, &src) in perm.iter().enumerate() {
            shuffled.row_mut(dst).copy_from_slice(images.row(src));
            shuffled_labels[dst] = labels[src];
        }
        Dataset { images: shuffled, labels: shuffled_labels, n_classes: 10 }
    }

    /// Raw 28×28 digit images (no noise, no label shuffle) — used by the
    /// super-resolution experiment as the high-resolution targets.
    pub fn clean_images(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut images = Mat::zeros(n, DIM);
        for i in 0..n {
            let class = (i % 10) as u8;
            let dx = rng.normal(0.0, 0.04);
            let dy = rng.normal(0.0, 0.04);
            let rot = rng.normal(0.0, 0.10);
            let sx = 1.0 + rng.normal(0.0, 0.08);
            let sy = 1.0 + rng.normal(0.0, 0.08);
            let pen = 1.1 + rng.uniform_in(0.0, 0.5);
            render(class, dx, dy, rot, sx, sy, pen, &mut rng, 0.0, images.row_mut(i));
        }
        images
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = SynthMnist::generate(50, 1);
        let b = SynthMnist::generate(50, 1);
        assert_eq!(a.images.data, b.images.data);
        assert_eq!(a.labels, b.labels);
        let c = SynthMnist::generate(50, 2);
        assert_ne!(a.images.data, c.images.data);
    }

    #[test]
    fn shapes_and_ranges() {
        let d = SynthMnist::generate(30, 3);
        assert_eq!(d.len(), 30);
        assert_eq!(d.dim(), DIM);
        assert_eq!(d.n_classes, 10);
        assert!(d.images.data.iter().all(|v| (0.0..=1.0).contains(v)));
        // all 10 classes present
        let mut seen = [false; 10];
        for &l in &d.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn digits_have_ink_and_differ() {
        let imgs = SynthMnist::clean_images(10, 5);
        for i in 0..10 {
            let ink: f32 = imgs.row(i).iter().sum();
            assert!(ink > 5.0, "class {i} has too little ink: {ink}");
        }
        // class templates are distinguishable: pairwise L2 distances nonzero
        for i in 0..10 {
            for j in (i + 1)..10 {
                let d = crate::linalg::vecops::l2_dist(imgs.row(i), imgs.row(j));
                assert!(d > 1.0, "classes {i},{j} too similar: {d}");
            }
        }
    }

    #[test]
    fn classes_cluster_tighter_than_between() {
        // mean within-class distance < mean between-class distance
        let d = SynthMnist::generate_with(200, 7, 0.02);
        let (mut wsum, mut wn, mut bsum, mut bn) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let dist =
                    crate::linalg::vecops::l2_dist(d.images.row(i), d.images.row(j)) as f64;
                if d.labels[i] == d.labels[j] {
                    wsum += dist;
                    wn += 1;
                } else {
                    bsum += dist;
                    bn += 1;
                }
            }
        }
        let (w, b) = (wsum / wn as f64, bsum / bn as f64);
        assert!(w < b, "within {w} should be < between {b}");
    }
}
