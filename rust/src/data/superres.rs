//! Super-resolution regression dataset (paper §5.2).
//!
//! The paper constructs low-resolution 14×14 images from MNIST 28×28 by
//! bicubic interpolation (Matlab) plus Gaussian noise, and trains a linear
//! regression x(low) → y(high). The optimal weight matrix is close to the
//! pseudo-inverse of the (sparse, few-distinct-coefficients) bicubic
//! operator, which gives the **clustered, non-Gaussian weight distribution**
//! the experiment studies. We reproduce the construction exactly: Keys
//! bicubic kernel (α = −0.5, Matlab's default), 2× decimation, additive
//! Gaussian noise on the low-res inputs.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Keys cubic convolution kernel with α = −0.5.
pub fn keys_cubic(x: f32) -> f32 {
    const A: f32 = -0.5;
    let x = x.abs();
    if x < 1.0 {
        (A + 2.0) * x * x * x - (A + 3.0) * x * x + 1.0
    } else if x < 2.0 {
        A * x * x * x - 5.0 * A * x * x + 8.0 * A * x - 4.0 * A
    } else {
        0.0
    }
}

/// Bicubic 2× downsample of a `side`×`side` image (row-major) to
/// `side/2`×`side/2`, with antialiasing scaling (kernel stretched by the
/// scale factor, as Matlab's imresize does when shrinking).
pub fn bicubic_downsample2(img: &[f32], side: usize) -> Vec<f32> {
    assert_eq!(img.len(), side * side);
    let out_side = side / 2;
    let scale = 2.0f32; // shrink factor
    let support = 2.0 * scale; // kernel support after stretching
    let mut out = vec![0.0f32; out_side * out_side];
    // Separable: precompute the 1-D weight pattern for each output coord.
    let mut taps: Vec<(usize, Vec<(usize, f32)>)> = Vec::with_capacity(out_side);
    for o in 0..out_side {
        // centre of output pixel o in input coordinates
        let c = (o as f32 + 0.5) * scale - 0.5;
        let lo = (c - support).floor().max(0.0) as usize;
        let hi = (c + support).ceil().min(side as f32 - 1.0) as usize;
        let mut w: Vec<(usize, f32)> = Vec::new();
        let mut sum = 0.0f32;
        for i in lo..=hi {
            let v = keys_cubic((i as f32 - c) / scale);
            if v != 0.0 {
                w.push((i, v));
                sum += v;
            }
        }
        for (_, v) in w.iter_mut() {
            *v /= sum;
        }
        taps.push((o, w));
    }
    // rows then columns
    let mut tmp = vec![0.0f32; side * out_side]; // [side rows, out_side cols]
    for r in 0..side {
        for (o, w) in &taps {
            let mut s = 0.0f32;
            for &(i, v) in w {
                s += img[r * side + i] * v;
            }
            tmp[r * out_side + *o] = s;
        }
    }
    for (o_r, w_r) in &taps {
        for oc in 0..out_side {
            let mut s = 0.0f32;
            for &(i, v) in w_r {
                s += tmp[i * out_side + oc] * v;
            }
            out[*o_r * out_side + oc] = s;
        }
    }
    out
}

/// The regression dataset: X (n, d_low) noisy low-res inputs, Y (n, d_high)
/// high-res targets.
pub struct SuperResData {
    pub x: Mat,
    pub y: Mat,
}

impl SuperResData {
    /// Build from `n` clean synthetic digits with the paper's construction.
    pub fn generate(n: usize, noise_std: f32, seed: u64) -> SuperResData {
        use super::synth_mnist::{SynthMnist, DIM, SIDE};
        let y = SynthMnist::clean_images(n, seed);
        let d_low = (SIDE / 2) * (SIDE / 2);
        let mut x = Mat::zeros(n, d_low);
        let mut rng = Rng::new(seed ^ 0xD0_5E5);
        for i in 0..n {
            let lo = bicubic_downsample2(y.row(i), SIDE);
            let row = x.row_mut(i);
            for (j, v) in lo.iter().enumerate() {
                row[j] = v + rng.normal(0.0, noise_std);
            }
        }
        debug_assert_eq!(y.cols, DIM);
        SuperResData { x, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_properties() {
        assert!((keys_cubic(0.0) - 1.0).abs() < 1e-6);
        assert!(keys_cubic(1.0).abs() < 1e-6);
        assert!(keys_cubic(2.0).abs() < 1e-6);
        assert_eq!(keys_cubic(2.5), 0.0);
        // symmetric
        assert_eq!(keys_cubic(0.7), keys_cubic(-0.7));
        // partition of unity at integer shifts: sum_k keys(x - k) == 1
        for xi in 0..20 {
            let x = xi as f32 * 0.1 - 1.0;
            let s: f32 = (-3..=3).map(|k| keys_cubic(x - k as f32)).sum();
            assert!((s - 1.0).abs() < 1e-5, "x={x} s={s}");
        }
    }

    #[test]
    fn downsample_constant_preserved() {
        let img = vec![0.7f32; 28 * 28];
        let lo = bicubic_downsample2(&img, 28);
        assert_eq!(lo.len(), 14 * 14);
        for v in lo {
            assert!((v - 0.7).abs() < 1e-4);
        }
    }

    #[test]
    fn downsample_linear_ramp_preserved() {
        // bicubic reproduces linear functions away from borders
        let mut img = vec![0.0f32; 28 * 28];
        for r in 0..28 {
            for c in 0..28 {
                img[r * 28 + c] = c as f32;
            }
        }
        let lo = bicubic_downsample2(&img, 28);
        for r in 3..11 {
            for c in 3..11 {
                let expect = (c as f32 + 0.5) * 2.0 - 0.5;
                assert!(
                    (lo[r * 14 + c] - expect).abs() < 0.05,
                    "r={r} c={c}: {} vs {}",
                    lo[r * 14 + c],
                    expect
                );
            }
        }
    }

    #[test]
    fn dataset_shapes_and_determinism() {
        let a = SuperResData::generate(20, 0.05, 9);
        assert_eq!(a.x.rows, 20);
        assert_eq!(a.x.cols, 196);
        assert_eq!(a.y.cols, 784);
        let b = SuperResData::generate(20, 0.05, 9);
        assert_eq!(a.x.data, b.x.data);
    }

    #[test]
    fn noise_actually_added() {
        let clean = SuperResData::generate(5, 0.0, 11);
        let noisy = SuperResData::generate(5, 0.1, 11);
        assert_eq!(clean.y.data, noisy.y.data); // targets identical
        assert_ne!(clean.x.data, noisy.x.data); // inputs perturbed
    }
}
