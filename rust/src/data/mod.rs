//! Dataset substrate.
//!
//! The paper trains on MNIST and CIFAR10; this environment has no network
//! access, so we build deterministic **synthetic** stand-ins that exercise
//! the same code paths (multi-class image classification with a learnable
//! structure, and a bicubic super-resolution regression set). The
//! substitution rationale is in DESIGN.md §3.

pub mod batcher;
pub mod cifar_like;
pub mod superres;
pub mod synth_mnist;

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// An in-memory classification dataset: row-major images `[n, dim]` plus
/// integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Mat,
    pub labels: Vec<u8>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
    pub fn dim(&self) -> usize {
        self.images.cols
    }

    /// Normalize pixels to zero mean (paper §5.3: "normalize the pixel
    /// grayscales to [0,1] and then subtract the mean"). Returns the mean so
    /// a test set can reuse the train-set statistics.
    pub fn subtract_mean(&mut self, mean: Option<f32>) -> f32 {
        let m = mean.unwrap_or_else(|| {
            self.images.data.iter().sum::<f32>() / self.images.data.len() as f32
        });
        for v in self.images.data.iter_mut() {
            *v -= m;
        }
        m
    }

    /// Random split into (train, test) with `test_frac` held out
    /// (paper: 90%/10%).
    pub fn split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.len();
        let n_test = ((n as f64) * test_frac).round() as usize;
        let perm = rng.permutation(n);
        let take = |idx: &[usize]| -> Dataset {
            let mut images = Mat::zeros(idx.len(), self.dim());
            let mut labels = Vec::with_capacity(idx.len());
            for (r, &i) in idx.iter().enumerate() {
                images.row_mut(r).copy_from_slice(self.images.row(i));
                labels.push(self.labels[i]);
            }
            Dataset { images, labels, n_classes: self.n_classes }
        };
        (take(&perm[n_test..]), take(&perm[..n_test]))
    }

    /// One-hot encode labels as an `[n, n_classes]` matrix.
    pub fn one_hot(&self) -> Mat {
        let mut y = Mat::zeros(self.len(), self.n_classes);
        for (i, &l) in self.labels.iter().enumerate() {
            y[(i, l as usize)] = 1.0;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let images = Mat::from_vec(4, 2, vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        Dataset { images, labels: vec![0, 1, 0, 1], n_classes: 2 }
    }

    #[test]
    fn split_sizes_and_contents() {
        let d = tiny();
        let mut rng = Rng::new(3);
        let (tr, te) = d.split(0.25, &mut rng);
        assert_eq!(tr.len(), 3);
        assert_eq!(te.len(), 1);
        // Every original row appears exactly once across the two splits.
        let mut rows: Vec<Vec<i64>> = Vec::new();
        for ds in [&tr, &te] {
            for r in 0..ds.len() {
                rows.push(ds.images.row(r).iter().map(|v| *v as i64).collect());
            }
        }
        rows.sort();
        assert_eq!(rows, vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]]);
    }

    #[test]
    fn mean_subtraction() {
        let mut d = tiny();
        let m = d.subtract_mean(None);
        assert!((m - 3.5).abs() < 1e-6);
        let new_mean: f32 = d.images.data.iter().sum::<f32>() / 8.0;
        assert!(new_mean.abs() < 1e-6);
        // Reusing a provided mean shifts by exactly that value.
        let mut d2 = tiny();
        d2.subtract_mean(Some(1.0));
        assert_eq!(d2.images[(0, 0)], -1.0);
    }

    #[test]
    fn one_hot_encoding() {
        let d = tiny();
        let y = d.one_hot();
        assert_eq!(y.row(0), &[1.0, 0.0]);
        assert_eq!(y.row(1), &[0.0, 1.0]);
    }
}
