//! Serve-fabric substrate: the static shard map, per-backend connection
//! pools, and the backend health state machine the router routes by.
//!
//! A **fabric** is a set of backend LCQ-RPC servers (each a plain
//! [`NetServer`](crate::net::NetServer)) described by a static shard map
//! from config (`serve.fabric`): each shard names the models it holds
//! (empty list = wildcard, "whatever the backend's hello catalog says")
//! and the replica addresses serving them. The router
//! ([`crate::net::router`]) holds one [`Backend`] per unique address and
//! consults this module for three things:
//!
//! * **candidates** — which backends can serve a model
//!   ([`Fabric::candidates`]), from the shard map plus the hello catalogs
//!   learned at handshake/probe time;
//! * **replica choice** — [`Fabric::pick`], a rotor scan preferring
//!   `Healthy` replicas, then `Suspect`, never `Down`, avoiding the
//!   backend that just failed when an alternative exists;
//! * **health** — a three-state machine per backend
//!   ([`HealthState`]), driven by passive signals (connect/IO errors ⇒
//!   `Down`, `Overloaded`/`ShuttingDown` frames ⇒ `Suspect`/`Down`,
//!   success ⇒ `Healthy`) and an active hello-probe loop
//!   ([`Fabric::probe_all`]) that both recovers `Down` backends and
//!   refreshes their catalogs. Every transition is counted per backend
//!   and in the global `fabric_health_transitions` counter, and the
//!   `fabric_backends_healthy`/`fabric_backends_down` gauges are
//!   recomputed on each transition.
//!
//! The state machine and pool discipline are documented (and doc-pinned
//! by `rust/tests/fabric.rs`) in `docs/FABRIC.md`.

use crate::net::proto::{self, Frame, FrameReader, ModelEntry};
use crate::obs::{self, CounterId, GaugeId};
use crate::util::backoff::BackoffCfg;
use crate::util::json::Json;
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Read-timeout tick used on backend sockets (mirrors the server's
/// shutdown poll so deadline checks run even against a silent peer).
pub(crate) const BACKEND_POLL: Duration = Duration::from_millis(25);

/// Cap on any single backend write.
const BACKEND_WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Idle connections kept per backend.
const POOL_CAP: usize = 8;

/// One shard of the map: the models a replica set holds. An empty
/// `models` list is a wildcard — route by the backend's hello catalog.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardConfig {
    /// Model names this shard serves (registry names, as on the wire).
    pub models: Vec<String>,
    /// Replica addresses (`host:port`), one backend process each.
    pub replicas: Vec<String>,
}

/// Fabric-wide routing knobs (config file: the `"fabric"` object inside
/// the `"serve"` section; see [`crate::config::FabricSettings`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FabricConfig {
    /// The static shard map.
    pub shards: Vec<ShardConfig>,
    /// Total forward attempts per request (first try included).
    pub retry_budget: usize,
    /// Per-request end-to-end deadline at the router: retries (and their
    /// backoff sleeps) never exceed it, so the client's patience bounds
    /// the router's persistence.
    pub deadline: Duration,
    /// Decorrelated-jitter backoff between forward attempts.
    pub backoff: BackoffCfg,
    /// Active hello-probe period (zero disables the probe loop; passive
    /// signals still drive health, but `Down` backends then only recover
    /// via a probe — keep it on outside tests).
    pub probe_every: Duration,
    /// TCP connect + handshake timeout for backend dials.
    pub connect_timeout: Duration,
    /// Seed for backoff jitter (per-request streams derive from it).
    pub seed: u64,
}

impl Default for FabricConfig {
    fn default() -> FabricConfig {
        FabricConfig {
            shards: Vec::new(),
            retry_budget: 4,
            deadline: Duration::from_secs(5),
            backoff: BackoffCfg {
                base: Duration::from_millis(1),
                cap: Duration::from_millis(50),
            },
            probe_every: Duration::from_millis(500),
            connect_timeout: Duration::from_secs(1),
            seed: 0,
        }
    }
}

/// Backend health, the router's routing signal. Stored per backend in an
/// atomic so handlers and the prober share it lock-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum HealthState {
    /// Answering normally; preferred by [`Fabric::pick`].
    Healthy = 0,
    /// Recently shed with `Overloaded` (or a router-side framing upset):
    /// used only when no `Healthy` replica exists.
    Suspect = 1,
    /// Connect/IO failure or `ShuttingDown`: never picked; only a
    /// successful hello probe promotes it back to `Healthy`.
    Down = 2,
}

impl HealthState {
    fn from_u8(v: u8) -> HealthState {
        match v {
            0 => HealthState::Healthy,
            1 => HealthState::Suspect,
            _ => HealthState::Down,
        }
    }

    /// Stable lowercase name (used in stats JSON and docs).
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Suspect => "suspect",
            HealthState::Down => "down",
        }
    }
}

/// One pooled backend connection: socket plus frame reassembly state and
/// the protocol version negotiated at handshake (a v2 backend must never
/// be sent a trace-context tail).
pub(crate) struct BackendConn {
    pub(crate) stream: TcpStream,
    pub(crate) reader: FrameReader,
    pub(crate) version: u32,
}

/// One backend replica: address, health, idle-connection pool, learned
/// catalog, and exact per-backend counters.
pub struct Backend {
    addr: String,
    /// Model-name filter from the shard map; empty = wildcard.
    filter: Vec<String>,
    state: AtomicU8,
    pool: Mutex<Vec<BackendConn>>,
    catalog: Mutex<Vec<ModelEntry>>,
    forwards_ok: AtomicU64,
    forwards_failed: AtomicU64,
    health_transitions: AtomicU64,
    probes_ok: AtomicU64,
    probes_failed: AtomicU64,
}

impl Backend {
    fn new(addr: String, filter: Vec<String>) -> Backend {
        Backend {
            addr,
            filter,
            state: AtomicU8::new(HealthState::Healthy as u8),
            pool: Mutex::new(Vec::new()),
            catalog: Mutex::new(Vec::new()),
            forwards_ok: AtomicU64::new(0),
            forwards_failed: AtomicU64::new(0),
            health_transitions: AtomicU64::new(0),
            probes_ok: AtomicU64::new(0),
            probes_failed: AtomicU64::new(0),
        }
    }

    /// The backend's address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Current health state.
    pub fn state(&self) -> HealthState {
        HealthState::from_u8(self.state.load(Ordering::Relaxed))
    }

    /// Health transitions this backend has undergone.
    pub fn health_transitions(&self) -> u64 {
        self.health_transitions.load(Ordering::Relaxed)
    }

    /// Requests this backend answered (any typed frame counts as an
    /// answer; only transport-level failures count as failed).
    pub fn forwards_ok(&self) -> u64 {
        self.forwards_ok.load(Ordering::Relaxed)
    }

    /// Forward attempts that failed at the transport or timed out.
    pub fn forwards_failed(&self) -> u64 {
        self.forwards_failed.load(Ordering::Relaxed)
    }

    pub(crate) fn inc_forward_ok(&self) {
        self.forwards_ok.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn inc_forward_failed(&self) {
        self.forwards_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// The hello catalog learned from this backend (last handshake or
    /// probe; empty until first contact).
    pub fn catalog(&self) -> Vec<ModelEntry> {
        self.catalog.lock().unwrap().clone()
    }

    fn set_catalog(&self, models: Vec<ModelEntry>) {
        *self.catalog.lock().unwrap() = models;
    }

    /// Take an idle pooled connection, if any.
    pub(crate) fn checkout_pooled(&self) -> Option<BackendConn> {
        self.pool.lock().unwrap().pop()
    }

    /// Return a still-framed connection to the idle pool (dropped if the
    /// pool is full).
    pub(crate) fn checkin(&self, conn: BackendConn) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push(conn);
        }
    }

    /// Drop every pooled connection (after an IO failure the pool may
    /// hold more sockets to a dead process — fail fast instead of
    /// retrying each one).
    pub(crate) fn drain_pool(&self) {
        self.pool.lock().unwrap().clear();
    }
}

/// Dial a backend and run the client-side handshake: preamble exchange,
/// then the hello frame. Returns the framed connection and the catalog.
pub(crate) fn dial_backend(
    addr: &str,
    connect_timeout: Duration,
    max_frame: usize,
) -> Result<(BackendConn, Vec<ModelEntry>), String> {
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| format!("resolve {addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("resolve {addr}: no addresses"))?;
    let stream = TcpStream::connect_timeout(&sock, connect_timeout.max(BACKEND_POLL))
        .map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(BACKEND_POLL));
    let _ = stream.set_write_timeout(Some(BACKEND_WRITE_TIMEOUT));
    let mut stream = stream;
    stream
        .write_all(&proto::encode_preamble())
        .map_err(|e| format!("handshake send {addr}: {e}"))?;
    let deadline = Instant::now() + connect_timeout.max(BACKEND_POLL) * 4;
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    let mut filled = 0;
    loop {
        if Instant::now() > deadline {
            return Err(format!("handshake timeout for {addr}"));
        }
        match proto::poll_exact(&mut stream, &mut pre, &mut filled) {
            Ok(true) => break,
            Ok(false) => continue,
            Err(e) => return Err(format!("handshake read {addr}: {e}")),
        }
    }
    let version =
        proto::decode_preamble(&pre).map_err(|e| format!("bad preamble from {addr}: {e}"))?;
    if !(proto::MIN_VERSION..=proto::VERSION).contains(&version) {
        return Err(format!(
            "{addr} speaks LCQ-RPC v{version}, router accepts v{}..=v{}",
            proto::MIN_VERSION,
            proto::VERSION
        ));
    }
    let mut reader = FrameReader::new(max_frame);
    loop {
        if Instant::now() > deadline {
            return Err(format!("hello timeout for {addr}"));
        }
        match reader.poll_frame(&mut stream) {
            Ok(None) => continue,
            Ok(Some(Frame::Hello(h))) => {
                return Ok((BackendConn { stream, reader, version }, h.models));
            }
            Ok(Some(Frame::Error(e))) => {
                return Err(format!("{addr} refused: [{}] {}", e.code, e.message));
            }
            Ok(Some(_)) => return Err(format!("{addr}: expected hello frame")),
            Err(e) => return Err(format!("hello read {addr}: {e}")),
        }
    }
}

/// The shard map resolved into live backends, plus the pick rotor.
pub struct Fabric {
    backends: Vec<Backend>,
    rr: AtomicUsize,
    cfg: FabricConfig,
    max_frame: usize,
}

impl Fabric {
    /// Build the fabric from config. Addresses appearing in several
    /// shards collapse into one backend whose filter is the union (a
    /// wildcard shard makes the merged filter wildcard).
    pub fn new(cfg: FabricConfig, max_frame: usize) -> Fabric {
        let mut backends: Vec<Backend> = Vec::new();
        for shard in &cfg.shards {
            for addr in &shard.replicas {
                if let Some(b) = backends.iter_mut().find(|b| &b.addr == addr) {
                    if shard.models.is_empty() {
                        b.filter.clear(); // wildcard absorbs everything
                    } else if !b.filter.is_empty() {
                        for m in &shard.models {
                            if !b.filter.contains(m) {
                                b.filter.push(m.clone());
                            }
                        }
                    }
                } else {
                    backends.push(Backend::new(addr.clone(), shard.models.clone()));
                }
            }
        }
        let fabric = Fabric { backends, rr: AtomicUsize::new(0), cfg, max_frame };
        fabric.update_gauges();
        fabric
    }

    /// The fabric's routing knobs.
    pub fn cfg(&self) -> &FabricConfig {
        &self.cfg
    }

    /// All backends, shard-map order.
    pub fn backends(&self) -> &[Backend] {
        &self.backends
    }

    /// Indices of backends that can serve `model`: explicit shard matches
    /// first; otherwise wildcard backends whose catalog contains the
    /// model (or is still unknown — the backend itself answers
    /// `UnknownModel` if we guessed wrong, which is typed and relayed).
    pub fn candidates(&self, model: &str) -> Vec<usize> {
        let explicit: Vec<usize> = self
            .backends
            .iter()
            .enumerate()
            .filter(|(_, b)| b.filter.iter().any(|m| m == model))
            .map(|(i, _)| i)
            .collect();
        if !explicit.is_empty() {
            return explicit;
        }
        self.backends
            .iter()
            .enumerate()
            .filter(|(_, b)| {
                if !b.filter.is_empty() {
                    return false;
                }
                let cat = b.catalog.lock().unwrap();
                cat.is_empty() || cat.iter().any(|m| m.name == model)
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Choose a replica from `candidates`: rotor scan preferring
    /// `Healthy`, then `Suspect`; `Down` is never picked. The backend in
    /// `avoid` (the one that just failed) is skipped while any
    /// alternative exists.
    pub fn pick(&self, candidates: &[usize], avoid: Option<usize>) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let scan = |want: HealthState, skip_avoid: bool| -> Option<usize> {
            for i in 0..candidates.len() {
                let idx = candidates[(start + i) % candidates.len()];
                if skip_avoid && Some(idx) == avoid {
                    continue;
                }
                if self.backends[idx].state() == want {
                    return Some(idx);
                }
            }
            None
        };
        scan(HealthState::Healthy, true)
            .or_else(|| scan(HealthState::Suspect, true))
            .or_else(|| scan(HealthState::Healthy, false))
            .or_else(|| scan(HealthState::Suspect, false))
    }

    /// Record a health transition for backend `idx`. No-op if the state
    /// is unchanged; otherwise bumps the per-backend and global
    /// transition counters and refreshes the health gauges. Returns
    /// whether a transition happened.
    pub fn set_state(&self, idx: usize, new: HealthState) -> bool {
        let b = &self.backends[idx];
        let old = b.state.swap(new as u8, Ordering::Relaxed);
        if old == new as u8 {
            return false;
        }
        b.health_transitions.fetch_add(1, Ordering::Relaxed);
        if obs::enabled() {
            obs::counter(CounterId::FabricHealthTransitions).inc();
        }
        self.update_gauges();
        true
    }

    /// Total health transitions across all backends.
    pub fn health_transitions_total(&self) -> u64 {
        self.backends.iter().map(|b| b.health_transitions()).sum()
    }

    fn update_gauges(&self) {
        if !obs::enabled() {
            return;
        }
        let healthy =
            self.backends.iter().filter(|b| b.state() == HealthState::Healthy).count();
        let down = self.backends.iter().filter(|b| b.state() == HealthState::Down).count();
        obs::gauge(GaugeId::FabricBackendsHealthy).set(healthy as f64);
        obs::gauge(GaugeId::FabricBackendsDown).set(down as f64);
    }

    /// Take a connection to backend `idx`: pooled if available, else a
    /// fresh dial (which also refreshes the backend's catalog).
    pub(crate) fn checkout(&self, idx: usize) -> Result<BackendConn, String> {
        let b = &self.backends[idx];
        if let Some(conn) = b.checkout_pooled() {
            return Ok(conn);
        }
        let (conn, models) = dial_backend(&b.addr, self.cfg.connect_timeout, self.max_frame)?;
        b.set_catalog(models);
        Ok(conn)
    }

    /// Hello-probe one backend: fresh dial + handshake. Success promotes
    /// to `Healthy` and refreshes the catalog (the probe connection is
    /// donated to the idle pool); failure demotes to `Down`. Each probe
    /// bumps the per-backend and global probe counters.
    pub fn probe(&self, idx: usize) -> bool {
        let b = &self.backends[idx];
        if obs::enabled() {
            obs::counter(CounterId::FabricProbes).inc();
        }
        match dial_backend(&b.addr, self.cfg.connect_timeout, self.max_frame) {
            Ok((conn, models)) => {
                b.probes_ok.fetch_add(1, Ordering::Relaxed);
                b.set_catalog(models);
                self.set_state(idx, HealthState::Healthy);
                b.checkin(conn);
                true
            }
            Err(_) => {
                b.probes_failed.fetch_add(1, Ordering::Relaxed);
                self.set_state(idx, HealthState::Down);
                b.drain_pool();
                false
            }
        }
    }

    /// Probe every backend once (startup warm-up and the prober loop's
    /// body). Returns how many probes succeeded.
    pub fn probe_all(&self) -> usize {
        (0..self.backends.len()).filter(|&i| self.probe(i)).count()
    }

    /// Total probes across all backends (success + failure).
    pub fn probes_total(&self) -> u64 {
        self.backends
            .iter()
            .map(|b| {
                b.probes_ok.load(Ordering::Relaxed) + b.probes_failed.load(Ordering::Relaxed)
            })
            .sum()
    }

    /// Union of the backend catalogs, name-deduplicated and sorted — the
    /// router's own hello catalog, so a [`crate::net::NetClient`] sees
    /// one merged model list and needs no fabric awareness.
    pub fn merged_catalog(&self) -> Vec<ModelEntry> {
        let mut merged: Vec<ModelEntry> = Vec::new();
        for b in &self.backends {
            for m in b.catalog.lock().unwrap().iter() {
                if !merged.iter().any(|e| e.name == m.name) {
                    merged.push(m.clone());
                }
            }
        }
        merged.sort_by(|a, b| a.name.cmp(&b.name));
        merged
    }

    /// Per-backend stats array for the router's snapshot JSON (schema in
    /// `docs/FABRIC.md`).
    pub fn backends_json(&self) -> Json {
        let items = self
            .backends
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("addr", Json::Str(b.addr.clone())),
                    ("state", Json::Str(b.state().name().to_string())),
                    ("forwards_ok", Json::from(b.forwards_ok() as usize)),
                    ("forwards_failed", Json::from(b.forwards_failed() as usize)),
                    (
                        "health_transitions",
                        Json::from(b.health_transitions() as usize),
                    ),
                    (
                        "probes_ok",
                        Json::from(b.probes_ok.load(Ordering::Relaxed) as usize),
                    ),
                    (
                        "probes_failed",
                        Json::from(b.probes_failed.load(Ordering::Relaxed) as usize),
                    ),
                ])
            })
            .collect();
        Json::Arr(items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg(shards: Vec<ShardConfig>) -> FabricConfig {
        FabricConfig { shards, ..FabricConfig::default() }
    }

    #[test]
    fn duplicate_addrs_merge_filters() {
        let cfg = test_cfg(vec![
            ShardConfig {
                models: vec!["a".into()],
                replicas: vec!["h:1".into(), "h:2".into()],
            },
            ShardConfig { models: vec!["b".into()], replicas: vec!["h:1".into()] },
        ]);
        let f = Fabric::new(cfg, proto::DEFAULT_MAX_FRAME);
        assert_eq!(f.backends().len(), 2);
        assert_eq!(f.candidates("a"), vec![0, 1]);
        assert_eq!(f.candidates("b"), vec![0]);
        assert!(f.candidates("c").is_empty());
    }

    #[test]
    fn wildcard_routes_by_catalog() {
        let cfg = test_cfg(vec![ShardConfig {
            models: vec![],
            replicas: vec!["h:1".into(), "h:2".into()],
        }]);
        let f = Fabric::new(cfg, proto::DEFAULT_MAX_FRAME);
        // unknown catalogs: every wildcard backend is a candidate
        assert_eq!(f.candidates("m"), vec![0, 1]);
        f.backends()[0].set_catalog(vec![ModelEntry {
            name: "m".into(),
            in_dim: 4,
            out_dim: 2,
        }]);
        f.backends()[1].set_catalog(vec![ModelEntry {
            name: "other".into(),
            in_dim: 4,
            out_dim: 2,
        }]);
        assert_eq!(f.candidates("m"), vec![0]);
        assert_eq!(f.candidates("other"), vec![1]);
        // both catalogs known, neither holds it: no candidates
        assert!(f.candidates("missing").is_empty());
    }

    #[test]
    fn pick_prefers_healthy_and_avoids_failed() {
        let cfg = test_cfg(vec![ShardConfig {
            models: vec!["m".into()],
            replicas: vec!["h:1".into(), "h:2".into(), "h:3".into()],
        }]);
        let f = Fabric::new(cfg, proto::DEFAULT_MAX_FRAME);
        let cands = f.candidates("m");
        // all healthy: avoid is honored
        for _ in 0..8 {
            let p = f.pick(&cands, Some(1)).unwrap();
            assert_ne!(p, 1);
        }
        // suspects are fallback only
        f.set_state(0, HealthState::Suspect);
        f.set_state(2, HealthState::Suspect);
        assert_eq!(f.pick(&cands, None), Some(1));
        // down is never picked
        f.set_state(0, HealthState::Down);
        f.set_state(1, HealthState::Down);
        assert_eq!(f.pick(&cands, None), Some(2));
        f.set_state(2, HealthState::Down);
        assert_eq!(f.pick(&cands, None), None);
    }

    #[test]
    fn transitions_are_counted_once_per_change() {
        let cfg = test_cfg(vec![ShardConfig {
            models: vec!["m".into()],
            replicas: vec!["h:1".into()],
        }]);
        let f = Fabric::new(cfg, proto::DEFAULT_MAX_FRAME);
        assert_eq!(f.health_transitions_total(), 0);
        assert!(f.set_state(0, HealthState::Down));
        assert!(!f.set_state(0, HealthState::Down), "no-op must not count");
        assert!(f.set_state(0, HealthState::Healthy));
        assert_eq!(f.health_transitions_total(), 2);
    }

    #[test]
    fn merged_catalog_dedupes_and_sorts() {
        let cfg = test_cfg(vec![ShardConfig {
            models: vec![],
            replicas: vec!["h:1".into(), "h:2".into()],
        }]);
        let f = Fabric::new(cfg, proto::DEFAULT_MAX_FRAME);
        let m = |n: &str| ModelEntry { name: n.into(), in_dim: 4, out_dim: 2 };
        f.backends()[0].set_catalog(vec![m("b"), m("a")]);
        f.backends()[1].set_catalog(vec![m("a"), m("c")]);
        let names: Vec<String> =
            f.merged_catalog().into_iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["a", "b", "c"]);
    }
}
