//! Blocking LCQ-RPC client: connect, handshake, `infer`/`infer_batch`,
//! pipelined `infer_pipelined`, transparent reconnect-on-drop.
//!
//! One [`NetClient`] owns one TCP connection (plus the model catalog the
//! server sent in its hello frame). The classic calls issue one request
//! at a time; [`NetClient::infer_pipelined`] keeps up to a window of
//! request ids **in flight on the same connection** and matches replies
//! by id, so a single connection can saturate the server's pipeline
//! bound without fan-out threads (the wire format needed no change —
//! ids were u64 from v1; the ordering contract is documented in
//! `docs/wire-protocol.md`). Fan-out across connections still belongs to
//! callers: the load generator ([`crate::net::loadgen`]) opens one
//! client per scoped thread.
//!
//! A dropped connection (server restart, idle timeout, network blip) is
//! retried with a fresh connection before the error surfaces, governed by
//! a configurable [`RetryPolicy`] (default: one transparent retry, no
//! backoff — exactly the historical behavior). Inference is idempotent,
//! so retries are safe even when the failure struck after the request was
//! sent. Each retry bumps the `net_client_retries` counter in the global
//! [`obs`](crate::obs) registry.

use crate::net::proto::{
    self, ErrorCode, FleetStatsRequestFrame, Frame, FrameReader, ModelEntry, RequestFrame,
    StatsRequestFrame, TraceContext, WireError,
};
use crate::obs::{self, CounterId};
use crate::util::backoff::{Backoff, BackoffCfg};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;

/// How a [`NetClient`] retries transport failures: total attempt budget
/// plus the jittered backoff between attempts. The default (2 attempts,
/// zero backoff) is the historical single transparent reconnect.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts per call (first try included). Clamped to ≥ 1.
    pub attempts: usize,
    /// Decorrelated-jitter backoff between attempts ([`BackoffCfg::ZERO`]
    /// retries immediately).
    pub backoff: BackoffCfg,
    /// Seed for the backoff jitter (pin it for reproducible delays).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { attempts: 2, backoff: BackoffCfg::ZERO, seed: 0 }
    }
}

/// Client-side failure modes, split by where the fault lies.
#[derive(Debug)]
pub enum ClientError {
    /// Transport-level failure (connect/send/receive). Retried once per
    /// call before surfacing.
    Io(String),
    /// The server answered with a structured error frame.
    Remote {
        /// The wire error code.
        code: ErrorCode,
        /// The server's diagnostic message.
        message: String,
    },
    /// The peer violated the protocol (or an API misuse, e.g. rows that
    /// do not divide the data length).
    Protocol(String),
}

impl ClientError {
    /// Whether the server shed this request/connection for overload —
    /// the signal load generators count separately and callers may retry
    /// with backoff.
    pub fn is_overloaded(&self) -> bool {
        matches!(self, ClientError::Remote { code: ErrorCode::Overloaded, .. })
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(m) => write!(f, "connection error: {m}"),
            ClientError::Remote { code, message } => {
                write!(f, "server error [{code}]: {message}")
            }
            ClientError::Protocol(m) => write!(f, "protocol error: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

/// One live connection: socket, frame reassembly state, the server's
/// model catalog, and the protocol version negotiated at handshake.
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
    models: Vec<ModelEntry>,
    version: u32,
}

/// Blocking LCQ-RPC client (see module docs).
pub struct NetClient {
    addr: String,
    max_frame: usize,
    next_id: u64,
    conn: Option<Conn>,
    retry: RetryPolicy,
    backoff: Backoff,
    /// When set, requests carry a trace context (v3 servers only): ids
    /// are `base + n` for the n-th traced request, `parent_span = 0`
    /// (client origin).
    trace_base: Option<u64>,
    /// Traced requests issued so far (the `n` above).
    trace_seq: u64,
}

/// Mint the next client-origin trace context, if tracing is on and the
/// negotiated version carries it (a v2 server must never see the tail).
fn mint_trace(base: Option<u64>, seq: &mut u64, version: u32) -> Option<TraceContext> {
    let base = base?;
    if version < proto::VERSION {
        return None;
    }
    *seq += 1;
    Some(TraceContext { trace_id: base.wrapping_add(*seq), parent_span: 0 })
}

impl NetClient {
    /// Connect and complete the handshake (preamble exchange + hello)
    /// with the default [`RetryPolicy`] (one transparent reconnect).
    /// A server shedding connections surfaces here as
    /// [`ClientError::Remote`] with [`ErrorCode::Overloaded`].
    pub fn connect(addr: &str) -> Result<NetClient, ClientError> {
        NetClient::connect_with(addr, RetryPolicy::default())
    }

    /// Connect with an explicit retry policy.
    pub fn connect_with(addr: &str, retry: RetryPolicy) -> Result<NetClient, ClientError> {
        let mut client = NetClient {
            addr: addr.to_string(),
            max_frame: proto::DEFAULT_MAX_FRAME,
            next_id: 1,
            conn: None,
            backoff: Backoff::new(retry.backoff, retry.seed),
            retry,
            trace_base: None,
            trace_seq: 0,
        };
        client.ensure_conn()?;
        Ok(client)
    }

    /// Turn on client-origin trace contexts: subsequent requests to a v3
    /// server carry trace id `base + n` (n = 1, 2, …) with
    /// `parent_span = 0`. Pick disjoint bases across clients so ids stay
    /// unique fleet-wide. No-op on a v2-negotiated connection.
    pub fn set_trace_base(&mut self, base: u64) {
        self.trace_base = Some(base);
    }

    /// Traced requests issued so far (trace ids `base + 1 ..= base + n`).
    pub fn traces_issued(&self) -> u64 {
        self.trace_seq
    }

    /// The protocol version negotiated with the server (reconnecting if
    /// the connection was dropped).
    pub fn server_version(&mut self) -> Result<u32, ClientError> {
        self.ensure_conn()?;
        Ok(self.conn.as_ref().expect("connected").version)
    }

    /// Bookkeeping for one re-attempt: count it and sleep the jittered
    /// backoff delay.
    fn before_retry(&mut self) {
        if obs::enabled() {
            obs::counter(CounterId::NetClientRetries).inc();
        }
        let delay = self.backoff.next_delay();
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
    }

    /// The model catalog from the server's hello frame (reconnecting if
    /// the connection was dropped).
    pub fn models(&mut self) -> Result<Vec<ModelEntry>, ClientError> {
        self.ensure_conn()?;
        Ok(self.conn.as_ref().expect("connected").models.clone())
    }

    /// Infer one row: `row.len()` must match the model's input dimension
    /// (check [`NetClient::models`]). Returns the logits row.
    pub fn infer(&mut self, model: &str, row: &[f32]) -> Result<Vec<f32>, ClientError> {
        self.infer_batch(model, 1, row)
    }

    /// Infer a batch: `data` holds `rows` row-major input rows. Returns
    /// `rows * out_dim` row-major logits.
    pub fn infer_batch(
        &mut self,
        model: &str,
        rows: usize,
        data: &[f32],
    ) -> Result<Vec<f32>, ClientError> {
        if rows == 0 || rows > u32::MAX as usize || data.len() % rows != 0 {
            return Err(ClientError::Protocol(format!(
                "rows ({rows}) must be 1..=u32::MAX and divide data length ({})",
                data.len()
            )));
        }
        let cols = (data.len() / rows) as u32;
        // transparent reconnects for dropped connections, within the
        // retry budget (backoff-jittered between attempts)
        self.backoff.reset();
        let attempts = self.retry.attempts.max(1);
        let mut last_io: Option<ClientError> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.before_retry();
            }
            self.ensure_conn()?;
            match self.round_trip(model, rows as u32, cols, data) {
                Ok(logits) => return Ok(logits),
                Err(e @ ClientError::Io(_)) => {
                    self.conn = None; // reconnect on the next attempt
                    last_io = Some(e);
                }
                Err(e @ ClientError::Protocol(_)) => {
                    // the stream is no longer framed (corruption, id
                    // desync): drop it so the *next* call reconnects
                    // cleanly, but surface this error — a protocol
                    // violation is not transparently retryable
                    self.conn = None;
                    return Err(e);
                }
                Err(e) => return Err(e),
            }
        }
        Err(last_io.expect("loop exits early unless an Io error occurred"))
    }

    /// Infer many single-row requests **pipelined** on this connection:
    /// up to `window` request ids are kept in flight at once, and replies
    /// are matched by id (the server may interleave them with other
    /// traffic, but per connection it answers in submission order — see
    /// `docs/wire-protocol.md`). Returns one result per input row, in
    /// input order: logits, or the typed error the server answered for
    /// that id.
    ///
    /// Transport failures drop the connection and transparently re-issue
    /// the **unanswered** ids on a fresh one, within the retry budget
    /// (inference is idempotent; each re-attempt bumps
    /// `net_client_retries`). A connection-level error frame (id 0 —
    /// shed, shutdown, frame timeout) resolves every in-flight id with
    /// that error; ids not yet written are retried on reconnect.
    pub fn infer_pipelined(
        &mut self,
        model: &str,
        rows: &[&[f32]],
        window: usize,
    ) -> Vec<Result<Vec<f32>, ClientError>> {
        let mut results: Vec<Option<Result<Vec<f32>, ClientError>>> =
            (0..rows.len()).map(|_| None).collect();
        if rows.is_empty() {
            return Vec::new();
        }
        self.backoff.reset();
        let attempts = self.retry.attempts.max(1);
        // (fatal, message): fatal = protocol violation, not retryable
        let mut last_fail: Option<(bool, String)> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.before_retry();
            }
            if let Err(e) = self.ensure_conn() {
                last_fail = Some((matches!(e, ClientError::Protocol(_)), e.to_string()));
                continue;
            }
            let mut conn = self.conn.take().expect("connected");
            match drive_pipeline(
                &mut conn,
                &mut self.next_id,
                (self.trace_base, &mut self.trace_seq),
                model,
                rows,
                window.max(1),
                &mut results,
            ) {
                Ok(()) => {
                    self.conn = Some(conn);
                    last_fail = None;
                    break;
                }
                // conn stays dropped: the next attempt reconnects
                Err(PipelineFailure::Transport(m)) => last_fail = Some((false, m)),
                Err(PipelineFailure::Protocol(m)) => {
                    last_fail = Some((true, m));
                    break; // a protocol violation is not transparently retryable
                }
            }
        }
        results
            .into_iter()
            .map(|slot| {
                slot.unwrap_or_else(|| match &last_fail {
                    Some((true, m)) => Err(ClientError::Protocol(m.clone())),
                    Some((false, m)) => Err(ClientError::Io(m.clone())),
                    None => Err(ClientError::Io("pipeline incomplete".to_string())),
                })
            })
            .collect()
    }

    /// Fetch the server's observability snapshot (v2 `Stats` frame) as a
    /// JSON document. Same retry discipline as
    /// [`NetClient::infer_batch`].
    pub fn stats(&mut self) -> Result<String, ClientError> {
        self.backoff.reset();
        let attempts = self.retry.attempts.max(1);
        let mut last_io: Option<ClientError> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.before_retry();
            }
            self.ensure_conn()?;
            match self.stats_round_trip() {
                Ok(json) => return Ok(json),
                Err(e @ ClientError::Io(_)) => {
                    self.conn = None; // reconnect on the next attempt
                    last_io = Some(e);
                }
                Err(e @ ClientError::Protocol(_)) => {
                    self.conn = None;
                    return Err(e);
                }
                Err(e) => return Err(e),
            }
        }
        Err(last_io.expect("loop exits early unless an Io error occurred"))
    }

    /// Fetch the fleet-wide observability snapshot (v3 `FleetStats`
    /// frame) as a JSON document. Only fabric routers answer this; a
    /// plain backend rejects it with [`ErrorCode::Malformed`], which
    /// surfaces as [`ClientError::Remote`]. Same retry discipline as
    /// [`NetClient::infer_batch`].
    pub fn fleet_stats(&mut self) -> Result<String, ClientError> {
        self.backoff.reset();
        let attempts = self.retry.attempts.max(1);
        let mut last_io: Option<ClientError> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.before_retry();
            }
            self.ensure_conn()?;
            match self.fleet_stats_round_trip() {
                Ok(json) => return Ok(json),
                Err(e @ ClientError::Io(_)) => {
                    self.conn = None; // reconnect on the next attempt
                    last_io = Some(e);
                }
                Err(e @ ClientError::Protocol(_)) => {
                    self.conn = None;
                    return Err(e);
                }
                Err(e) => return Err(e),
            }
        }
        Err(last_io.expect("loop exits early unless an Io error occurred"))
    }

    fn fleet_stats_round_trip(&mut self) -> Result<String, ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let conn = self.conn.as_mut().expect("connected");
        if conn.version < proto::VERSION {
            return Err(ClientError::Protocol(format!(
                "fleet stats need LCQ-RPC v{}, server negotiated v{}",
                proto::VERSION,
                conn.version
            )));
        }
        proto::write_frame(
            &mut conn.stream,
            &Frame::FleetStatsRequest(FleetStatsRequestFrame { id }),
        )
        .map_err(|e| ClientError::Io(format!("send: {e}")))?;
        loop {
            match conn.reader.poll_frame(&mut conn.stream) {
                Ok(None) => continue, // only if a read timeout is set
                Ok(Some(Frame::FleetStatsResponse(resp))) => {
                    if resp.id != id {
                        return Err(ClientError::Protocol(format!(
                            "fleet stats response id {} for request {id}",
                            resp.id
                        )));
                    }
                    return Ok(resp.json);
                }
                Ok(Some(Frame::Error(e))) => {
                    if e.id != id && e.id != 0 {
                        return Err(ClientError::Protocol(format!(
                            "error frame for foreign request {}",
                            e.id
                        )));
                    }
                    return Err(ClientError::Remote { code: e.code, message: e.message });
                }
                Ok(Some(_)) => {
                    return Err(ClientError::Protocol(
                        "unexpected frame while awaiting a fleet stats response".to_string(),
                    ))
                }
                Err(WireError::Closed) => {
                    return Err(ClientError::Io("connection closed by server".to_string()))
                }
                Err(WireError::Io(e)) => return Err(ClientError::Io(e.to_string())),
                Err(e) => return Err(ClientError::Protocol(e.to_string())),
            }
        }
    }

    fn stats_round_trip(&mut self) -> Result<String, ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let conn = self.conn.as_mut().expect("connected");
        proto::write_frame(&mut conn.stream, &Frame::StatsRequest(StatsRequestFrame { id }))
            .map_err(|e| ClientError::Io(format!("send: {e}")))?;
        loop {
            match conn.reader.poll_frame(&mut conn.stream) {
                Ok(None) => continue, // only if a read timeout is set
                Ok(Some(Frame::StatsResponse(resp))) => {
                    if resp.id != id {
                        return Err(ClientError::Protocol(format!(
                            "stats response id {} for request {id}",
                            resp.id
                        )));
                    }
                    return Ok(resp.json);
                }
                Ok(Some(Frame::Error(e))) => {
                    if e.id != id && e.id != 0 {
                        return Err(ClientError::Protocol(format!(
                            "error frame for foreign request {}",
                            e.id
                        )));
                    }
                    return Err(ClientError::Remote { code: e.code, message: e.message });
                }
                Ok(Some(_)) => {
                    return Err(ClientError::Protocol(
                        "unexpected frame while awaiting a stats response".to_string(),
                    ))
                }
                Err(WireError::Closed) => {
                    return Err(ClientError::Io("connection closed by server".to_string()))
                }
                Err(WireError::Io(e)) => return Err(ClientError::Io(e.to_string())),
                Err(e) => return Err(ClientError::Protocol(e.to_string())),
            }
        }
    }

    fn round_trip(
        &mut self,
        model: &str,
        rows: u32,
        cols: u32,
        data: &[f32],
    ) -> Result<Vec<f32>, ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let conn = self.conn.as_mut().expect("connected");
        let trace = mint_trace(self.trace_base, &mut self.trace_seq, conn.version);
        let frame = Frame::Request(RequestFrame {
            id,
            model: model.to_string(),
            rows,
            cols,
            data: data.to_vec(),
            trace,
        });
        proto::write_frame(&mut conn.stream, &frame)
            .map_err(|e| ClientError::Io(format!("send: {e}")))?;
        loop {
            match conn.reader.poll_frame(&mut conn.stream) {
                Ok(None) => continue, // only if a read timeout is set
                Ok(Some(Frame::Response(resp))) => {
                    if resp.id != id {
                        return Err(ClientError::Protocol(format!(
                            "response id {} for request {id}",
                            resp.id
                        )));
                    }
                    if resp.rows != rows {
                        return Err(ClientError::Protocol(format!(
                            "response carries {} rows for a {rows}-row request",
                            resp.rows
                        )));
                    }
                    return Ok(resp.data);
                }
                Ok(Some(Frame::Error(e))) => {
                    // id 0 marks connection-level errors (shed/shutdown)
                    if e.id != id && e.id != 0 {
                        return Err(ClientError::Protocol(format!(
                            "error frame for foreign request {}",
                            e.id
                        )));
                    }
                    return Err(ClientError::Remote { code: e.code, message: e.message });
                }
                Ok(Some(_)) => {
                    return Err(ClientError::Protocol(
                        "unexpected frame while awaiting a response".to_string(),
                    ))
                }
                Err(WireError::Closed) => {
                    return Err(ClientError::Io("connection closed by server".to_string()))
                }
                Err(WireError::Io(e)) => return Err(ClientError::Io(e.to_string())),
                Err(e) => return Err(ClientError::Protocol(e.to_string())),
            }
        }
    }

    fn ensure_conn(&mut self) -> Result<(), ClientError> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut stream = TcpStream::connect(&self.addr)
            .map_err(|e| ClientError::Io(format!("connect {}: {e}", self.addr)))?;
        let _ = stream.set_nodelay(true);
        stream
            .write_all(&proto::encode_preamble())
            .map_err(|e| ClientError::Io(format!("handshake send: {e}")))?;
        let mut pre = [0u8; proto::PREAMBLE_LEN];
        stream
            .read_exact(&mut pre)
            .map_err(|e| ClientError::Io(format!("handshake read: {e}")))?;
        let version =
            proto::decode_preamble(&pre).map_err(|e| ClientError::Protocol(e.to_string()))?;
        if !(proto::MIN_VERSION..=proto::VERSION).contains(&version) {
            return Err(ClientError::Protocol(format!(
                "server speaks LCQ-RPC v{version}, this client accepts v{}..=v{}",
                proto::MIN_VERSION,
                proto::VERSION
            )));
        }
        let mut reader = FrameReader::new(self.max_frame);
        let first = loop {
            match reader.poll_frame(&mut stream) {
                Ok(Some(f)) => break f,
                Ok(None) => continue,
                Err(WireError::Closed) => {
                    return Err(ClientError::Io("closed during handshake".to_string()))
                }
                Err(WireError::Io(e)) => return Err(ClientError::Io(e.to_string())),
                Err(e) => return Err(ClientError::Protocol(e.to_string())),
            }
        };
        match first {
            Frame::Hello(h) => {
                self.conn = Some(Conn { stream, reader, models: h.models, version });
                Ok(())
            }
            // connection-shed and version rejection arrive as error frames
            Frame::Error(e) => Err(ClientError::Remote { code: e.code, message: e.message }),
            _ => Err(ClientError::Protocol("expected hello frame".to_string())),
        }
    }
}

/// Why one pipelined drive over a connection ended early.
enum PipelineFailure {
    /// Transport-level: reconnect and re-issue the unanswered ids.
    Transport(String),
    /// The stream violated the protocol: surface, do not retry.
    Protocol(String),
}

/// Drive unanswered rows through one connection with a bounded in-flight
/// window. Fills `results` slots as replies land (matched by id, possibly
/// ahead of older traffic the server already shed); returns `Ok` when
/// every slot is resolved.
fn drive_pipeline(
    conn: &mut Conn,
    next_id: &mut u64,
    (trace_base, trace_seq): (Option<u64>, &mut u64),
    model: &str,
    rows: &[&[f32]],
    window: usize,
    results: &mut [Option<Result<Vec<f32>, ClientError>>],
) -> Result<(), PipelineFailure> {
    let mut queue: std::collections::VecDeque<usize> =
        (0..rows.len()).filter(|&i| results[i].is_none()).collect();
    let mut inflight: HashMap<u64, usize> = HashMap::new();
    while !queue.is_empty() || !inflight.is_empty() {
        // fill the window before blocking on a reply
        while inflight.len() < window {
            let Some(i) = queue.pop_front() else { break };
            let id = *next_id;
            *next_id += 1;
            let row = rows[i];
            let trace = mint_trace(trace_base, trace_seq, conn.version);
            let frame = Frame::Request(RequestFrame {
                id,
                model: model.to_string(),
                rows: 1,
                cols: row.len() as u32,
                data: row.to_vec(),
                trace,
            });
            proto::write_frame(&mut conn.stream, &frame)
                .map_err(|e| PipelineFailure::Transport(format!("send: {e}")))?;
            inflight.insert(id, i);
        }
        match conn.reader.poll_frame(&mut conn.stream) {
            Ok(None) => continue, // only if a read timeout is set
            Ok(Some(Frame::Response(resp))) => {
                let Some(i) = inflight.remove(&resp.id) else {
                    return Err(PipelineFailure::Protocol(format!(
                        "response id {} matches no in-flight request",
                        resp.id
                    )));
                };
                results[i] = Some(if resp.rows == 1 {
                    Ok(resp.data)
                } else {
                    Err(ClientError::Protocol(format!(
                        "response carries {} rows for a 1-row request",
                        resp.rows
                    )))
                });
            }
            Ok(Some(Frame::Error(e))) => {
                if e.id == 0 {
                    // connection-level error (shed, shutdown, frame
                    // timeout): it resolves everything in flight; the
                    // server closes after it, so unsent ids go back to
                    // the caller's retry loop
                    for (_, i) in inflight.drain() {
                        results[i] = Some(Err(ClientError::Remote {
                            code: e.code,
                            message: e.message.clone(),
                        }));
                    }
                    if queue.is_empty() {
                        return Ok(());
                    }
                    return Err(PipelineFailure::Transport(format!(
                        "connection-level error [{}]: {}",
                        e.code, e.message
                    )));
                }
                let Some(i) = inflight.remove(&e.id) else {
                    return Err(PipelineFailure::Protocol(format!(
                        "error frame for foreign request {}",
                        e.id
                    )));
                };
                results[i] = Some(Err(ClientError::Remote { code: e.code, message: e.message }));
            }
            Ok(Some(_)) => {
                return Err(PipelineFailure::Protocol(
                    "unexpected frame while awaiting pipelined responses".to_string(),
                ))
            }
            Err(WireError::Closed) => {
                return Err(PipelineFailure::Transport(
                    "connection closed by server".to_string(),
                ))
            }
            Err(WireError::Io(e)) => return Err(PipelineFailure::Transport(e.to_string())),
            Err(e) => return Err(PipelineFailure::Protocol(e.to_string())),
        }
    }
    Ok(())
}
