//! LCQ-RPC connection plane: a TCP listener feeding the in-process
//! micro-batch server.
//!
//! Layout (drawn out in `docs/ARCHITECTURE.md`):
//!
//! * an **acceptor** thread blocks in `accept()` and hands sockets to a
//!   bounded connection queue; when every handler is busy and the queue is
//!   full, the connection is **shed** at the door with an
//!   [`ErrorCode::Overloaded`] handshake instead of being silently queued
//!   forever;
//! * a fixed set of `max_connections` **handler** threads (one blocking
//!   connection each, fanned out via [`crate::linalg::pool::run_scoped`] —
//!   real scoped threads, so parked connections never occupy the compute
//!   pool's task slots) runs the handshake and request loop;
//! * decoded request rows are submitted to the shared
//!   [`MicroBatchServer`] **in place** ([`Client::submit`] hands the
//!   frame-decoded `Vec<f32>` straight to the engine), so the wire → batch
//!   path performs no per-request input copy;
//! * a **bounded in-flight budget** (`NetConfig::inflight_budget`, counted
//!   in rows) sheds excess requests with [`ErrorCode::Overloaded`] before
//!   they touch the compute plane — explicit backpressure instead of
//!   unbounded queueing.
//!
//! Every answered request leaves a [`Trace`](crate::obs::Trace) — accept →
//! decode → queue wait → batch assembly → pool compute → frame → write —
//! in a bounded overwrite-oldest ring, and every counter bump mirrors into
//! the process-wide [`obs`] registry. The whole picture (per-server
//! counters + batch-plane stats + pool profile + slowest traces) is served
//! over the wire as a v2 `Stats` frame and rendered by
//! [`NetServer::snapshot_json`]; the snapshot path reads shared atomics,
//! so it is valid at **every** lifecycle point — before the first request,
//! mid-traffic, after [`NetServer::stop`], even after the batch server is
//! gone.
//!
//! Handler sockets carry a short read timeout so every blocking read
//! doubles as a shutdown poll; [`NetServer::stop`] (also run on drop)
//! stops the acceptor, joins the handlers, then stops the batch server —
//! in-flight requests are answered before the engine goes away.

use crate::net::proto::{
    self, ErrorCode, ErrorFrame, Frame, FrameReader, HelloFrame, ModelEntry, RequestFrame,
    StatsResponseFrame, WireError,
};
use crate::obs::{self, CounterId, HistId, Stage, Trace, TraceRing};
use crate::serve::{Client, MicroBatchServer, Registry, ServeStats, ServerConfig, StatsSnapshot};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Read-timeout tick at which connection handlers re-check the shutdown
/// flag (mirrors the micro-batcher's poll).
const SHUTDOWN_POLL: Duration = Duration::from_millis(25);

/// Cap on any single write (handshakes, shed notices, responses): a
/// stalled peer must not pin a handler forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Deadline for the unauthenticated pre-hello phase: a connection that
/// has not delivered its preamble within this window is dropped. Without
/// it, `max_connections` silent connects (`nc host port`) would pin every
/// handler forever and shed all future traffic.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Connection-plane knobs (config file: the `"net"` object **inside the
/// `"serve"` section** — the top-level `"net"` key names the MLP
/// architecture; see [`crate::config::NetSettings`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetConfig {
    /// Listen address, `host:port`. Port 0 binds an ephemeral port
    /// (report it with [`NetServer::local_addr`]) — the loopback tests and
    /// benches rely on this.
    pub bind_addr: String,
    /// Concurrent connections served; one handler thread each. Beyond
    /// this (plus a same-sized accept backlog), connections are shed with
    /// [`ErrorCode::Overloaded`] at handshake time.
    pub max_connections: usize,
    /// In-flight request budget in **rows**: rows submitted to the batch
    /// server but not yet answered. Requests that would exceed it are
    /// shed with [`ErrorCode::Overloaded`] — the backpressure signal.
    pub inflight_budget: usize,
    /// Largest accepted frame payload, bytes (guards allocation).
    pub max_frame_bytes: usize,
    /// Recent-trace ring capacity (rounded up to a power of two). Each
    /// slot is ~80 bytes of atomics; the default keeps the last 256
    /// request traces.
    pub trace_slots: usize,
    /// Per-frame progress deadline: once the first byte of a request
    /// frame arrives, the whole frame must complete within this window or
    /// the connection is shed with [`ErrorCode::Timeout`] (slow-loris
    /// defense — the handshake deadline alone leaves the request loop
    /// holdable forever by dribbling one byte per read tick). Idle
    /// connections (no partial frame) are unaffected.
    pub frame_deadline: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            bind_addr: "127.0.0.1:7070".to_string(),
            max_connections: 64,
            inflight_budget: 256,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME,
            trace_slots: 256,
            frame_deadline: Duration::from_secs(10),
        }
    }
}

/// Monotonic connection-plane counters (all-time, point-in-time read).
#[derive(Clone, Debug, Default)]
pub struct NetStatsSnapshot {
    /// Connections accepted by the listener.
    pub connections: u64,
    /// Connections shed at the door (handler pool + backlog full).
    pub connections_shed: u64,
    /// Requests answered with logits.
    pub requests_ok: u64,
    /// Requests shed by the in-flight budget.
    pub requests_shed: u64,
    /// Requests answered with a non-overload error.
    pub requests_failed: u64,
    /// Stats snapshot frames served.
    pub stats_requests: u64,
    /// Connections shed by the per-frame progress deadline (slow-loris).
    pub frame_timeouts: u64,
}

/// Per-server exact counters. Every bump also mirrors into the global
/// [`obs`] registry (when enabled), but the per-instance values are the
/// source of truth a test or a client can match against its own
/// accounting — many servers can coexist in one process without their
/// counts blending.
#[derive(Default)]
struct NetStats {
    connections: AtomicU64,
    connections_shed: AtomicU64,
    requests_ok: AtomicU64,
    requests_shed: AtomicU64,
    requests_failed: AtomicU64,
    stats_requests: AtomicU64,
    frame_timeouts: AtomicU64,
}

impl NetStats {
    fn bump(own: &AtomicU64, id: CounterId) {
        own.fetch_add(1, Ordering::Relaxed);
        if obs::enabled() {
            obs::counter(id).inc();
        }
    }
    fn inc_connections(&self) {
        NetStats::bump(&self.connections, CounterId::NetConnections);
    }
    fn inc_connections_shed(&self) {
        NetStats::bump(&self.connections_shed, CounterId::NetConnectionsShed);
    }
    fn inc_ok(&self) {
        NetStats::bump(&self.requests_ok, CounterId::NetRequestsOk);
    }
    fn inc_shed(&self) {
        NetStats::bump(&self.requests_shed, CounterId::NetRequestsShed);
    }
    fn inc_failed(&self) {
        NetStats::bump(&self.requests_failed, CounterId::NetRequestsFailed);
    }
    fn inc_stats(&self) {
        NetStats::bump(&self.stats_requests, CounterId::NetStatsRequests);
    }
    fn inc_frame_timeout(&self) {
        NetStats::bump(&self.frame_timeouts, CounterId::NetFrameTimeouts);
    }

    fn snapshot(&self) -> NetStatsSnapshot {
        NetStatsSnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            connections_shed: self.connections_shed.load(Ordering::Relaxed),
            requests_ok: self.requests_ok.load(Ordering::Relaxed),
            requests_shed: self.requests_shed.load(Ordering::Relaxed),
            requests_failed: self.requests_failed.load(Ordering::Relaxed),
            stats_requests: self.stats_requests.load(Ordering::Relaxed),
            frame_timeouts: self.frame_timeouts.load(Ordering::Relaxed),
        }
    }

    fn to_json(&self) -> Json {
        let s = self.snapshot();
        Json::obj(vec![
            ("connections", Json::from(s.connections as usize)),
            ("connections_shed", Json::from(s.connections_shed as usize)),
            ("requests_ok", Json::from(s.requests_ok as usize)),
            ("requests_shed", Json::from(s.requests_shed as usize)),
            ("requests_failed", Json::from(s.requests_failed as usize)),
            ("stats_requests", Json::from(s.stats_requests as usize)),
            ("frame_timeouts", Json::from(s.frame_timeouts as usize)),
        ])
    }
}

/// Everything a connection handler needs, shared by `Arc`.
struct ConnCtx {
    registry: Arc<Registry>,
    client: Client,
    shutdown: AtomicBool,
    /// Rows currently submitted to the batch server and unanswered.
    inflight: AtomicUsize,
    inflight_max: usize,
    max_frame: usize,
    /// Per-frame progress deadline (see [`NetConfig::frame_deadline`]).
    frame_deadline: Duration,
    stats: NetStats,
    /// Batch-plane stats, shared with the micro-batch server's executors.
    /// Outlives the batch server itself, so snapshots are valid at every
    /// lifecycle point.
    serve_stats: Arc<ServeStats>,
    /// Recent request traces (overwrite-oldest; never blocks a handler).
    traces: TraceRing,
    /// Precomputed server preamble + hello frame (catalog), written to
    /// every accepted connection.
    hello: Vec<u8>,
}

/// The TCP serving front end: listener + handler pool + micro-batch
/// server, one self-contained unit (see module docs).
pub struct NetServer {
    ctx: Arc<ConnCtx>,
    local_addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    conn_plane: Option<JoinHandle<()>>,
    batch: Option<MicroBatchServer>,
}

impl NetServer {
    /// Bind `net_cfg.bind_addr`, start the micro-batch server with
    /// `serve_cfg`, and begin accepting LCQ-RPC connections.
    pub fn start(
        registry: Arc<Registry>,
        serve_cfg: ServerConfig,
        net_cfg: NetConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(&net_cfg.bind_addr)
            .with_context(|| format!("binding {}", net_cfg.bind_addr))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        let batch = MicroBatchServer::start(Arc::clone(&registry), serve_cfg);
        let max_conns = net_cfg.max_connections.max(1);
        let ctx = Arc::new(ConnCtx {
            hello: hello_bytes(&registry),
            client: batch.client(),
            serve_stats: batch.stats_handle(),
            registry,
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            inflight_max: net_cfg.inflight_budget.max(1),
            max_frame: net_cfg.max_frame_bytes.max(1024),
            frame_deadline: net_cfg.frame_deadline.max(SHUTDOWN_POLL),
            stats: NetStats::default(),
            traces: TraceRing::new(net_cfg.trace_slots.max(2)),
        });
        // bounded hand-off from the acceptor to the handlers; its slack
        // doubles as the accept backlog before connections are shed
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(max_conns);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let conn_plane = {
            let ctx = Arc::clone(&ctx);
            std::thread::Builder::new()
                .name("lcq-net-conns".to_string())
                .spawn(move || handler_pool(ctx, conn_rx, max_conns))
                .context("spawning connection plane")?
        };
        let acceptor = {
            let ctx = Arc::clone(&ctx);
            std::thread::Builder::new()
                .name("lcq-net-accept".to_string())
                .spawn(move || acceptor_loop(listener, conn_tx, ctx))
                .context("spawning acceptor")?
        };
        Ok(NetServer {
            ctx,
            local_addr,
            acceptor: Some(acceptor),
            conn_plane: Some(conn_plane),
            batch: Some(batch),
        })
    }

    /// The bound listen address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Connection-plane counters.
    pub fn stats(&self) -> NetStatsSnapshot {
        self.ctx.stats.snapshot()
    }

    /// The micro-batch plane's latency/batching summary. Reads the stats
    /// shared with the executors directly, so the same path is valid
    /// before, during and after [`NetServer::stop`] — there is no cached
    /// "final" snapshot to race against.
    pub fn batch_stats(&self) -> StatsSnapshot {
        self.ctx.serve_stats.snapshot()
    }

    /// The full observability snapshot this server exposes over the wire
    /// (per-server counters, batch-plane stats, process registry, pool
    /// profile, slowest traces), as a JSON document.
    pub fn snapshot_json(&self) -> String {
        snapshot_json(&self.ctx)
    }

    /// Stop accepting, join every handler (in-flight requests are
    /// answered), then stop the batch server. Idempotent; also run on
    /// drop.
    pub fn stop(&mut self) {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        // the acceptor blocks in accept(): poke it with a throwaway
        // connection so it observes the flag
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // the acceptor owned the connection queue's sender; handlers
        // finish their current connection (bounded by the shutdown poll),
        // then exit on the disconnected queue
        if let Some(h) = self.conn_plane.take() {
            let _ = h.join();
        }
        if let Some(mut b) = self.batch.take() {
            b.stop();
            // stats live on in ctx.serve_stats — nothing to capture
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Render the full stats snapshot for one server (the `Stats` frame body;
/// schema in `docs/OBSERVABILITY.md`).
fn snapshot_json(ctx: &ConnCtx) -> String {
    Json::obj(vec![
        ("server", ctx.stats.to_json()),
        ("batch", ctx.serve_stats.to_json()),
        ("process", obs::global().snapshot_json()),
        ("pool", crate::linalg::pool::profile().to_json()),
        ("traces", obs::traces_json(&ctx.traces.slowest(8))),
        ("traces_dropped", Json::from(ctx.traces.dropped() as usize)),
    ])
    .to_string()
}

/// Server preamble + hello frame, encoded once at startup.
fn hello_bytes(registry: &Registry) -> Vec<u8> {
    let models = registry
        .catalog()
        .into_iter()
        .map(|m| ModelEntry {
            name: m.name,
            in_dim: m.in_dim as u32,
            out_dim: m.out_dim as u32,
        })
        .collect();
    let mut out = proto::encode_preamble().to_vec();
    out.extend_from_slice(&Frame::Hello(HelloFrame { models }).to_bytes());
    out
}

fn acceptor_loop(
    listener: TcpListener,
    conn_tx: mpsc::SyncSender<TcpStream>,
    ctx: Arc<ConnCtx>,
) {
    for stream in listener.incoming() {
        if ctx.shutdown.load(Ordering::Relaxed) {
            return; // drops conn_tx: handlers drain the backlog and exit
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // accept failures (EMFILE under fd pressure, transient
                // network errors) can repeat instantly: back off briefly
                // instead of busy-spinning a core exactly when the
                // process is already overloaded
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        ctx.stats.inc_connections();
        let _ = stream.set_nodelay(true);
        match conn_tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(stream)) => {
                // every handler busy and the backlog full: shed at the
                // door with an explicit overload handshake
                ctx.stats.inc_connections_shed();
                shed_connection(stream, ctx.inflight_max);
            }
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
}

/// Best-effort overload handshake for a connection the plane cannot take:
/// preamble + `Overloaded` error frame, then close.
fn shed_connection(mut stream: TcpStream, budget: usize) {
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut bytes = proto::encode_preamble().to_vec();
    bytes.extend_from_slice(
        &Frame::Error(ErrorFrame {
            id: 0,
            code: ErrorCode::Overloaded,
            message: format!("connection limit reached (in-flight budget {budget})"),
        })
        .to_bytes(),
    );
    let _ = stream.write_all(&bytes);
}

/// `max_conns` blocking connection handlers on scoped threads. Handlers
/// block on sockets and channel replies, so they use `run_scoped` (real
/// threads), never the compute pool's task slots.
fn handler_pool(
    ctx: Arc<ConnCtx>,
    conn_rx: Arc<Mutex<Receiver<TcpStream>>>,
    max_conns: usize,
) {
    crate::linalg::pool::run_scoped(max_conns, |_| loop {
        let next = { conn_rx.lock().unwrap().recv() };
        match next {
            Ok(stream) => handle_conn(stream, &ctx),
            Err(_) => return, // acceptor gone and backlog drained
        }
    });
}

#[inline]
fn dur_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// One connection, handshake to close.
fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    let _ = stream.set_read_timeout(Some(SHUTDOWN_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    // --- handshake: read the client preamble (polling for shutdown,
    //     bounded by HANDSHAKE_TIMEOUT so silent connects free the
    //     handler instead of pinning it) ------------------------------
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    let mut filled = 0;
    let handshake_start = Instant::now();
    loop {
        if ctx.shutdown.load(Ordering::Relaxed)
            || handshake_start.elapsed() > HANDSHAKE_TIMEOUT
        {
            return;
        }
        match proto::poll_exact(&mut stream, &mut pre, &mut filled) {
            Ok(true) => break,
            Ok(false) => continue,
            Err(_) => return,
        }
    }
    match proto::decode_preamble(&pre) {
        Ok(v) if v == proto::VERSION => {}
        Ok(v) => {
            // speaks LCQ-RPC but a different version: say so, then close
            let mut bytes = proto::encode_preamble().to_vec();
            bytes.extend_from_slice(
                &Frame::Error(ErrorFrame {
                    id: 0,
                    code: ErrorCode::UnsupportedVersion,
                    message: format!("server speaks v{}, client sent v{v}", proto::VERSION),
                })
                .to_bytes(),
            );
            let _ = stream.write_all(&bytes);
            return;
        }
        Err(_) => return, // not our protocol: close without a reply
    }
    // --- hello: preamble + model catalog (precomputed) -----------------
    if stream.write_all(&ctx.hello).is_err() {
        return;
    }
    // the accept span (handshake duration) is shared by every request on
    // this connection; the wait above is client-paced, so it measures the
    // peer's preamble latency, not server work
    let accept_ns = dur_ns(handshake_start.elapsed());
    if obs::enabled() {
        obs::hist(HistId::NetHandshake).record_ns(accept_ns);
    }
    // --- request loop ---------------------------------------------------
    let mut reader = FrameReader::new(ctx.max_frame);
    // Slow-loris defense: once the first bytes of a frame arrive, the
    // whole frame must land within `frame_deadline`. Dribbling one byte
    // per read tick resets nothing — the clock runs from the first byte
    // until the frame completes. Idle connections (no partial frame)
    // never time out here.
    let mut frame_started: Option<Instant> = None;
    loop {
        if ctx.shutdown.load(Ordering::Relaxed) {
            let _ = proto::write_frame(
                &mut stream,
                &Frame::Error(ErrorFrame {
                    id: 0,
                    code: ErrorCode::ShuttingDown,
                    message: "server shutting down".to_string(),
                }),
            );
            return;
        }
        match reader.poll_frame(&mut stream) {
            Ok(None) => {
                // read-timeout tick: check partial-frame progress
                if reader.buffered_len() == 0 {
                    frame_started = None;
                    continue;
                }
                let started = *frame_started.get_or_insert_with(Instant::now);
                if started.elapsed() > ctx.frame_deadline {
                    ctx.stats.inc_frame_timeout();
                    let _ = proto::write_frame(
                        &mut stream,
                        &Frame::Error(ErrorFrame {
                            id: 0,
                            code: ErrorCode::Timeout,
                            message: format!(
                                "request frame made no progress within {:?} \
                                 ({} bytes buffered); closing",
                                ctx.frame_deadline,
                                reader.buffered_len()
                            ),
                        }),
                    );
                    return;
                }
                continue;
            }
            Ok(Some(Frame::Request(req))) => {
                frame_started = None;
                let decode_ns = reader.last_decode_ns();
                if !answer_request(&mut stream, ctx, req, accept_ns, decode_ns) {
                    return;
                }
            }
            Ok(Some(Frame::StatsRequest(s))) => {
                frame_started = None;
                ctx.stats.inc_stats();
                let json = snapshot_json(ctx);
                if proto::write_frame(
                    &mut stream,
                    &Frame::StatsResponse(StatsResponseFrame { id: s.id, json }),
                )
                .is_err()
                {
                    return;
                }
            }
            Ok(Some(_)) => {
                // clients may only send requests
                let _ = proto::write_frame(
                    &mut stream,
                    &Frame::Error(ErrorFrame {
                        id: 0,
                        code: ErrorCode::Malformed,
                        message: "unexpected frame type from client".to_string(),
                    }),
                );
                return;
            }
            Err(WireError::Closed) => return, // clean close
            Err(WireError::Io(_)) => return,
            Err(e) => {
                // protocol violation: the stream is no longer framed —
                // report once and close
                let _ = proto::write_frame(
                    &mut stream,
                    &Frame::Error(ErrorFrame {
                        id: 0,
                        code: ErrorCode::Malformed,
                        message: e.to_string(),
                    }),
                );
                return;
            }
        }
    }
}

/// Batch-plane span times aggregated over a request's rows (single-row
/// requests: the one job's spans; multi-row: the worst row, since the
/// response waits for the slowest).
#[derive(Default, Clone, Copy)]
struct PipelineSpans {
    queue_ns: u64,
    assembly_ns: u64,
    compute_ns: u64,
}

/// Validate, budget, submit and answer one request. Returns `false` when
/// the connection should close (write failure). `accept_ns`/`decode_ns`
/// seed the request's trace span.
fn answer_request(
    stream: &mut TcpStream,
    ctx: &ConnCtx,
    req: RequestFrame,
    accept_ns: u64,
    decode_ns: u64,
) -> bool {
    let id = req.id;
    let fail = |stream: &mut TcpStream, code: ErrorCode, message: String| -> bool {
        proto::write_frame(stream, &Frame::Error(ErrorFrame { id, code, message })).is_ok()
    };
    // validate against the registry *before* spending compute
    let Some(loaded) = ctx.registry.get(&req.model) else {
        ctx.stats.inc_failed();
        return fail(
            stream,
            ErrorCode::UnknownModel,
            format!("model '{}' not registered", req.model),
        );
    };
    let in_dim = loaded.engine.in_dim();
    let out_dim = loaded.engine.out_dim();
    let rows = req.rows as usize;
    if req.cols as usize != in_dim {
        ctx.stats.inc_failed();
        return fail(
            stream,
            ErrorCode::WrongDims,
            format!("model '{}' expects {in_dim} features, got {}", req.model, req.cols),
        );
    }
    // reject requests whose *response* could not be framed: without this
    // a small-input/large-output model could make the server pay the full
    // forward pass only to emit a frame every conforming client must
    // reject as oversized
    let response_bytes = rows
        .checked_mul(out_dim)
        .and_then(|n| n.checked_mul(4))
        .and_then(|n| n.checked_add(64)); // envelope + header slack
    let response_fits = matches!(response_bytes, Some(n) if n <= ctx.max_frame);
    if !response_fits {
        ctx.stats.inc_failed();
        return fail(
            stream,
            ErrorCode::WrongDims,
            format!(
                "a {rows}-row response ({out_dim} logits/row) would exceed the \
                 frame cap of {} bytes",
                ctx.max_frame
            ),
        );
    }
    // bounded in-flight budget (counted in rows): shed, don't queue
    if !try_acquire(&ctx.inflight, ctx.inflight_max, rows) {
        ctx.stats.inc_shed();
        return fail(
            stream,
            ErrorCode::Overloaded,
            format!(
                "in-flight budget exhausted ({} rows in flight, budget {}, request {rows})",
                ctx.inflight.load(Ordering::Relaxed),
                ctx.inflight_max
            ),
        );
    }
    let outcome = submit_rows(ctx, req);
    ctx.inflight.fetch_sub(rows, Ordering::Relaxed);
    match outcome {
        Ok((data, spans)) => {
            ctx.stats.inc_ok();
            let frame = Frame::Response(proto::ResponseFrame {
                id,
                rows: rows as u32,
                cols: out_dim as u32,
                data,
            });
            let t_frame = Instant::now();
            let bytes = frame.to_bytes();
            let frame_ns = dur_ns(t_frame.elapsed());
            let t_write = Instant::now();
            let ok = stream.write_all(&bytes).is_ok();
            if obs::enabled() {
                let mut trace = Trace::begin(id);
                trace.set(Stage::Accept, accept_ns);
                trace.set(Stage::Decode, decode_ns);
                trace.set(Stage::QueueWait, spans.queue_ns);
                trace.set(Stage::Assembly, spans.assembly_ns);
                trace.set(Stage::Compute, spans.compute_ns);
                trace.set(Stage::Frame, frame_ns);
                trace.set(Stage::Write, dur_ns(t_write.elapsed()));
                // server-side request time: everything except the peer's
                // handshake pacing
                obs::hist(HistId::NetRequest).record_ns(
                    trace.total_ns().saturating_sub(accept_ns),
                );
                if ctx.traces.record(&trace) {
                    obs::counter(CounterId::TracesRecorded).inc();
                } else {
                    obs::counter(CounterId::TracesDropped).inc();
                }
            }
            ok
        }
        Err((code, message)) => {
            ctx.stats.inc_failed();
            fail(stream, code, message)
        }
    }
}

/// Submit a request's rows to the batch server and collect the logits
/// plus the batch-plane span times.
///
/// The single-row fast path moves the frame-decoded `Vec<f32>` straight
/// into the job — the engine gathers from that buffer in place, so the
/// socket → logits path copies input floats exactly once (the kernel read
/// into the frame buffer). Multi-row requests split into per-row jobs
/// (they coalesce back into one engine batch via the model group) and pay
/// one row copy each; batch clients are the convenience path.
///
/// Every submission gets a **fresh** reply channel: if the batch plane
/// ever drops a job without answering (an executor panic), the channel
/// disconnects and `recv` errors instead of blocking this handler — and
/// [`NetServer::stop`] — forever. The per-request channel allocation is
/// the price of that liveness guarantee.
fn submit_rows(
    ctx: &ConnCtx,
    req: RequestFrame,
) -> std::result::Result<(Vec<f32>, PipelineSpans), (ErrorCode, String)> {
    let rows = req.rows as usize;
    let stopping = |e: String| (ErrorCode::ShuttingDown, e);
    let dropped = || (ErrorCode::Internal, "server dropped the request".to_string());
    let mut spans = PipelineSpans::default();
    if rows == 1 {
        let (tx, rx) = mpsc::channel();
        ctx.client.submit(&req.model, req.data, tx).map_err(stopping)?;
        return match rx.recv() {
            Ok(o) => {
                spans.queue_ns = o.queue_ns;
                spans.assembly_ns = o.assembly_ns;
                spans.compute_ns = o.compute_ns;
                match o.result {
                    Ok(logits) => Ok((logits, spans)),
                    Err(msg) => Err((ErrorCode::Internal, msg)),
                }
            }
            Err(_) => Err(dropped()),
        };
    }
    let cols = req.cols as usize;
    let mut pending = Vec::with_capacity(rows);
    for r in 0..rows {
        let (tx, rx) = mpsc::channel();
        let row = req.data[r * cols..(r + 1) * cols].to_vec();
        ctx.client.submit(&req.model, row, tx).map_err(stopping)?;
        pending.push(rx);
    }
    let mut out = Vec::new();
    for rx in pending {
        match rx.recv() {
            Ok(o) => {
                // the response waits on the slowest row: keep the worst span
                spans.queue_ns = spans.queue_ns.max(o.queue_ns);
                spans.assembly_ns = spans.assembly_ns.max(o.assembly_ns);
                spans.compute_ns = spans.compute_ns.max(o.compute_ns);
                match o.result {
                    Ok(logits) => out.extend_from_slice(&logits),
                    Err(msg) => return Err((ErrorCode::Internal, msg)),
                }
            }
            Err(_) => return Err(dropped()),
        }
    }
    Ok((out, spans))
}

/// Claim `n` rows of the in-flight budget; `false` (shed) when the budget
/// cannot cover them. A request larger than the whole budget is always
/// shed — by construction it can never fit.
fn try_acquire(inflight: &AtomicUsize, max: usize, n: usize) -> bool {
    let mut cur = inflight.load(Ordering::Relaxed);
    loop {
        if cur + n > max {
            return false;
        }
        match inflight.compare_exchange_weak(
            cur,
            cur + n,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return true,
            Err(c) => cur = c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_acquire_and_shed() {
        let b = AtomicUsize::new(0);
        assert!(try_acquire(&b, 4, 3));
        assert!(try_acquire(&b, 4, 1));
        assert!(!try_acquire(&b, 4, 1), "budget exhausted must shed");
        b.fetch_sub(3, Ordering::Relaxed);
        assert!(try_acquire(&b, 4, 2));
        // a request larger than the whole budget can never fit
        let b = AtomicUsize::new(0);
        assert!(!try_acquire(&b, 4, 5));
    }

    #[test]
    fn default_config_is_sane() {
        let c = NetConfig::default();
        assert!(c.max_connections >= 1);
        assert!(c.inflight_budget >= 1);
        assert_eq!(c.max_frame_bytes, proto::DEFAULT_MAX_FRAME);
        assert!(c.trace_slots >= 2);
    }
}
